"""Quickstart: the FlashAttention-2 stack in 60 seconds.

1. Call the three interchangeable attention backends and check they agree.
2. Differentiate through flash attention (Algorithm 2 backward).
3. Run one training step of an assigned architecture's reduced config.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.attention import AttentionConfig, attention
from repro.core.masks import MaskSpec
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.training.optimizer import AdamWConfig, init_opt_state


def main():
    # --- 1. three backends, one answer -------------------------------
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, H, D = 2, 512, 4, 64
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, D), jnp.float32)
    spec = MaskSpec(causal=True)

    outs = {}
    for impl in ("ref", "flash_xla", "flash_pallas"):
        cfg = AttentionConfig(impl=impl, block_q=128, block_kv=128)
        outs[impl] = attention(q, k, v, spec, cfg)
    err_xla = float(jnp.abs(outs["ref"] - outs["flash_xla"]).max())
    err_pl = float(jnp.abs(outs["ref"] - outs["flash_pallas"]).max())
    print(f"[1] flash_xla vs ref max|err| = {err_xla:.2e}   "
          f"flash_pallas vs ref max|err| = {err_pl:.2e}")
    assert err_xla < 1e-5 and err_pl < 1e-5

    # --- 2. exact gradients through the flash backward ----------------
    f = lambda q: attention(q, k, v, spec, AttentionConfig(impl="flash_xla",
                                                           block_q=128, block_kv=128)).sum()
    g = lambda q: attention(q, k, v, spec, AttentionConfig(impl="ref")).sum()
    dq_flash = jax.grad(f)(q)
    dq_ref = jax.grad(g)(q)
    err_g = float(jnp.abs(dq_flash - dq_ref).max())
    print(f"[2] dQ flash vs ref max|err| = {err_g:.2e}")
    assert err_g < 1e-4

    # --- 3. one train step of a real (reduced) architecture -----------
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(
        cfg, AttentionConfig(impl="flash_xla", block_q=64, block_kv=64),
        AdamWConfig(),
    ))
    batch = {
        "inputs": jnp.zeros((2, 64), jnp.int32),
        "targets": jnp.ones((2, 64), jnp.int32),
    }
    _, _, metrics = step(params, opt, batch)
    print(f"[3] {cfg.name}: one train step, loss = {float(metrics['loss']):.4f}")
    assert jnp.isfinite(metrics["loss"])
    print("quickstart OK")


if __name__ == "__main__":
    main()
