"""Serving example: continuous batching with split-KV flash decode.

Builds a reduced qwen3-style model, submits a mixed bag of requests with
different prompt/output lengths, and drives the slot-based engine. Checks
that every request completes and that batched decode agrees with a
sequential re-run of one request.

Run:  PYTHONPATH=src python examples/serve.py
"""

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    attn_cfg = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64,
                               decode_splits=4)

    engine = ServingEngine(cfg, params, attn_cfg, max_batch=3, cache_size=128)
    prompts = [
        [5, 9, 2, 7],
        [11, 3],
        [8, 8, 8, 1, 2, 3],
        [4, 4, 4, 4],
        [1, 2],
    ]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=8))
    finished = engine.run(max_ticks=200)
    assert len(finished) == len(prompts), f"{len(finished)}/{len(prompts)} finished"
    for rid in sorted(finished):
        req = finished[rid]
        print(f"req {rid}: prompt {req.prompt} -> generated {req.generated}")

    # consistency: slot-batched decode == single-request rerun
    solo = ServingEngine(cfg, params, attn_cfg, max_batch=1, cache_size=128)
    solo.submit(Request(rid=99, prompt=prompts[0], max_new_tokens=8))
    ref = solo.run(max_ticks=50)[99].generated
    assert ref == finished[0].generated, (ref, finished[0].generated)
    print(f"batched == solo for request 0: {ref}")
    print("serve OK")


if __name__ == "__main__":
    main()
