"""End-to-end driver: train a GPT-style model with the FA2 stack.

Trains on the deterministic synthetic-LM pipeline with checkpointing,
straggler telemetry, and NaN step-skip -- the full launch/train.py loop.
Loss must drop well below the uniform-vocabulary entropy (the stream is a
learnable permutation map), which is the end-to-end correctness signal.

Defaults are CPU-friendly (~20M params, 120 steps). The paper-scale run is
the same command with bigger flags:

  # the "few hundred steps of a ~100M model" configuration:
  PYTHONPATH=src python examples/train_gpt.py --preset gpt-100m --steps 300

Run:  PYTHONPATH=src python examples/train_gpt.py [--steps N] [--preset P]
"""

import argparse
import math
import tempfile

import numpy as np

from repro.launch.train import PRESETS, TrainLoopConfig, train
from repro.training.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="gpt-20m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--attn", default="flash_xla")
    ap.add_argument("--packed", action="store_true",
                    help="train on varlen packed batches (segment-masked attention)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoopConfig(
            steps=args.steps, seq_len=args.seq, batch_size=args.batch,
            attn_impl=args.attn, ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 3, 10),
            packed=args.packed,
        )
        _, _, hist = train(cfg, loop, AdamWConfig(lr=1e-3, warmup_steps=20,
                                                  total_steps=args.steps))

    uniform = math.log(cfg.vocab_size)
    first = float(np.mean(hist["loss"][:5]))
    last = float(np.mean(hist["loss"][-5:]))
    print(f"\nuniform entropy {uniform:.3f} | first-5 loss {first:.3f} | last-5 loss {last:.3f}")
    assert last < first - 0.5, "training did not learn"
    print("train_gpt OK")


if __name__ == "__main__":
    main()
