"""Long-context example: context-parallel attention + windowed flash +
O(N) SSM decode.

The paper's motivation is scaling context. This example shows the paths the
framework uses for the long_500k shape:

  1. Context parallelism on a device mesh, BOTH sharding modes:
     'sequence' (KV all-gathered per layer -- per-device KV is O(S)) vs
     'ring' (KV stays sharded and rotates -- per-device KV is O(S/P)).
     On the overlap regime, where replicated KV still fits, the two modes
     are asserted equal; the printed ledger shows why only the ring
     scales to lengths where S * Hkv * D no longer fits one device.
  2. Sliding-window flash attention (gemma3/mixtral style): packed tile
     scheduling visits only ~(window/block) tiles per row instead of all,
     validated against the reference on a window-masked computation.
  3. A hybrid (attention+SSM) reduced hymba config decoding far past its
     attention window with constant per-token state.

Run:  PYTHONPATH=src python examples/long_context.py
(The mesh demo forces 4 virtual host devices; it must run before jax
initializes, which is why the env var is set at the top of this file.)
"""

import os
import time

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    ).strip()

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.core.flash import flash_attention
from repro.core.masks import MaskSpec
from repro.kernels.ref import attention_reference
from repro.launch.steps import build_prefill_step, build_serve_step


def context_parallel_modes():
    """Ring vs all-gather context parallelism on a (1, 4) host mesh.

    Both legs run on genuinely sequence-sharded inputs and mirror the model
    path (models/attention_layer.py): constrain q, gather_kv, attention.
    Under 'sequence' rules the gather constraint makes XLA all-gather the
    full KV per device; under 'ring' rules KV stays sharded and rotates --
    the compiled programs are inspected to show exactly that.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P_
    from repro.core.attention import AttentionConfig, attention
    from repro.distributed import ring_schedule as rs
    from repro.distributed.context_parallel import gather_kv
    from repro.distributed.sharding import constrain, lm_rules, use_rules
    from repro.launch.mesh import make_long_context_mesh

    mesh = make_long_context_mesh()
    P = mesh.shape["model"]
    B, S, Hq, Hkv, D = 1, 4096, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, Hkv, D), jnp.float32)
    spec = MaskSpec(causal=True)
    cfg = AttentionConfig(impl="flash_xla", block_q=256, block_kv=256)
    seq_sharded = NamedSharding(mesh, P_(None, "model", None, None))

    def make_layer(mode):
        # One closure PER MODE: attention() reads the sharding rules from
        # the ambient context at trace time, and jax's tracing cache keys
        # on function identity + avals -- re-jitting one shared function
        # under different rule contexts would silently reuse the first
        # mode's trace (see context_parallel.attn_context_mode).
        def layer(q, k, v):  # the model path: constrain + gather_kv + attention
            q = constrain(q, "batch", "seq", "heads", None)
            k, v = gather_kv(k, v)
            return attention(q, k, v, spec, cfg)

        return layer

    outs, gathers = {}, {}
    for mode in ("sequence", "ring"):
        rules = lm_rules(attn_sharding=mode, model_axis=P)
        with mesh, use_rules(mesh, rules):
            fn = jax.jit(make_layer(mode), in_shardings=(seq_sharded,) * 3)
            compiled = fn.lower(q, k, v).compile()  # AOT: compile ONCE, reuse
            gathers[mode] = "all-gather" in compiled.as_text()
            outs[mode] = compiled(q, k, v)
    err = float(jnp.abs(outs["ring"] - outs["sequence"]).max())
    print(f"[1] ring vs all-gather context parallelism on {P} devices: "
          f"max|err| = {err:.2e}  (compiled HLO: gather mode "
          f"{'has' if gathers['sequence'] else 'MISSING'} the KV all-gather, "
          f"ring mode has {'NONE' if not gathers['ring'] else 'one?!'})")
    assert err < 1e-5, "ring and gather context parallelism disagree"
    assert gathers["sequence"] and not gathers["ring"], gathers

    # The ledger for a length where replicated KV stops fitting: 512k
    # tokens of bf16 KV at Hkv=8, D=128 is 2 GB replicated -- per chip! --
    # vs 2/P of that resident under the ring.
    S_big = 1 << 19
    layout = rs.make_layout(S_big, 16, spec)
    kw = dict(kv_heads=8, head_dim=128, dtype_bytes=2)
    gather = rs.peak_kv_bytes_per_device(layout, mode="gather", **kw)
    ring = rs.peak_kv_bytes_per_device(layout, mode="ring", **kw)
    print(f"    long_500k ledger (P=16): per-device resident KV "
          f"{gather/2**30:.2f} GiB gathered vs {ring/2**30:.3f} GiB ring; "
          f"comms/device equal ({rs.comm_bytes_per_device(layout, **kw)/2**20:.0f} MiB/layer), "
          "rotation overlaps compute")


def windowed_flash():
    B, S, H, D, W = 1, 2048, 2, 64, 256
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, D), jnp.float32)

    spec = MaskSpec(causal=True, window=W)
    o_ref = attention_reference(q, k, v, spec)[0]

    dense = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, MaskSpec(causal=True), block_q=128, block_kv=128, mode="dense"))
    packed = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, spec, block_q=128, block_kv=128, mode="packed"))

    o = packed(q, k, v)
    err = float(jnp.abs(o - o_ref).max())
    print(f"[2] windowed packed flash vs ref: max|err| = {err:.2e}")
    assert err < 1e-5

    for name, fn in (("dense/causal", dense), ("packed/window", packed)):
        jax.block_until_ready(fn(q, k, v))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(q, k, v))
        print(f"    {name:14s} {(time.perf_counter()-t0)/3*1e3:8.1f} ms")


def hybrid_long_decode():
    cfg = registry.reduce_config(registry.get("hymba-1.5b"))
    params = __import__("repro.models.lm", fromlist=["lm"]).init_lm(
        cfg, jax.random.PRNGKey(1))
    attn_cfg = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64,
                               decode_splits=4)
    cache = 512  # far beyond the reduced window of 32
    prefill = jax.jit(build_prefill_step(cfg, attn_cfg, cache_size=cache))
    step = jax.jit(build_serve_step(cfg, attn_cfg))

    tok, caches, lens = prefill(params, {"inputs": jnp.ones((1, 16), jnp.int32)})
    n_new = 64
    for _ in range(n_new):
        tok, caches = step(params, tok, caches, lens)
        lens = lens + 1
        assert bool(jnp.isfinite(tok).all())
    print(f"[3] {cfg.name}: decoded {n_new} tokens past window={cfg.window} "
          f"(SSM state is O(1)/token); final len {int(lens[0])}")


def main():
    context_parallel_modes()
    windowed_flash()
    hybrid_long_decode()
    print("long_context OK")


if __name__ == "__main__":
    main()
