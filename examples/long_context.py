"""Long-context example: windowed flash attention + O(N) SSM decode.

The paper's motivation is scaling context. This example shows the two
sub-quadratic paths the framework uses for the long_500k shape:

  1. Sliding-window flash attention (gemma3/mixtral style): packed tile
     scheduling visits only ~(window/block) tiles per row instead of all,
     validated against the reference on a window-masked computation.
  2. A hybrid (attention+SSM) reduced hymba config decoding far past its
     attention window with constant per-token state.

Run:  PYTHONPATH=src python examples/long_context.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.core.flash import flash_attention
from repro.core.masks import MaskSpec
from repro.kernels.ref import attention_reference
from repro.launch.steps import build_prefill_step, build_serve_step


def windowed_flash():
    B, S, H, D, W = 1, 2048, 2, 64, 256
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, D), jnp.float32)

    spec = MaskSpec(causal=True, window=W)
    o_ref = attention_reference(q, k, v, spec)[0]

    dense = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, MaskSpec(causal=True), block_q=128, block_kv=128, mode="dense"))
    packed = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, spec, block_q=128, block_kv=128, mode="packed"))

    o = packed(q, k, v)
    err = float(jnp.abs(o - o_ref).max())
    print(f"[1] windowed packed flash vs ref: max|err| = {err:.2e}")
    assert err < 1e-5

    for name, fn in (("dense/causal", dense), ("packed/window", packed)):
        jax.block_until_ready(fn(q, k, v))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(q, k, v))
        print(f"    {name:14s} {(time.perf_counter()-t0)/3*1e3:8.1f} ms")


def hybrid_long_decode():
    cfg = registry.reduce_config(registry.get("hymba-1.5b"))
    params = __import__("repro.models.lm", fromlist=["lm"]).init_lm(
        cfg, jax.random.PRNGKey(1))
    attn_cfg = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64,
                               decode_splits=4)
    cache = 512  # far beyond the reduced window of 32
    prefill = jax.jit(build_prefill_step(cfg, attn_cfg, cache_size=cache))
    step = jax.jit(build_serve_step(cfg, attn_cfg))

    tok, caches, lens = prefill(params, {"inputs": jnp.ones((1, 16), jnp.int32)})
    n_new = 64
    for _ in range(n_new):
        tok, caches = step(params, tok, caches, lens)
        lens = lens + 1
        assert bool(jnp.isfinite(tok).all())
    print(f"[2] {cfg.name}: decoded {n_new} tokens past window={cfg.window} "
          f"(SSM state is O(1)/token); final len {int(lens[0])}")


def main():
    windowed_flash()
    hybrid_long_decode()
    print("long_context OK")


if __name__ == "__main__":
    main()
