"""Occupancy sweep (paper Fig. 5 analog): forward partitioning vs shape.

FlashAttention-2 Section 3.2: at small batch x heads the (B*H)-parallel
grid starves the chip, and parallelizing over the *sequence* axis
recovers occupancy. This module measures the three forward schedules --
dense (legacy 3-D grid), compact unbanded (PR 2), compact banded
(ISSUE 5) -- over a (batch x heads x seqlen) grid at B in {1, 2, 8}:

  * ``occupancy_fwd`` rows: kernel-layer wall time (jit over prepped
    (BH, S, D) tensors; interpret mode executes grid steps serially on
    CPU, so these rows measure *total* step count, not parallel speed --
    reported, not asserted).
  * ``occupancy_grid`` rows: the grid-utilization ledger. Per shape and
    variant: parallel grid cells, sequential steps per cell, and the
    modeled time ``steps * ceil(cells / CORES)`` for a CORES-way chip.
    This is where the paper's claim is checkable on a host without a TPU:
    ASSERTED -- banded modeled time beats unbanded compact at every
    small-BH shape and never regresses (the auto policy degrades to one
    band when BH alone fills the target, making banded == unbanded).
  * ``occupancy_census`` rows: trip-aware HLO transcendental census
    (nonmatmul_census-style): at a balance-exact shape the banded kernel
    must run EXACTLY the unbanded kernel's exp count -- banding adds zero
    exps/rescales per visible tile, i.e. placeholder steps are
    compute-free, not masked-compute. ASSERTED.

Rows merge into BENCH_attn.json via ``python -m benchmarks.run --json``;
the CI benchmark smoke runs this module (fast shapes only).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_timeit
from repro.core.flash import _visible_pairs
from repro.core.masks import MaskSpec
from repro.kernels import flash_fwd as FF
from repro.kernels.ops import (
    _TARGET_PARALLEL_CELLS,
    default_forward_partitions,
)
from repro.kernels.schedule import build_partitioned_schedule, build_tile_schedule

HEAD_DIM = 64
BLOCK = 64
# modeled chip parallelism: grid cells that can run concurrently. Matches
# the auto policy's target so "policy fills the model chip" is the claim.
CORES = _TARGET_PARALLEL_CELLS

# (batch, heads, seq): B=1 long-S is the paper's Fig. 5 starved regime;
# B=8 x 8 heads saturates the target and must not regress.
SHAPES = ((1, 4, 512), (2, 4, 512), (8, 8, 256))


def _grid_stats(variant: str, BH: int, t: int, spec: MaskSpec, seq: int):
    """(parallel_cells, seq_steps) for one forward variant at one shape."""
    if variant == "dense":
        # (BH, Tq, Tkv) with (parallel, parallel, arbitrary) semantics
        return BH * t, t
    if variant == "compact":
        sched = build_tile_schedule(spec, t, t, BLOCK, BLOCK, seq)
        return BH, sched.n_steps
    if variant == "banded":
        nb, _ = default_forward_partitions(BH, t, t)
        sched = build_partitioned_schedule(
            spec, t, t, BLOCK, BLOCK, seq, num_q_bands=nb
        )
        return BH * sched.num_parts, sched.n_steps
    raise ValueError(variant)


def _model_time(cells: int, steps: int) -> int:
    """Sequential steps on a CORES-way chip: waves x steps per cell."""
    return steps * -(-cells // CORES)


def grid_utilization(csv: List[str]) -> None:
    """The static occupancy ledger + the banded-beats-unbanded assert."""
    spec = MaskSpec(causal=True)
    for B, H, seq in SHAPES:
        BH, t = B * H, seq // BLOCK
        model = {}
        for variant in ("dense", "compact", "banded"):
            cells, steps = _grid_stats(variant, BH, t, spec, seq)
            model[variant] = _model_time(cells, steps)
            nb, _ = default_forward_partitions(BH, t, t)
            bands = nb if variant == "banded" else 1
            csv.append(
                f"occupancy_grid/B={B}/H={H}/seq={seq}/{variant},,"
                f"cells={cells};steps={steps};model={model[variant]};bands={bands}"
            )
        # the tentpole claim: sequence parallelism and visible-tile-only
        # scheduling COMPOSE -- banded never models slower than unbanded,
        # and strictly beats it wherever BH alone under-fills the chip.
        assert model["banded"] <= model["compact"], (B, H, seq, model)
        if BH < CORES:
            assert model["banded"] < model["compact"], (B, H, seq, model)
        else:
            nb, _ = default_forward_partitions(BH, t, t)
            assert nb == 1, "auto policy must degrade to 1 band at large BH"


def fwd_timing(csv: List[str]) -> None:
    """Kernel-layer wall-clock rows (interpret mode: serial step count).

    The three schedule variants of one shape are timed INTERLEAVED
    min-of-N (shared benchmarks/timing helper): they are compared against
    each other, so host drift must hit all three equally.
    """
    spec = MaskSpec(causal=True)
    key = jax.random.PRNGKey(0)
    for B, H, seq in SHAPES:
        BH, t = B * H, seq // BLOCK
        ks = jax.random.split(jax.random.fold_in(key, B * seq), 3)
        qh, kh, vh = (
            jax.random.normal(k_, (BH, seq, HEAD_DIM), jnp.float32) for k_ in ks
        )
        kw = dict(group=1, block_q=BLOCK, block_kv=BLOCK, kv_valid=seq)
        nb, _ = default_forward_partitions(BH, t, t)
        variants = {
            "dense": dict(schedule="dense"),
            "compact": dict(schedule="compact"),
            "banded": dict(schedule="compact", num_q_bands=nb),
        }
        fns = {
            name: jax.jit(
                lambda q, k, v, e=tuple(extra.items()): FF.flash_fwd(
                    q, k, v, spec, **kw, **dict(e)
                )
            )
            for name, extra in variants.items()
        }
        best = interleaved_timeit(fns, qh, kh, vh, iters=3)
        for name in variants:
            csv.append(
                f"occupancy_fwd/B={B}/H={H}/seq={seq}/{name},"
                f"{best[name]*1e6:.0f},bands={nb if name == 'banded' else 1}"
                f";timing={best.provenance}"
            )


def banded_exp_census(csv: List[str]) -> None:
    """Zero-extra-exp assert (nonmatmul_census-style).

    At a balance-exact shape (causal t=4, 2 bands: rows {0,3} and {1,2}
    both hold 5 visible tiles, so partition tables need no padding) the
    banded kernel's compiled HLO must contain EXACTLY the unbanded
    kernel's transcendental count: placeholder steps are compute-free
    (`pl.when` skipped), never masked-compute, and banding adds zero exps
    or rescale divides per visible tile.
    """
    from benchmarks.nonmatmul_census import _census

    B2, H2, S2 = 2, 2, 256
    BH, t = B2 * H2, S2 // BLOCK
    spec = MaskSpec(causal=True)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    qh, kh, vh = (
        jax.random.normal(k_, (BH, S2, 32), jnp.float32) for k_ in ks
    )
    kw = dict(group=1, block_q=BLOCK, block_kv=BLOCK, kv_valid=S2)
    n_vis = len(_visible_pairs(spec, t, t, BLOCK, BLOCK)[0])
    sched = build_partitioned_schedule(
        spec, t, t, BLOCK, BLOCK, S2, num_q_bands=2
    )
    assert sched.num_parts * sched.n_steps == n_vis, "shape must balance exactly"
    counts = {}
    for name, nb in (("unbanded", 1), ("banded", 2)):
        c = _census(
            lambda q, k, v, nb=nb: FF.flash_fwd(
                q, k, v, spec, **kw, num_q_bands=nb
            ),
            qh, kh, vh,
        )
        counts[name] = (c["transcendentals"], c["divides"])
        csv.append(
            f"occupancy_census/{name},,"
            f"exp_elems={c['transcendentals']:.3e};div={c['divides']:.3e}"
        )
    assert counts["banded"] == counts["unbanded"], (
        "banding must add zero exps/rescales per visible tile", counts,
    )


def run(csv: List[str]) -> None:
    grid_utilization(csv)
    fwd_timing(csv)
    banded_exp_census(csv)
