"""Fig. 4/5/6 analogue: attention speed, 3 implementations x sequence length.

Paper setting (A100): seq 512..16k with batch*seq = 16k tokens, hidden 2048,
head dim 64/128, causal and non-causal, fwd and fwd+bwd. CPU adaptation:
same batch*seq = const protocol with a reduced token budget; the *claims*
validated are relative (flash >= ref as seq grows; causal ~halves time in
packed mode), not A100 TFLOPs/s.

Derived column: TFLOPs/s using the paper's formula
    4 * seqlen^2 * head_dim * heads   ( / 2 if causal; * 2.5 for fwd+bwd ).
"""

from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_timeit, time_min
from repro.core.attention import AttentionConfig, attention
from repro.core.masks import MaskSpec

TOKENS = 4096  # batch * seq held constant, like the paper's 16k
HEADS, HEAD_DIM = 4, 64
SEQS = (256, 512, 1024, 2048)


def _time(fn: Callable, *args, iters: int = 5) -> float:
    """Min-of-N wall time (shared helper; see benchmarks/timing.py).

    The previous single-warmup mean-of-3 was noise-dominated on a shared
    host and recorded ``ref`` forward-only at seq=512 as *slower* than
    forward+backward in BENCH_attn.json — a physical impossibility that
    forced a re-baseline of the whole trajectory once fixed.
    """
    return time_min(fn, *args, iters=iters)


def _flops(seq: int, batch: int, causal: bool, bwd: bool) -> float:
    f = 4.0 * seq * seq * HEAD_DIM * HEADS * batch
    if causal:
        f /= 2
    if bwd:
        f *= 3.5  # fwd (1) + bwd (2.5)
    return f


def _mk_qkv(key, seq: int, batch: int):
    kq, kk, kv = jax.random.split(key, 3)
    shape = (batch, seq, HEADS, HEAD_DIM)
    return (
        jax.random.normal(kq, shape, jnp.float32),
        jax.random.normal(kk, shape, jnp.float32),
        jax.random.normal(kv, shape, jnp.float32),
    )


def _time_pair(
    csv: List[str], names, cfg: AttentionConfig, spec: MaskSpec,
    q, k, v, seq: int, batch: int, causal: bool,
) -> None:
    """Time fwd and fwd+bwd for one config; append one CSV row each.

    names = (fwd_row_name, fwdbwd_row_name) -- everything left of the first
    comma in the emitted rows. The two are timed INTERLEAVED min-of-N
    (shared helper): they will be compared, so drift must hit both equally
    -- fwd > fwd+bwd in the output is a timing bug, not a measurement.
    """
    fwd = jax.jit(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg))
    loss = jax.jit(
        jax.grad(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg).sum())
    )
    best = interleaved_timeit({"fwd": fwd, "fwdbwd": loss}, q, k, v)
    t_f, t_b = best["fwd"], best["fwdbwd"]
    csv.append(
        f"{names[0]},{t_f*1e6:.0f},{_flops(seq, batch, causal, False)/t_f/1e12:.4f} TFLOP/s"
        f";timing={best.provenance}"
    )
    csv.append(
        f"{names[1]},{t_b*1e6:.0f},{_flops(seq, batch, causal, True)/t_b/1e12:.4f} TFLOP/s"
        f";timing={best.provenance}"
    )


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    for causal in (False, True):
        spec = MaskSpec(causal=causal)
        for seq in SEQS:
            batch = max(1, TOKENS // seq)
            q, k, v = _mk_qkv(jax.random.fold_in(key, seq), seq, batch)
            for impl in ("ref", "flash_xla", "flash_pallas"):
                if impl == "flash_pallas" and seq > 512:
                    continue  # interpret-mode python loop: keep it tractable
                cfg = AttentionConfig(
                    impl=impl, block_q=128, block_kv=128,
                    mode="packed" if causal else "dense",
                )
                tag = f"{impl}/causal={int(causal)}/seq={seq}"
                _time_pair(
                    csv, (f"fig5_fwd/{tag}", f"fig4_fwdbwd/{tag}"),
                    cfg, spec, q, k, v, seq, batch, causal,
                )

    schedule_comparison(csv, key)
    bwd_comparison(csv, key)


def schedule_comparison(csv: List[str], key=None) -> None:
    """Compact-vs-dense Pallas tile schedule (FA2 Section 3.1 partitioning).

    Causal at a fixed small shape (interpret mode makes each grid step a
    Python-level kernel invocation, so the visited-step count is exactly
    what this measures): the compact schedule visits ~(t+1)/2t of the dense
    steps and must not regress on fwd or fwd+bwd. Also exposed as the
    ``sched_cmp`` benchmark module for the CI fast-tier smoke.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    seq, batch = 256, max(1, TOKENS // 256)
    spec = MaskSpec(causal=True)
    q, k, v = _mk_qkv(jax.random.fold_in(key, 7), seq, batch)
    for schedule in ("dense", "compact"):
        cfg = AttentionConfig(
            impl="flash_pallas", block_q=64, block_kv=64, schedule=schedule
        )
        tag = f"flash_pallas/schedule={schedule}/causal=1/seq={seq}"
        _time_pair(
            csv, (f"sched_cmp_fwd/{tag}", f"sched_cmp_fwdbwd/{tag}"),
            cfg, spec, q, k, v, seq, batch, True,
        )


def bwd_comparison(csv: List[str], key=None) -> None:
    """Fused one-pass vs split 3-launch Pallas backward (ISSUE 4).

    Causal seq >= 512 (the acceptance shape), timed at the KERNEL layer:
    one jit'd fwd+bwd over prepped (B*H, S, D) tensors per variant, so the
    row isolates exactly what the fused kernel changes (launches, (s, p)
    recompute, exp count, Q/dO streaming). The ``attention()``-layer grad
    is NOT used here on purpose: interpret mode lowers each grid step to an
    XLA while iteration that copies every carried array, and inside a full
    ``jax.grad`` those copies dominate and wash out the kernel delta on a
    small host. Fused must beat split -- asserted (interleaved min-of-N
    timing via the shared benchmarks/timing helper -- this function's
    original inline scheme is where the repo-wide discipline came from),
    not just reported. Also the ``bwd_cmp`` module for CI.
    """
    from repro.kernels import flash_bwd as FB
    from repro.kernels import flash_fwd as FF

    if key is None:
        key = jax.random.PRNGKey(0)
    seq, blk = 2048, 256
    batch = max(1, TOKENS // seq)
    BH = batch * HEADS
    spec = MaskSpec(causal=True)
    ks = jax.random.split(jax.random.fold_in(key, 11), 4)
    qh, kh, vh, do = (
        jax.random.normal(k_, (BH, seq, HEAD_DIM), jnp.float32) for k_ in ks
    )
    kw = dict(group=1, block_q=blk, block_kv=blk, kv_valid=seq)

    def make(bwd):
        def fn(qh, kh, vh, do):
            o, lse = FF.flash_fwd(qh, kh, vh, spec, **kw)
            if bwd == "fused":
                dk, dv, dq = FB.flash_bwd_fused(
                    qh, kh, vh, o, do, lse, spec, **kw
                )
            else:
                delta = FB.flash_bwd_delta(o, do, block_q=blk)
                lse_s = jnp.where(jnp.isneginf(lse), 0.0, lse)
                dk, dv = FB.flash_bwd_dkv(qh, kh, vh, do, lse_s, delta, spec, **kw)
                dq = FB.flash_bwd_dq(qh, kh, vh, do, lse_s, delta, spec, **kw)
            return dq, dk, dv

        return jax.jit(fn)

    fns = {bwd: make(bwd) for bwd in ("split", "fused")}
    # interleaved min-of-N (shared helper): robust to host contention
    best = interleaved_timeit(fns, qh, kh, vh, do, iters=5)
    for bwd in ("split", "fused"):
        tag = f"flash_pallas/bwd={bwd}/causal=1/seq={seq}"
        csv.append(
            f"bwd_cmp_fwdbwd/{tag},{best[bwd]*1e6:.0f},"
            f"{_flops(seq, batch, True, True)/best[bwd]/1e12:.4f} TFLOP/s"
            f";timing={best.provenance}"
        )
    assert best["fused"] < best["split"], (
        "fused backward must beat the split baseline", best,
    )
