"""Fig. 4/5/6 analogue: attention speed, 3 implementations x sequence length.

Paper setting (A100): seq 512..16k with batch*seq = 16k tokens, hidden 2048,
head dim 64/128, causal and non-causal, fwd and fwd+bwd. CPU adaptation:
same batch*seq = const protocol with a reduced token budget; the *claims*
validated are relative (flash >= ref as seq grows; causal ~halves time in
packed mode), not A100 TFLOPs/s.

Derived column: TFLOPs/s using the paper's formula
    4 * seqlen^2 * head_dim * heads   ( / 2 if causal; * 2.5 for fwd+bwd ).
"""

from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig, attention
from repro.core.masks import MaskSpec

TOKENS = 4096  # batch * seq held constant, like the paper's 16k
HEADS, HEAD_DIM = 4, 64
SEQS = (256, 512, 1024, 2048)


def _time(fn: Callable, *args, iters: int = 3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _flops(seq: int, batch: int, causal: bool, bwd: bool) -> float:
    f = 4.0 * seq * seq * HEAD_DIM * HEADS * batch
    if causal:
        f /= 2
    if bwd:
        f *= 3.5  # fwd (1) + bwd (2.5)
    return f


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    for causal in (False, True):
        spec = MaskSpec(causal=causal)
        for seq in SEQS:
            batch = max(1, TOKENS // seq)
            kq, kk, kv = jax.random.split(jax.random.fold_in(key, seq), 3)
            q = jax.random.normal(kq, (batch, seq, HEADS, HEAD_DIM), jnp.float32)
            k = jax.random.normal(kk, (batch, seq, HEADS, HEAD_DIM), jnp.float32)
            v = jax.random.normal(kv, (batch, seq, HEADS, HEAD_DIM), jnp.float32)
            for impl in ("ref", "flash_xla", "flash_pallas"):
                if impl == "flash_pallas" and seq > 512:
                    continue  # interpret-mode python loop: keep it tractable
                cfg = AttentionConfig(
                    impl=impl, block_q=128, block_kv=128,
                    mode="packed" if causal else "dense",
                )

                fwd = jax.jit(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg))
                t_f = _time(fwd, q, k, v)
                csv.append(
                    f"fig5_fwd/{impl}/causal={int(causal)}/seq={seq},"
                    f"{t_f*1e6:.0f},{_flops(seq, batch, causal, False)/t_f/1e12:.4f} TFLOP/s"
                )

                loss = jax.jit(
                    jax.grad(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg).sum())
                )
                t_b = _time(loss, q, k, v)
                csv.append(
                    f"fig4_fwdbwd/{impl}/causal={int(causal)}/seq={seq},"
                    f"{t_b*1e6:.0f},{_flops(seq, batch, causal, True)/t_b/1e12:.4f} TFLOP/s"
                )
