"""Fig. 4/5/6 analogue: attention speed, 3 implementations x sequence length.

Paper setting (A100): seq 512..16k with batch*seq = 16k tokens, hidden 2048,
head dim 64/128, causal and non-causal, fwd and fwd+bwd. CPU adaptation:
same batch*seq = const protocol with a reduced token budget; the *claims*
validated are relative (flash >= ref as seq grows; causal ~halves time in
packed mode), not A100 TFLOPs/s.

Derived column: TFLOPs/s using the paper's formula
    4 * seqlen^2 * head_dim * heads   ( / 2 if causal; * 2.5 for fwd+bwd ).
"""

from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig, attention
from repro.core.masks import MaskSpec

TOKENS = 4096  # batch * seq held constant, like the paper's 16k
HEADS, HEAD_DIM = 4, 64
SEQS = (256, 512, 1024, 2048)


def _time(fn: Callable, *args, iters: int = 3) -> float:
    # warmup (compile) once; jax.block_until_ready handles pytrees/tuples.
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def _flops(seq: int, batch: int, causal: bool, bwd: bool) -> float:
    f = 4.0 * seq * seq * HEAD_DIM * HEADS * batch
    if causal:
        f /= 2
    if bwd:
        f *= 3.5  # fwd (1) + bwd (2.5)
    return f


def _mk_qkv(key, seq: int, batch: int):
    kq, kk, kv = jax.random.split(key, 3)
    shape = (batch, seq, HEADS, HEAD_DIM)
    return (
        jax.random.normal(kq, shape, jnp.float32),
        jax.random.normal(kk, shape, jnp.float32),
        jax.random.normal(kv, shape, jnp.float32),
    )


def _time_pair(
    csv: List[str], names, cfg: AttentionConfig, spec: MaskSpec,
    q, k, v, seq: int, batch: int, causal: bool,
) -> None:
    """Time fwd and fwd+bwd for one config; append one CSV row each.

    names = (fwd_row_name, fwdbwd_row_name) -- everything left of the first
    comma in the emitted rows.
    """
    fwd = jax.jit(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg))
    t_f = _time(fwd, q, k, v)
    csv.append(
        f"{names[0]},{t_f*1e6:.0f},{_flops(seq, batch, causal, False)/t_f/1e12:.4f} TFLOP/s"
    )
    loss = jax.jit(
        jax.grad(lambda q, k, v, cfg=cfg: attention(q, k, v, spec, cfg).sum())
    )
    t_b = _time(loss, q, k, v)
    csv.append(
        f"{names[1]},{t_b*1e6:.0f},{_flops(seq, batch, causal, True)/t_b/1e12:.4f} TFLOP/s"
    )


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    for causal in (False, True):
        spec = MaskSpec(causal=causal)
        for seq in SEQS:
            batch = max(1, TOKENS // seq)
            q, k, v = _mk_qkv(jax.random.fold_in(key, seq), seq, batch)
            for impl in ("ref", "flash_xla", "flash_pallas"):
                if impl == "flash_pallas" and seq > 512:
                    continue  # interpret-mode python loop: keep it tractable
                cfg = AttentionConfig(
                    impl=impl, block_q=128, block_kv=128,
                    mode="packed" if causal else "dense",
                )
                tag = f"{impl}/causal={int(causal)}/seq={seq}"
                _time_pair(
                    csv, (f"fig5_fwd/{tag}", f"fig4_fwdbwd/{tag}"),
                    cfg, spec, q, k, v, seq, batch, causal,
                )

    schedule_comparison(csv, key)


def schedule_comparison(csv: List[str], key=None) -> None:
    """Compact-vs-dense Pallas tile schedule (FA2 Section 3.1 partitioning).

    Causal at a fixed small shape (interpret mode makes each grid step a
    Python-level kernel invocation, so the visited-step count is exactly
    what this measures): the compact schedule visits ~(t+1)/2t of the dense
    steps and must not regress on fwd or fwd+bwd. Also exposed as the
    ``sched_cmp`` benchmark module for the CI fast-tier smoke.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    seq, batch = 256, max(1, TOKENS // 256)
    spec = MaskSpec(causal=True)
    q, k, v = _mk_qkv(jax.random.fold_in(key, 7), seq, batch)
    for schedule in ("dense", "compact"):
        cfg = AttentionConfig(
            impl="flash_pallas", block_q=64, block_kv=64, schedule=schedule
        )
        tag = f"flash_pallas/schedule={schedule}/causal=1/seq={seq}"
        _time_pair(
            csv, (f"sched_cmp_fwd/{tag}", f"sched_cmp_fwdbwd/{tag}"),
            cfg, spec, q, k, v, seq, batch, True,
        )
