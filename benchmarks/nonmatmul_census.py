"""C1 validation: FA1-style vs FA2 non-matmul FLOP census + wall time.

The paper's Section 3.1 claim: deferring the `diag(l)^-1` rescale to the end
of the KV loop (C1a) and saving only the logsumexp (C1b) removes O(N*d) and
O(N) non-matmul work *per KV block*. We lower both variants and

  * count transcendental + divide elementwise FLOPs with the trip-aware HLO
    walker (XLA's own cost_analysis counts scan bodies once),
  * time both on CPU (same matmul FLOPs -> any delta is non-matmul work).

The ``nonmatmul_bwd`` rows extend the census to the Pallas backward: the
fused one-pass kernel must run EXACTLY one exp per visible tile
(BH * n_vis * bq * bk transcendental elements -- asserted, not just
reported), while the split baseline recomputes p in both dkv and dq and
runs exactly two.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_timeit

from repro.core.flash import flash_attention_with_lse
from repro.core.flash_v1 import flash_v1_attention
from repro.core.masks import MaskSpec
from repro.utils.hlo_walker import HloModule

B, S, H, D = 4, 2048, 4, 64
BLOCK = 256


def _census(fn, *args) -> dict:
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    mod = HloModule(hlo)
    cost = mod.entry_cost()
    return {
        "transcendentals": cost.transcendentals,
        "divides": _count_divide_elems(mod),
        "flops": cost.flops,
    }


def _count_divide_elems(mod: HloModule) -> float:
    """Trip-aware divide element count (walker tracks transcendentals only)."""
    from repro.utils.hlo_walker import _first_shape

    def comp_divides(comp: str, seen=None) -> float:
        total = 0.0
        for op in mod.computations.get(comp, []):
            if op.op == "divide":
                sh = _first_shape(op.result_str)
                n = 1
                if sh:
                    for d in sh[1]:
                        n *= d
                total += n
            trips = 1
            if op.op == "while":
                trips = mod._trip_count(op.rest) or 1
            for sub in mod._called(op.rest):
                total += comp_divides(sub) * trips
        return total

    return comp_divides(mod.entry)


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, D), jnp.float32)
    spec = MaskSpec(causal=True)

    def fa2(q, k, v):
        return flash_attention_with_lse(
            q, k, v, spec, block_q=BLOCK, block_kv=BLOCK, mode="dense"
        )[0]

    def fa1(q, k, v):
        return flash_v1_attention(q, k, v, spec, block_kv=BLOCK)[0]

    # numerically identical first
    o1 = jax.jit(fa1)(q, k, v)
    o2 = jax.jit(fa2)(q, k, v)
    assert jnp.allclose(o1, o2, atol=1e-5), "FA1/FA2 forward mismatch"

    fns = {"fa1_style": fa1, "fa2": fa2}
    census = {name: _census(fn, q, k, v) for name, fn in fns.items()}
    # the two variants are compared row-to-row: interleaved min-of-N
    # (shared benchmarks/timing helper) so drift hits both equally
    best = interleaved_timeit(
        {name: jax.jit(fn) for name, fn in fns.items()}, q, k, v, iters=5
    )
    for name, c in census.items():
        csv.append(
            f"c1_census/{name},{best[name]*1e6:.0f},"
            f"transc={c['transcendentals']:.3e};div={c['divides']:.3e};matmul={c['flops']:.3e}"
            f";timing={best.provenance}"
        )

    bwd_exp_census(csv)


def bwd_exp_census(csv: List[str]) -> None:
    """Backward exp census: fused one-pass vs split 3-launch Pallas bwd.

    Runs the two backward variants on identical prepped residuals and counts
    transcendental elements in the compiled (interpret-mode) HLO with the
    trip-aware walker. Asserts the fused kernel's count is EXACTLY
    ``BH * n_visible_tiles * bq * bk`` (one exp per visible tile) and the
    split baseline's exactly double (dkv + dq each recompute p).
    """
    from repro.core.flash import _visible_pairs
    from repro.kernels import flash_bwd as FB
    from repro.kernels import flash_fwd as FF

    B2, S2, H2, D2, BLK = 2, 256, 2, 32, 64
    BH = B2 * H2
    spec = MaskSpec(causal=True)
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    qh = jax.random.normal(ks[0], (BH, S2, D2), jnp.float32)
    kh = jax.random.normal(ks[1], (BH, S2, D2), jnp.float32)
    vh = jax.random.normal(ks[2], (BH, S2, D2), jnp.float32)
    do = jax.random.normal(ks[3], (BH, S2, D2), jnp.float32)
    o, lse = FF.flash_fwd(
        qh, kh, vh, spec, group=1, block_q=BLK, block_kv=BLK, kv_valid=S2
    )
    kw = dict(group=1, block_q=BLK, block_kv=BLK, kv_valid=S2)

    def fused(qh, kh, vh, o, do, lse):
        return FB.flash_bwd_fused(qh, kh, vh, o, do, lse, spec, **kw)

    def split(qh, kh, vh, o, do, lse):
        delta = FB.flash_bwd_delta(o, do, block_q=BLK)
        lse_s = jnp.where(jnp.isneginf(lse), 0.0, lse)
        dk, dv = FB.flash_bwd_dkv(qh, kh, vh, do, lse_s, delta, spec, **kw)
        dq = FB.flash_bwd_dq(qh, kh, vh, do, lse_s, delta, spec, **kw)
        return dq, dk, dv

    t = S2 // BLK
    n_vis = len(_visible_pairs(spec, t, t, BLK, BLK)[0])
    one_exp_per_tile = BH * n_vis * BLK * BLK
    fns = {"fused": fused, "split": split}
    census = {name: _census(fn, qh, kh, vh, o, do, lse)
              for name, fn in fns.items()}
    counts = {name: c["transcendentals"] for name, c in census.items()}
    best = interleaved_timeit(
        {name: jax.jit(fn) for name, fn in fns.items()},
        qh, kh, vh, o, do, lse, iters=5,
    )
    for name, c in census.items():
        csv.append(
            f"nonmatmul_bwd/{name},{best[name]*1e6:.0f},"
            f"exp_elems={c['transcendentals']:.3e};exp_per_tile="
            f"{c['transcendentals'] / one_exp_per_tile:.2f};matmul={c['flops']:.3e}"
            f";timing={best.provenance}"
        )
    assert counts["fused"] == one_exp_per_tile, (
        "fused bwd must run exactly one exp per visible tile",
        counts["fused"], one_exp_per_tile,
    )
    assert counts["split"] == 2 * one_exp_per_tile, (
        "split bwd recomputes p twice per visible tile",
        counts["split"], one_exp_per_tile,
    )
