"""Roofline report: render the dry-run JSONs as the section-(g) table.

Reads experiments/dryrun_singlepod.json (the roofline table is single-pod
per the brief) and emits one CSV row per (arch x shape) cell with the three
terms, the dominant bottleneck, useful-FLOPs ratio, and roofline fraction.
"""

from __future__ import annotations

import json
import os
from typing import List

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "..", "experiments", "dryrun_singlepod.json")


def run(csv: List[str]) -> None:
    if not os.path.exists(RESULTS):
        csv.append("roofline/missing,0,run launch.dryrun first")
        return
    with open(RESULTS) as f:
        data = json.load(f)
    for key in sorted(data):
        rec = data[key]
        if rec.get("status") == "skipped":
            csv.append(f"roofline/{key},0,skipped: {rec.get('reason','')[:60]}")
            continue
        if rec.get("status") != "ok":
            csv.append(f"roofline/{key},0,{rec.get('status')}")
            continue
        rl = rec["roofline"]
        step_us = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"]) * 1e6
        csv.append(
            f"roofline/{key},{step_us:.0f},"
            f"tc={rl['t_compute_s']:.3e};tm={rl['t_memory_s']:.3e};"
            f"tcoll={rl['t_collective_s']:.3e};dom={rl['dominant']};"
            f"useful={rl['useful_ratio']:.3f};frac={rl['roofline_fraction']:.4f}"
        )
