"""Benchmark harness: one module per paper table/figure.

  fig4_6_attn_speed   Fig. 4/5/6 -- attention speed, 3 impls x seq len
                      (+ compact-vs-dense Pallas tile-schedule comparison
                      + fused-vs-split backward comparison)
  sched_cmp           the schedule comparison alone (CI fast-tier smoke;
                      not in ALL -- fig4_6_attn_speed already includes it)
  bwd_cmp             the fused-vs-split backward comparison alone (CI
                      fast-tier smoke; not in ALL for the same reason)
  nonmatmul_census    Section 3.1 C1 -- FA1-vs-FA2 non-matmul FLOP census
                      (+ the backward exp census: one exp per visible tile
                      fused, two split -- asserted)
  table1_e2e          Table 1 -- end-to-end GPT training throughput
  roofline            deliverable (g) -- dry-run roofline table
  ring_accounting     context-parallel ring vs all-gather: per-mode comms
                      bytes, peak KV bytes, step/launch counts (static
                      ledger; no timing -- also in the CI fast smoke)
  occupancy_sweep     Fig. 5 analog -- forward partitioning (q-banded /
                      unbanded compact / dense) over a B x H x S grid:
                      grid-utilization ledger (asserted), kernel-layer
                      timing, banded exp census (also in the CI smoke)
  serving_sweep       ISSUE 7 -- paged vs fixed-slot continuous batching at
                      matched HBM on a Poisson mixed-length trace:
                      tokens/sec, p50/p95 per-token latency, utilization,
                      active-cell ledger (paged>fixed ASSERTED)

Prints ``name,us_per_call,derived`` CSV.

    python -m benchmarks.run [--json PATH] [--json-serving PATH]
                             [--prune-stale] [names]

``--json PATH`` additionally writes the rows as machine-readable records
``{"bench", "config", "us_per_call", "derived"}`` (the perf trajectory file
committed as BENCH_attn.json; CI runs a fast-tier smoke of it). An existing
file is MERGED, not clobbered: rows whose (bench, config) the current run
re-measured are replaced, everything else is kept — so the fast CI smoke
(sched_cmp + ring_accounting) never erases the fig4/fig5 trajectory.

``--json-serving PATH`` routes rows of the serving benches (bench name
starting with ``serving``) into their own trajectory file (committed as
BENCH_serving.json) with the same merge/dedupe/backup rules; with it set,
``--json`` receives only the non-serving rows.

Durability rules (the committed trajectory must survive bad runs):

  * a corrupt/truncated/mis-typed existing file never crashes the merge —
    it is backed up to ``PATH.bad`` with a warning and the run continues
    from an empty trajectory (losing the history to a crash in CI was the
    original failure mode);
  * kept + fresh rows are deduped by (bench, config), last write wins;
  * ``--prune-stale`` drops kept rows belonging to a *bench this run
    re-measured* whose (bench, config) was not emitted again — i.e. rows
    stranded by a config rename. Benches that did not run are never pruned.
  * every merged row (kept + fresh) passes a required-key schema check
    (``bench``/``config`` identity plus a units field: numeric
    ``us_per_call`` or non-empty ``derived``); nonconforming rows are
    warned about and tagged ``"schema": "nonconforming: ..."`` instead of
    silently mixing into the committed trajectory.
"""

from __future__ import annotations

import json
import os
import sys
import time

ALL = ("fig4_6_attn_speed", "nonmatmul_census", "table1_e2e", "roofline",
       "ring_accounting", "occupancy_sweep", "autotune_sweep",
       "serving_sweep")


def _records(csv_rows):
    """CSV rows ('bench/config...,us,derived') -> list of dict records."""
    records = []
    for row in csv_rows:
        name, _, rest = row.partition(",")
        us, _, derived = rest.partition(",")
        bench, _, config = name.partition("/")
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        records.append(
            dict(bench=bench, config=config, us_per_call=us_val, derived=derived)
        )
    return records


def _load_existing(json_path: str):
    """Tolerantly load the committed trajectory; never crash the merge.

    A corrupt/truncated file (a killed CI run mid-write) or a wrong-typed
    one is moved aside to ``PATH.bad`` with a warning and treated as empty,
    so one bad write can't take the merge step — and the whole committed
    history — down with it. Rows are deduped by (bench, config), keeping
    the last occurrence (the newest measurement of a key wins).
    """
    if not os.path.exists(json_path):
        return []
    try:
        with open(json_path) as f:
            rows = json.load(f)
        if not isinstance(rows, list) or not all(
            isinstance(r, dict) and "bench" in r and "config" in r for r in rows
        ):
            raise ValueError("trajectory must be a list of bench/config records")
    except (json.JSONDecodeError, ValueError, OSError) as e:
        backup = json_path + ".bad"
        os.replace(json_path, backup)
        print(f"# WARNING: existing {json_path} is invalid ({e}); backed it "
              f"up to {backup} and starting a fresh trajectory", file=sys.stderr)
        return []
    deduped = {}
    for r in rows:
        deduped[(r["bench"], r["config"])] = r
    if len(deduped) != len(rows):
        print(f"# deduped {len(rows) - len(deduped)} duplicate (bench, config) "
              f"rows in {json_path}", file=sys.stderr)
    return list(deduped.values())


# Required ledger-row schema, enforced at merge time: identity keys plus
# the units-bearing fields. Every bench module emits heterogeneous derived
# payloads, but a row missing its identity or carrying NO measurement at
# all (neither a us_per_call number nor a derived string) used to mix
# silently into the committed BENCH_*.json; now it is warned about and
# tagged so downstream readers can filter it.
REQUIRED_ROW_KEYS = ("bench", "config", "us_per_call", "derived")


def _check_schema(rows):
    """Warn-and-tag nonconforming ledger rows (never drop, never crash).

    A conforming row has all of ``REQUIRED_ROW_KEYS``, a non-empty
    ``bench`` name, and at least one units field filled in: a numeric
    ``us_per_call`` or a non-empty ``derived`` payload. Violations get a
    ``"schema": "nonconforming: <reason>"`` tag and a stderr warning.
    """
    bad = 0
    for r in rows:
        reason = None
        missing = [k for k in REQUIRED_ROW_KEYS if k not in r]
        if missing:
            reason = f"missing keys {missing}"
        elif not isinstance(r["bench"], str) or not r["bench"]:
            reason = "empty bench name"
        elif (not isinstance(r["us_per_call"], (int, float))
              and not (isinstance(r.get("derived"), str) and r["derived"])):
            reason = "no units field (neither us_per_call nor derived)"
        if reason is not None:
            r["schema"] = f"nonconforming: {reason}"
            bad += 1
        else:
            r.pop("schema", None)  # row was fixed since it was tagged
    if bad:
        print(f"# WARNING: {bad} ledger rows are nonconforming; tagged with "
              f"a 'schema' field instead of mixing silently", file=sys.stderr)
    return rows


def _merge_trajectory(json_path, records, prune_stale):
    """Merge fresh records into the committed trajectory at json_path.
    All rows (kept + fresh) pass the required-key schema check first."""
    fresh = {(r["bench"], r["config"]) for r in records}
    fresh_benches = {b for b, _ in fresh}
    kept = [r for r in _load_existing(json_path)
            if (r["bench"], r["config"]) not in fresh]
    if prune_stale:
        stale = [r for r in kept if r["bench"] in fresh_benches]
        if stale:
            print(f"# --prune-stale: dropping {len(stale)} stale rows of "
                  f"re-measured benches", file=sys.stderr)
        kept = [r for r in kept if r["bench"] not in fresh_benches]
    records = _check_schema(kept + records)
    with open(json_path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"# wrote {json_path} ({len(records)} rows)", file=sys.stderr)


def main() -> None:
    args = sys.argv[1:]
    json_path = None
    serving_path = None
    prune_stale = "--prune-stale" in args
    if prune_stale:
        args.remove("--prune-stale")
    for flag in ("--json", "--json-serving"):
        if flag in args:
            i = args.index(flag)
            if i + 1 >= len(args):
                sys.exit("usage: python -m benchmarks.run [--json PATH] "
                         "[--json-serving PATH] [--prune-stale] [names]")
            if flag == "--json":
                json_path = args[i + 1]
            else:
                serving_path = args[i + 1]
            args = args[:i] + args[i + 2:]
    names = args or list(ALL)
    csv = ["name,us_per_call,derived"]
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        before = len(csv)
        mod.run(csv)
        dt = time.perf_counter() - t0
        print(f"# {name}: {len(csv) - before} rows in {dt:.1f}s", file=sys.stderr)
    print("\n".join(csv))
    if json_path or serving_path:
        records = _records(csv[1:])
        if serving_path:
            serving = [r for r in records if r["bench"].startswith("serving")]
            records = [r for r in records if not r["bench"].startswith("serving")]
            _merge_trajectory(serving_path, serving, prune_stale)
        if json_path:
            _merge_trajectory(json_path, records, prune_stale)


if __name__ == "__main__":
    main()
