"""Benchmark harness: one module per paper table/figure.

  fig4_6_attn_speed   Fig. 4/5/6 -- attention speed, 3 impls x seq len
  nonmatmul_census    Section 3.1 C1 -- FA1-vs-FA2 non-matmul FLOP census
  table1_e2e          Table 1 -- end-to-end GPT training throughput
  roofline            deliverable (g) -- dry-run roofline table

Prints ``name,us_per_call,derived`` CSV. ``python -m benchmarks.run [names]``
"""

from __future__ import annotations

import sys
import time

ALL = ("fig4_6_attn_speed", "nonmatmul_census", "table1_e2e", "roofline")


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    csv = ["name,us_per_call,derived"]
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        before = len(csv)
        mod.run(csv)
        dt = time.perf_counter() - t0
        print(f"# {name}: {len(csv) - before} rows in {dt:.1f}s", file=sys.stderr)
    print("\n".join(csv))


if __name__ == "__main__":
    main()
