"""Ring-vs-gather context-parallel accounting (DESIGN.md Section 3 table).

No timing (the ring needs a real multi-chip mesh to mean anything): these
rows are the *static* ledger of the two context-parallel modes — bytes each
device sends per attention call, peak resident KV bytes per device, ring
step counts, kernel launches after empty-rectangle skipping, and the
zigzag balance spread. They live in BENCH_attn.json so the perf trajectory
tracks the subsystem; tests/test_ring.py asserts the invariants the numbers
exhibit (balance <= 1 tile, ring peak KV = 2/P of gather).

ISSUE 9 adds the *scaling model*: an analytic per-device-TFLOPS curve vs
ring size at fixed per-device tokens, for the double-buffered schedule
(hop i+1 prefetched under step i's compute: step time = max(compute,
comm)) against the pre-PR single-buffer one (hop on the critical path:
compute + comm). Weak-scaling flatness is the whole point of the ring —
per-device per-step work and hop bytes are both P-independent at fixed
per-device tokens — so the run ASSERTS double >= single at every P and
<= 15% droop across the double-buffered curve (the Megatron-style flat
TFLOPS line, cf. ROADMAP's long-context target).
"""

from __future__ import annotations

from repro.core.masks import MaskSpec
from repro.distributed import ring_schedule as rs

CASES = [
    # (name, S, P, spec, Hkv, D, dtype_bytes)
    ("causal_s8k_p4", 8192, 4, MaskSpec(causal=True), 8, 128, 2),
    ("causal_s64k_p16", 65536, 16, MaskSpec(causal=True), 8, 128, 2),
    ("window_s64k_p16", 65536, 16, MaskSpec(causal=True, window=4096), 8, 128, 2),
]


def run(csv):
    for name, S, P, spec, Hkv, D, db in CASES:
        layout = rs.make_layout(S, P, spec)
        kw = dict(kv_heads=Hkv, head_dim=D, dtype_bytes=db)
        tiles = rs.visible_tile_counts(layout, spec, 512, 512)
        launches = rs.kernel_launch_counts(layout, spec)
        rows = {
            "ring": dict(
                comms_bytes_per_device=rs.comm_bytes_per_device(layout, **kw),
                comms_bytes_per_device_bwd=rs.comm_bytes_per_device(
                    layout, backward=True, **kw
                ),
                peak_kv_bytes_per_device=rs.peak_kv_bytes_per_device(
                    layout, mode="ring", **kw
                ),
                steps=P,
                kernel_launches_per_device_max=int(launches.max()),
                visible_tiles_balance=f"{int(tiles.min())}..{int(tiles.max())}",
            ),
            "gather": dict(
                comms_bytes_per_device=rs.gather_bytes_per_device(layout, **kw),
                peak_kv_bytes_per_device=rs.peak_kv_bytes_per_device(
                    layout, mode="gather", **kw
                ),
                steps=1,
                kernel_launches_per_device_max=1,
                visible_tiles_balance="n/a (one local kernel over full KV)",
            ),
        }
        for mode, r in rows.items():
            derived = " ".join(f"{k}={v}" for k, v in r.items())
            csv.append(f"ring_accounting/{name}/{mode},,{derived}")
    _scaling_rows(csv)


# --- weak-scaling TFLOPS model (double-buffer vs single-buffer) ------------

# Fixed per-device tokens: S = TOKENS_PER_DEVICE * P. Hardware constants
# are the DESIGN.md roofline ones (dense-pod chip: peak bf16 matmul and
# one ICI link's effective bandwidth); the curve's *shape* is what the
# assertions pin, not the absolute numbers.
TOKENS_PER_DEVICE = 4096
RING_SIZES = (2, 4, 8, 16, 32)
SCALING_HQ, SCALING_HKV, SCALING_D = 32, 8, 128
SCALING_DTYPE_BYTES = 2  # bf16 KV on the wire
PEAK_FLOPS = 275e12
ICI_BYTES_PER_S = 90e9
SCALING_BQ = SCALING_BK = 512


def scaling_model(P: int, spec=MaskSpec(causal=True)):
    """Analytic per-device TFLOPS of one ring attention forward at ring
    size P with TOKENS_PER_DEVICE tokens per device.

    Per step t the critical-path compute is the max-over-devices visible
    tile count (the per-step rebalance target) at the 512x512 model tile;
    every step but the last also moves one KV shard to the neighbour.
    double: step = max(compute, comm)  (hop prefetched under compute)
    single: step = compute + comm      (hop serialized after compute)
    Returns dict(tflops_double, tflops_single, steps, compute_ms, comm_ms).
    """
    S = TOKENS_PER_DEVICE * P
    layout = rs.make_layout(S, P, spec)
    per_step = rs.per_step_tile_counts(layout, spec, SCALING_BQ, SCALING_BK)
    tile_flops = 4 * SCALING_BQ * SCALING_BK * SCALING_D * SCALING_HQ
    hop_bytes = 2 * (S // P) * SCALING_HKV * SCALING_D * SCALING_DTYPE_BYTES
    t_hop = hop_bytes / ICI_BYTES_PER_S
    t_steps = [int(row.max()) * tile_flops / PEAK_FLOPS for row in per_step]
    T = len(t_steps)
    t_double = sum(
        max(tc, t_hop if t < T - 1 else 0.0) for t, tc in enumerate(t_steps)
    )
    t_single = sum(
        tc + (t_hop if t < T - 1 else 0.0) for t, tc in enumerate(t_steps)
    )
    # Useful work per device: the balanced share of all visible tiles.
    useful = per_step.sum() / P * tile_flops
    return dict(
        tflops_double=useful / t_double / 1e12,
        tflops_single=useful / t_single / 1e12,
        steps=T,
        compute_ms=sum(t_steps) * 1e3,
        comm_ms=t_hop * (T - 1) * 1e3,
    )


def _scaling_rows(csv):
    curve = {P: scaling_model(P) for P in RING_SIZES}
    for P, m in curve.items():
        assert m["tflops_double"] >= m["tflops_single"], (
            f"P={P}: double-buffered model TFLOPS {m['tflops_double']:.1f} "
            f"below single-buffer {m['tflops_single']:.1f}"
        )
        csv.append(
            f"ring_scaling/causal_n{TOKENS_PER_DEVICE}_p{P},,"
            f"tflops_double={m['tflops_double']:.1f} "
            f"tflops_single={m['tflops_single']:.1f} "
            f"steps={m['steps']} compute_ms={m['compute_ms']:.3f} "
            f"comm_ms={m['comm_ms']:.3f}"
        )
    doubles = [m["tflops_double"] for m in curve.values()]
    droop = 1.0 - min(doubles) / max(doubles)
    assert droop <= 0.15, (
        f"double-buffered weak-scaling curve droops {droop:.1%} > 15% "
        f"across ring sizes {RING_SIZES}: {[f'{d:.1f}' for d in doubles]}"
    )
    csv.append(
        f"ring_scaling/causal_n{TOKENS_PER_DEVICE}_curve,,"
        f"droop={droop:.4f} ring_sizes={'/'.join(map(str, RING_SIZES))} "
        f"tflops_double={'/'.join(f'{d:.1f}' for d in doubles)}"
    )
