"""Ring-vs-gather context-parallel accounting (DESIGN.md Section 3 table).

No timing (the ring needs a real multi-chip mesh to mean anything): these
rows are the *static* ledger of the two context-parallel modes — bytes each
device sends per attention call, peak resident KV bytes per device, ring
step counts, kernel launches after empty-rectangle skipping, and the
zigzag balance spread. They live in BENCH_attn.json so the perf trajectory
tracks the subsystem; tests/test_ring.py asserts the invariants the numbers
exhibit (balance <= 1 tile, ring peak KV = 2/P of gather).
"""

from __future__ import annotations

from repro.core.masks import MaskSpec
from repro.distributed import ring_schedule as rs

CASES = [
    # (name, S, P, spec, Hkv, D, dtype_bytes)
    ("causal_s8k_p4", 8192, 4, MaskSpec(causal=True), 8, 128, 2),
    ("causal_s64k_p16", 65536, 16, MaskSpec(causal=True), 8, 128, 2),
    ("window_s64k_p16", 65536, 16, MaskSpec(causal=True, window=4096), 8, 128, 2),
]


def run(csv):
    for name, S, P, spec, Hkv, D, db in CASES:
        layout = rs.make_layout(S, P, spec)
        kw = dict(kv_heads=Hkv, head_dim=D, dtype_bytes=db)
        tiles = rs.visible_tile_counts(layout, spec, 512, 512)
        launches = rs.kernel_launch_counts(layout, spec)
        rows = {
            "ring": dict(
                comms_bytes_per_device=rs.comm_bytes_per_device(layout, **kw),
                comms_bytes_per_device_bwd=rs.comm_bytes_per_device(
                    layout, backward=True, **kw
                ),
                peak_kv_bytes_per_device=rs.peak_kv_bytes_per_device(
                    layout, mode="ring", **kw
                ),
                steps=P,
                kernel_launches_per_device_max=int(launches.max()),
                visible_tiles_balance=f"{int(tiles.min())}..{int(tiles.max())}",
            ),
            "gather": dict(
                comms_bytes_per_device=rs.gather_bytes_per_device(layout, **kw),
                peak_kv_bytes_per_device=rs.peak_kv_bytes_per_device(
                    layout, mode="gather", **kw
                ),
                steps=1,
                kernel_launches_per_device_max=1,
                visible_tiles_balance="n/a (one local kernel over full KV)",
            ),
        }
        for mode, r in rows.items():
            derived = " ".join(f"{k}={v}" for k, v in r.items())
            csv.append(f"ring_accounting/{name}/{mode},,{derived}")
