"""Compact-vs-dense Pallas tile-schedule comparison, standalone.

The CI fast-tier benchmark smoke: runs ONLY the ``sched_cmp_*`` rows of
fig4_6_attn_speed (a few tens of seconds in interpret mode) instead of the
full seq x impl sweep. ``python -m benchmarks.run --json BENCH_attn.json
sched_cmp``. Not in ``run.ALL`` -- the full fig4_6 module already emits
these rows, so running both would duplicate them.
"""

from __future__ import annotations

from typing import List

from benchmarks.fig4_6_attn_speed import schedule_comparison


def run(csv: List[str]) -> None:
    schedule_comparison(csv)
