"""Tuned-vs-heuristic knob comparison (the ISSUE 6 autotuner payoff rows).

For each committed ``tuned.json`` attention entry on a fast benchmark
shape, resolve the five kernel knobs twice -- once consulting the tuned
cache (``use_tuned=True``, i.e. what a ``PallasFlashConfig`` with all
knobs ``None`` now does) and once heuristics-only (``use_tuned=False``,
the pre-autotuner behavior) -- and time both with the shared interleaved
min-of-N helper:

    tuned_vs_heuristic_fwd/{tuned|heuristic}/causal=C/seq=S/heads=H/hd=D
    tuned_vs_heuristic_fwdbwd/{tuned|heuristic}/...   (one cheap shape)

ASSERTED: tuned must not lose to the heuristic beyond a small noise
tolerance on any swept shape -- the sweep's candidate set always contains
the heuristic's own pick, so losing means the cache is stale (re-run
``python -m repro.kernels.autotune``). When both resolutions pick
identical knobs the pair is timed once and reported twice (identical
configs cannot differ except by noise; report says so).

Shapes with seq > MAX_SEQ are skipped (interpret mode pays Python per
grid step); the skip is logged as a row so the cap is never silent.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_timeit
from repro.core.masks import MaskSpec
from repro.kernels import autotune
from repro.kernels.ops import (
    PallasFlashConfig,
    flash_attention_pallas,
    resolve_pallas_knobs,
)

TOKENS = 4096  # fig4_6 protocol fallback: batch * seq held constant
MAX_SEQ = 512
FWDBWD_SHAPES = {(256, True)}  # (seq, causal) pairs that also time fwd+bwd
NOISE_TOL = 1.10

KNOB_NAMES = ("block_q", "block_kv", "schedule", "bwd", "num_q_bands",
              "kv_splits")


def _fmt(knobs: dict) -> str:
    return ";".join(f"{k}={knobs[k]}" for k in KNOB_NAMES)


def _rows_for(csv: List[str], meta: dict, entry: dict) -> None:
    seq, heads, hd = meta["seq"], meta["heads"], meta["head_dim"]
    causal = meta["causal"]
    # Time at the batch the entry was SWEPT at (provenance field): the
    # cache key deliberately omits batch, so comparing at a different one
    # would judge the tuned knobs on a shape they were never measured for.
    # For the BENCH shapes this equals the fig4_6 TOKENS protocol anyway.
    batch = entry.get("batch") or max(1, TOKENS // seq)
    tag = f"causal={int(causal)}/seq={seq}/heads={heads}/hd={hd}/batch={batch}"
    spec = MaskSpec(causal=causal)
    shape = (batch, seq, heads, hd)
    resolved = {
        mode: resolve_pallas_knobs(
            PallasFlashConfig(spec=spec, use_tuned=(mode == "tuned")),
            shape, shape,
        )
        for mode in ("tuned", "heuristic")
    }
    knobs = {mode: {k: r[k] for k in KNOB_NAMES}
             for mode, r in resolved.items()}
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q, k, v = (jax.random.normal(k_, shape, jnp.float32) for k_ in ks)

    def _fwd(mode):
        kn = dict(knobs[mode])
        kn.pop("bwd")
        return jax.jit(lambda q, k, v: flash_attention_pallas(
            q, k, v, spec, use_tuned=False, **kn
        ))

    def _fwdbwd(mode):
        return jax.jit(jax.grad(lambda q, k, v: flash_attention_pallas(
            q, k, v, spec, use_tuned=False, **knobs[mode]
        ).sum()))

    passes = [("tuned_vs_heuristic_fwd", _fwd)]
    if (seq, causal) in FWDBWD_SHAPES:
        passes.append(("tuned_vs_heuristic_fwdbwd", _fwdbwd))
    for bench, make in passes:
        # identical per-PASS knobs (fwd ignores `bwd`) -> same jitted fn:
        # time it once, report twice (noise cannot separate identical fns)
        relevant = (lambda kn: {k: v for k, v in kn.items() if k != "bwd"}
                    ) if bench.endswith("_fwd") else (lambda kn: kn)
        if relevant(knobs["tuned"]) == relevant(knobs["heuristic"]):
            timed = interleaved_timeit({"both": make("tuned")}, q, k, v,
                                       iters=3)
            best = {"tuned": timed["both"], "heuristic": timed["both"]}
            note = "identical-knobs;"
        else:
            timed = best = interleaved_timeit(
                {mode: make(mode) for mode in ("tuned", "heuristic")},
                q, k, v, iters=3,
            )
            note = ""
        for mode in ("tuned", "heuristic"):
            csv.append(
                f"{bench}/{mode}/{tag},{best[mode]*1e6:.0f},"
                f"{note}{_fmt(knobs[mode])};timing={timed.provenance}"
            )
        assert best["tuned"] <= best["heuristic"] * NOISE_TOL, (
            "tuned knobs lost to the heuristic -- stale tuned.json? "
            "re-run `python -m repro.kernels.autotune`",
            bench, tag, best, knobs,
        )


def run(csv: List[str]) -> None:
    entries = autotune.load_cache()["entries"]
    seen = set()
    for key in sorted(entries):
        meta = autotune.parse_key(key)
        if meta["impl"] != "flash_pallas" or meta["dtype"] != "float32":
            continue
        sig = (meta["causal"], meta["seq"], meta["heads"], meta["head_dim"])
        if sig in seen:
            continue
        seen.add(sig)
        if meta["seq"] > MAX_SEQ:
            csv.append(
                f"tuned_vs_heuristic_skipped/causal={int(meta['causal'])}"
                f"/seq={meta['seq']}/heads={meta['heads']}/hd={meta['head_dim']}"
                f",,seq>{MAX_SEQ}: interpret-mode cost cap (not swept here)"
            )
            continue
        _rows_for(csv, meta, entries[key])
    if not seen:
        csv.append("tuned_vs_heuristic_skipped/none,,empty tuned cache")
