"""Table 1 analogue: end-to-end GPT-style training throughput.

Paper: GPT3-1.3B/2.7B at 2k/8k context on 8xA100 -- without-flash vs
FlashAttention vs FlashAttention-2. CPU adaptation: a GPT-style ~20M model
at two sequence lengths, comparing attention backends
(ref = "without FlashAttention", flash_xla = FA2). The validated claim is
the *relative* speedup growing with context, not absolute TFLOPs/s.

Derived column: tokens/s and model-FLOPs/s via the Megatron formula
(6*N*D + 12*L*h*s^2, as in the paper's Section 4.2).
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_timeit
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.utils import flops as F

GPT_SMALL = ModelConfig(
    name="gpt-bench-20m",
    family="dense",
    num_layers=4,
    d_model=256,
    num_heads=4,
    num_kv_heads=4,
    head_dim=64,
    d_ff=1024,
    vocab_size=8192,
    vocab_pad_to=256,
    dtype="float32",
    scan_layers=True,
    remat=False,
)

SEQS = (512, 2048)
BATCH_TOKENS = 4096


def run(csv: List[str]) -> None:
    params = lm.init_lm(GPT_SMALL, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    n_params, _ = F.param_count(GPT_SMALL)

    for seq in SEQS:
        batch_size = max(1, BATCH_TOKENS // seq)
        batch = {
            "inputs": jnp.zeros((batch_size, seq), jnp.int32),
            "targets": jnp.ones((batch_size, seq), jnp.int32),
        }
        def _step_fn(impl):
            attn_cfg = AttentionConfig(impl=impl, block_q=256, block_kv=256, mode="auto")
            step = jax.jit(
                build_train_step(GPT_SMALL, attn_cfg, AdamWConfig(), ce_chunk=512),
                donate_argnums=(),
            )
            return lambda params, opt, batch: step(params, opt, batch)[2]["loss"]

        # ref and flash_xla rows are compared (the paper's claim is their
        # ratio): interleaved min-of-N so host drift hits both equally
        best = interleaved_timeit(
            {impl: _step_fn(impl) for impl in ("ref", "flash_xla")},
            params, opt, batch, iters=3,
        )
        for impl in ("ref", "flash_xla"):
            t = best[impl]
            toks = batch_size * seq
            mflops = (
                6 * n_params * toks
                + 12 * GPT_SMALL.num_layers * GPT_SMALL.d_model * seq * seq * batch_size
            )
            csv.append(
                f"table1_e2e/{impl}/seq={seq},{t*1e6:.0f},"
                f"tok_per_s={toks/t:.0f};model_gflops_per_s={mflops/t/1e9:.2f}"
                f";timing={best.provenance}"
            )
