"""Serving throughput: paged (block-table) vs fixed-slot continuous batching.

ISSUE 7 tentpole measurement. Both engines get the SAME Poisson arrival
trace of mixed-length requests (many short + one long) and the SAME HBM
budget for KV: the fixed engine spends it on ``max_batch`` worst-case
contiguous slices sized for the *longest* request, the paged engine on a
shared page pool -- so at this fragmented operating point the paged engine
runs ~4x the concurrent requests in the same memory. Rows:

  * ``serving_fixed`` / ``serving_paged``: tokens/sec over the measured
    drive (engines pre-warmed: jit compiles happen in a throwaway pass over
    the same trace, so rows time steady-state serving), p50/p95 per-token
    latency (a token's latency = its decode tick's wall time), mean
    slot/page utilization, tick and preemption counts.
  * ``serving_paged_vs_fixed``: the throughput ratio. ASSERTED > 1: paged
    must beat fixed at matched HBM, or the whole indirection is pointless.
  * ``serving_active_cells``: satellite (a) ledger -- KV cells *touched*
    per generated token. The fixed decode walks every slot's full
    ``cache_size`` whether the slot is live or not; the paged kernel's
    page-level ``pl.when`` skip touches only ``ceil(L/ps)`` live pages per
    live row (empty/finished slots touch ZERO pages). ASSERTED strictly
    smaller per token.

``REPRO_SERVING_SMOKE=1`` shrinks the trace/engines for the CI smoke step
(which also pins the zero-decode-recompile invariant). Records merge into
BENCH_serving.json via ``python -m benchmarks.run --json-serving``.
"""

from __future__ import annotations

import os
import time
from typing import List, Tuple

import jax
import numpy as np

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.models import lm
from repro.serving.engine import PagedServingEngine, Request, ServingEngine

SMOKE = bool(int(os.environ.get("REPRO_SERVING_SMOKE", "0")))

# Matched HBM budget: fixed = BF slots x CACHE tokens; paged = the same
# token count as a pool (+1 null page), spent on more, mostly-short slots.
if SMOKE:
    BF, CACHE, PS, BP = 2, 64, 8, 4
    N_SHORT, SHORT_LEN, SHORT_NEW = 4, (2, 12), 4
    LONG_LEN, LONG_NEW = 30, 8
    RATE = 1.0
else:
    BF, CACHE, PS, BP = 2, 256, 16, 8
    N_SHORT, SHORT_LEN, SHORT_NEW = 12, (4, 24), 16
    LONG_LEN, LONG_NEW = 150, 32
    RATE = 2.0

NUM_PAGES = BF * CACHE // PS + 1
N_MAX = CACHE // PS  # paged per-seq capacity == the fixed slice


def _trace(seed: int) -> List[Tuple[int, dict]]:
    """Poisson arrivals (RATE requests per expected tick), mixed lengths:
    N_SHORT short prompts + ONE long one injected mid-trace -- the
    fragmented point where worst-case slot reservation hurts most."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(N_SHORT):
        L = int(rng.integers(*SHORT_LEN))
        reqs.append(dict(prompt=[int(t) for t in rng.integers(1, 100, L)],
                         max_new_tokens=SHORT_NEW))
    reqs.insert(N_SHORT // 2, dict(
        prompt=[int(t) for t in rng.integers(1, 100, LONG_LEN)],
        max_new_tokens=LONG_NEW))
    tick = 0
    trace = []
    for r in reqs:
        tick += int(rng.poisson(1.0 / RATE))
        trace.append((tick, r))
    return trace


def _drive(engine, trace, base_rid: int):
    """Run one trace to completion; returns per-tick (wall_s, tokens,
    live_cells, capacity_cells) samples. Arrival times are in engine ticks;
    an idle engine fast-forwards to the next arrival."""
    it = iter(trace)
    pending = next(it, None)
    rid = base_rid
    samples = []
    start = engine.ticks  # arrivals are relative: re-driving the trace on a
    # warmed engine replays the exact same admission pattern (same buckets,
    # same widths -> zero new jit traces in the measured pass)
    while True:
        while pending is not None and pending[0] + start <= engine.ticks:
            spec = pending[1]
            engine.submit(Request(rid=rid, prompt=list(spec["prompt"]),
                                  max_new_tokens=spec["max_new_tokens"]))
            rid += 1
            pending = next(it, None)
        idle = not engine.queue and not any(s is not None for s in engine.slots)
        if idle:
            if pending is None:
                break
            # fast-forward: submit the next arrival now
            spec = pending[1]
            engine.submit(Request(rid=rid, prompt=list(spec["prompt"]),
                                  max_new_tokens=spec["max_new_tokens"]))
            rid += 1
            pending = next(it, None)
            continue
        t0 = time.perf_counter()
        engine.tick()
        dt = time.perf_counter() - t0
        # Common engine interface (ISSUE 8 satellite): both engines expose
        # the cells their decode touches (paged: live pages only; fixed:
        # every slot's full slice) -- no isinstance special-casing.
        toks = sum(1 for l in np.asarray(engine.cache_len) if int(l) > 0)
        samples.append((dt, toks, engine.active_kv_cells(), engine.kv_capacity()))
    return samples


def _summarize(samples):
    total_s = sum(s[0] for s in samples)
    toks = sum(s[1] for s in samples)
    per_tok = [s[0] for s in samples for _ in range(s[1])]
    cells_per_tok = sum(s[2] for s in samples) / max(1, toks)
    occupancy = float(np.mean([s[2] / s[3] for s in samples if s[1]]))
    return dict(
        tok_per_s=toks / total_s if total_s else 0.0,
        us_per_tok=total_s / max(1, toks) * 1e6,
        p50_ms=float(np.percentile(per_tok, 50)) * 1e3,
        p95_ms=float(np.percentile(per_tok, 95)) * 1e3,
        ticks=len(samples),
        tokens=toks,
        cells_per_tok=cells_per_tok,
        occupancy=occupancy,
    )


def run(csv: List[str]) -> None:
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    attn = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64,
                           decode_splits=2)
    trace = _trace(seed=7)
    n_req = len(trace)

    fixed = ServingEngine(cfg, params, attn, max_batch=BF, cache_size=CACHE,
                          prompt_pad=16)
    paged = PagedServingEngine(cfg, params, attn, max_batch=BP,
                               num_pages=NUM_PAGES, page_size=PS,
                               pages_per_seq_max=N_MAX, prompt_pad=16)
    # warmup pass: same trace, same shapes -> all jit traces built; the
    # measured pass below times steady-state serving only
    _drive(fixed, trace, base_rid=10_000)
    _drive(paged, trace, base_rid=20_000)
    fx = _summarize(_drive(fixed, trace, base_rid=0))
    pg = _summarize(_drive(paged, trace, base_rid=1_000))

    assert len(fixed.finished) == 2 * n_req and len(paged.finished) == 2 * n_req
    # decode_compiles is now COMMON interface (ISSUE 8): pin both engines
    assert paged.decode_compiles == 1, (
        f"paged decode recompiled: {paged.decode_compiles} traces"
    )
    assert fixed.decode_compiles == 1, (
        f"fixed decode recompiled: {fixed.decode_compiles} traces"
    )
    fx_snap, pg_snap = fixed.snapshot(), paged.snapshot()

    csv.append(
        f"serving_fixed/b{BF}_cache{CACHE},{fx['us_per_tok']:.1f},"
        f"tok_s={fx['tok_per_s']:.1f};p50_ms={fx['p50_ms']:.1f};"
        f"p95_ms={fx['p95_ms']:.1f};ticks={fx['ticks']};tokens={fx['tokens']};"
        f"slot_occupancy={fx['occupancy']:.3f};"
        f"decode_mfu={fx_snap['decode/mfu']:.2e};"
        f"decode_compiles={fixed.decode_compiles}"
    )
    csv.append(
        f"serving_paged/b{BP}_ps{PS}x{NUM_PAGES},{pg['us_per_tok']:.1f},"
        f"tok_s={pg['tok_per_s']:.1f};p50_ms={pg['p50_ms']:.1f};"
        f"p95_ms={pg['p95_ms']:.1f};ticks={pg['ticks']};tokens={pg['tokens']};"
        f"page_occupancy={pg['occupancy']:.3f};"
        f"decode_mfu={pg_snap['decode/mfu']:.2e};"
        f"preemptions={paged.preemptions};decode_compiles={paged.decode_compiles}"
    )

    speedup = pg["tok_per_s"] / fx["tok_per_s"]
    assert speedup > 1.0, (
        f"paged engine must beat fixed at matched HBM on the fragmented "
        f"trace: paged {pg['tok_per_s']:.1f} vs fixed {fx['tok_per_s']:.1f} "
        f"tok/s (x{speedup:.2f})"
    )
    csv.append(
        f"serving_paged_vs_fixed/matched_hbm_{BF * CACHE}tok,,"
        f"speedup=x{speedup:.2f};asserted=paged>fixed"
    )

    saving = pg["cells_per_tok"] / fx["cells_per_tok"]
    assert pg["cells_per_tok"] < fx["cells_per_tok"], (
        f"paged decode must touch fewer KV cells per token "
        f"(paged {pg['cells_per_tok']:.0f} vs fixed {fx['cells_per_tok']:.0f})"
    )
    csv.append(
        f"serving_active_cells/per_token_{BF * CACHE}tok,,"
        f"paged={pg['cells_per_tok']:.0f};fixed={fx['cells_per_tok']:.0f};"
        f"ratio={saving:.3f};asserted=paged<fixed"
    )
