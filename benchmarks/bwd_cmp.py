"""Fused-vs-split Pallas backward comparison, standalone.

The CI fast-tier benchmark smoke: runs ONLY the ``bwd_cmp_*`` rows of
fig4_6_attn_speed (causal seq=2048 kernel-layer fwd+bwd, fused one-pass
vs split 3-launch backward -- fused must win, asserted inside). ``python -m
benchmarks.run --json BENCH_attn.json bwd_cmp``. Not in ``run.ALL`` --
the full fig4_6 module already emits these rows, so running both would
duplicate them.
"""

from __future__ import annotations

from typing import List

from benchmarks.fig4_6_attn_speed import bwd_comparison


def run(csv: List[str]) -> None:
    bwd_comparison(csv)
