"""Shared benchmark timing helper: interleaved min-of-N.

Thin re-export of :mod:`repro.utils.timing` so every benchmark module and
the kernel autotuner use the *same* timing discipline (the library side
cannot import ``benchmarks``; the benchmarks side should not fork the
implementation). See that module's docstring for why min-of-N and why
interleaved -- short version: the old mean-of-3 recorded a forward-only
row slower than forward+backward (a physical impossibility) and had to be
fixed before any timing could be trusted.
"""

from __future__ import annotations

from repro.utils.timing import (
    DEFAULT_ITERS,
    TimingResult,
    interleaved_timeit,
    time_min,
)

__all__ = ["DEFAULT_ITERS", "TimingResult", "interleaved_timeit", "time_min"]
