"""Sharded checkpointing: per-shard, atomic, async, elastic-restorable.

Layout:  ``<dir>/step_<N>/manifest.json`` + one ``.npy`` per *addressable
shard* of each leaf.  ``save()`` snapshots each leaf's local shards
(``arr.addressable_shards``) -- a ring / 2D-mesh run never materializes a
global array on host; the blocking portion of a save is the
device-to-host copy of the local shards only.  The manifest records
``(key, shard_index, index)`` per file so ``restore()`` can reassemble
the global logical array onto the *current* mesh -- same, smaller, or a
single CPU device (the elastic path) -- via a caller-provided
``sharding_fn``.

Durability contract (DESIGN.md Section 10):
  * every ``.npy`` is written + fsync'd inside ``step_<N>.tmp``,
  * the manifest (with a CRC32 per shard file) is written + fsync'd last,
  * the tmp directory is fsync'd, then os.rename'd to ``step_<N>``,
  * the parent directory is fsync'd so the rename itself is durable.
A crash at any point leaves either the previous durable step or a
``.tmp`` that is never picked up.  ``restore()`` verifies checksums and
coverage and walks *down* the step ladder past corrupt / partial steps
instead of crashing (counters ``ckpt/corruptions`` / ``ckpt/fallbacks``).

Async mode snapshots synchronously and writes on a worker thread; the
worker records its *actual* wall write duration (``drain_write_stats``)
so the Young/Daly cadence sees the true write cost, not the snapshot
time.  A failed async write is surfaced immediately (warning +
``ckpt/async_failures`` counter) and re-raised on the next
``save()``/``wait()``.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


class CheckpointCorruption(RuntimeError):
    """A step directory failed validation (torn manifest, bad CRC,
    missing/truncated shard, incomplete coverage)."""


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


# --------------------------------------------------------------------------
# shard index arithmetic: manifest indices are [[start, stop], ...] per dim
# --------------------------------------------------------------------------


def _normalize_index(index: Sequence[slice], shape: Sequence[int]) -> List[List[int]]:
    out = []
    for sl, n in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = int(n) if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _span_shape(bounds: Sequence[Sequence[int]]) -> Tuple[int, ...]:
    return tuple(int(e) - int(s) for s, e in bounds)


def _volume(bounds: Sequence[Sequence[int]]) -> int:
    v = 1
    for s, e in bounds:
        v *= max(0, int(e) - int(s))
    return v


def _fill_region(out: np.ndarray, region: Sequence[Sequence[int]],
                 shard_bounds: Sequence[Sequence[int]], data: np.ndarray) -> None:
    """Copy the intersection of ``shard_bounds`` into ``out`` (which covers
    ``region`` of the global array)."""
    inter = [(max(rs, ss), min(re, se))
             for (rs, re), (ss, se) in zip(region, shard_bounds)]
    if any(e <= s for s, e in inter):
        return
    dst = tuple(slice(s - rs, e - rs) for (s, e), (rs, _) in zip(inter, region))
    src = tuple(slice(s - ss, e - ss) for (s, e), (ss, _) in zip(inter, shard_bounds))
    out[dst] = data[src]


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointStore:
    """See module docstring.  ``registry`` (repro.obs) receives the
    ``ckpt/*`` counters; ``fault_plan`` (training/fault_injection.FaultPlan)
    lets tests/debug runs kill or corrupt writes deterministically."""

    MANIFEST_VERSION = 2

    def __init__(self, directory: str, keep_last: int = 3,
                 registry=None, fault_plan=None):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._write_stats: List[Tuple[int, float]] = []  # (step, seconds)
        self._lock = threading.Lock()
        self.fault_plan = fault_plan
        if registry is None:
            from repro.obs.metrics import default_registry

            registry = default_registry()
        self._c_saves = registry.counter("ckpt/saves")
        self._c_async_fail = registry.counter("ckpt/async_failures")
        self._c_corrupt = registry.counter("ckpt/corruptions")
        self._c_fallback = registry.counter("ckpt/fallbacks")
        self._h_write = registry.histogram(
            "ckpt/write_seconds", (0.01, 0.1, 1.0, 10.0, 60.0))

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, meta: Optional[dict] = None, async_: bool = False):
        """Snapshot local shards now; write synchronously or on a worker.

        The snapshot copies each leaf's *addressable shards* to host --
        never the global logical array -- so on a sharded mesh the
        blocking time is the local-shard device-to-host copy only.
        """
        snapshot = self._snapshot(tree)
        treedef = jax.tree_util.tree_structure(tree)
        if async_:
            self.wait()  # one in-flight save at a time
            self._worker = threading.Thread(
                target=self._write_guarded,
                args=(step, snapshot, str(treedef), meta or {}),
                daemon=True,
            )
            self._worker.start()
        else:
            self._write(step, snapshot, str(treedef), meta or {})

    def _snapshot(self, tree):
        """[(key, global_shape, dtype_str, [(bounds, host_array), ...])].

        Only ``shard.data`` (a single-device local array) is ever copied
        to host; an assert pins that each host block has the local shard
        shape, not the global one (the no-full-array guard the per-shard
        manifest is tested against).
        """
        out = []
        for key, leaf in _flatten(tree):
            if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
                shape = tuple(leaf.shape)
                shards = []
                for shard in leaf.addressable_shards:
                    if getattr(shard, "replica_id", 0) != 0:
                        continue  # replicated copy: one writer per index
                    bounds = _normalize_index(shard.index, shape)
                    host = np.asarray(shard.data)
                    assert host.shape == _span_shape(bounds), (
                        f"shard snapshot of {key!r} materialized {host.shape}, "
                        f"expected local {_span_shape(bounds)}"
                    )
                    shards.append((bounds, host))
                out.append((key, list(shape), str(leaf.dtype), shards))
            else:
                host = np.asarray(leaf)
                bounds = [[0, n] for n in host.shape]
                out.append((key, list(host.shape), str(host.dtype),
                            [(bounds, host)]))
        return out

    def _write_guarded(self, step, snapshot, treedef_str, meta):
        """Async worker body: a failure is surfaced *immediately* (warning
        + ``ckpt/async_failures``) and re-raised on the next
        ``save()``/``wait()`` so the supervisor sees it too."""
        try:
            self._write(step, snapshot, treedef_str, meta)
        except BaseException as e:
            self._error = e
            self._c_async_fail.inc()
            warnings.warn(f"async checkpoint write for step {step} failed: {e!r}")

    def _write(self, step: int, snapshot, treedef_str: str, meta: dict):
        t0 = time.perf_counter()
        plan = self.fault_plan
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "version": self.MANIFEST_VERSION,
            "step": step,
            "time": time.time(),
            "meta": meta,
            "treedef": treedef_str,
            "leaves": [],
        }
        n_files = sum(len(shards) for _, _, _, shards in snapshot)
        written = 0
        for key, shape, dtype, shards in snapshot:
            entry = {"key": key, "shape": shape, "dtype": dtype, "shards": []}
            base = key.replace("/", "__")
            for si, (bounds, host) in enumerate(shards):
                if plan is not None and plan.peek(step, "abort") \
                        and written >= n_files // 2:
                    # deterministic mid-write kill: half the files exist,
                    # the manifest never does -- the .tmp is abandoned.
                    from repro.training.fault_injection import InjectedFault

                    plan.take(step, "abort")
                    raise InjectedFault(f"abort@{step}: checkpoint write killed mid-file")
                fname = f"{base}.s{si:02d}.npy"
                fpath = os.path.join(tmp, fname)
                with open(fpath, "wb") as f:
                    np.save(f, host)
                    f.flush()
                    os.fsync(f.fileno())
                with open(fpath, "rb") as f:
                    crc = 0
                    while True:
                        block = f.read(1 << 20)
                        if not block:
                            break
                        crc = zlib.crc32(block, crc)
                entry["shards"].append({
                    "file": fname, "index": bounds,
                    "crc32": crc, "nbytes": os.path.getsize(fpath),
                })
                written += 1
            manifest["leaves"].append(entry)
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.dir)
        if plan is not None:
            kind = plan.post_write_fault(step)
            if kind is not None:
                from repro.training import fault_injection as FI

                FI.mutilate(final, kind, plan.rng(step))
        self._gc()
        dt = time.perf_counter() - t0
        with self._lock:
            self._write_stats.append((step, dt))
        self._c_saves.inc()
        self._h_write.observe(dt)
        self._trace_write(step, dt)

    def _trace_write(self, step: int, seconds: float) -> None:
        from repro.obs.trace import get_default_recorder

        rec = get_default_recorder()
        if rec is not None:
            rec.name_thread(90, "ckpt writer")
            rec.complete("ckpt_write", 90, rec.now_us() - seconds * 1e6,
                         seconds * 1e6, args={"step": step})

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    def drain_write_stats(self) -> List[Tuple[int, float]]:
        """(step, wall-seconds) of writes completed since the last drain --
        the worker's *actual* write duration, the number Young/Daly needs
        (the blocking ``save()`` call only measures the snapshot)."""
        with self._lock:
            out, self._write_stats = self._write_stats, []
        return out

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int, keys: List[str]) -> Tuple[dict, Dict[str, dict]]:
        """Parse + fully validate one step dir; raises CheckpointCorruption.

        Returns (manifest, {key: {"shape", "dtype", "shards":
        [(bounds, np_array), ...]}}) with every checksum verified and
        every leaf's shards covering the global volume exactly.
        """
        root = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(root, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorruption(f"step {step}: torn manifest ({e})") from e
        if not isinstance(manifest, dict) or "leaves" not in manifest:
            raise CheckpointCorruption(f"step {step}: manifest missing leaves")
        by_key: Dict[str, dict] = {}
        for entry in manifest["leaves"]:
            if "shards" not in entry:  # v1 manifest: one whole-array file
                entry = dict(entry)
                entry["shards"] = [{
                    "file": entry["file"],
                    "index": [[0, n] for n in entry["shape"]],
                    "crc32": None,
                }]
            by_key[entry["key"]] = entry
        missing = [k for k in keys if k not in by_key]
        if missing:
            raise CheckpointCorruption(
                f"step {step}: missing leaves {missing[:5]}...")
        loaded: Dict[str, dict] = {}
        for key in keys:
            entry = by_key[key]
            shape = tuple(entry["shape"])
            shards = []
            covered = 0
            for sh in entry["shards"]:
                fpath = os.path.join(self.dir, f"step_{step:08d}", sh["file"])
                try:
                    with open(fpath, "rb") as f:
                        raw = f.read()
                except OSError as e:
                    raise CheckpointCorruption(
                        f"step {step}: missing shard {sh['file']} ({e})") from e
                if sh.get("crc32") is not None and zlib.crc32(raw) != sh["crc32"]:
                    raise CheckpointCorruption(
                        f"step {step}: CRC mismatch in {sh['file']}")
                try:
                    arr = np.load(io.BytesIO(raw), allow_pickle=False)
                except Exception as e:
                    raise CheckpointCorruption(
                        f"step {step}: unreadable shard {sh['file']} ({e})") from e
                bounds = [[int(s), int(e)] for s, e in sh["index"]]
                if arr.shape != _span_shape(bounds) or str(arr.dtype) != entry["dtype"]:
                    raise CheckpointCorruption(
                        f"step {step}: shard {sh['file']} shape/dtype mismatch")
                if any(s < 0 or e > n for (s, e), n in zip(bounds, shape)):
                    raise CheckpointCorruption(
                        f"step {step}: shard {sh['file']} index out of bounds")
                covered += _volume(bounds)
                shards.append((bounds, arr))
            want = int(np.prod(shape)) if shape else 1
            if covered != want:
                raise CheckpointCorruption(
                    f"step {step}: leaf {key!r} shards cover {covered} of "
                    f"{want} elements")
            loaded[key] = {"shape": shape, "dtype": entry["dtype"], "shards": shards}
        return manifest, loaded

    def _place(self, info: dict, sharding) -> jax.Array:
        """Reassemble one leaf onto the current mesh.

        With a target sharding, only the regions the callback asks for are
        assembled (jax.make_array_from_callback); the full logical array
        is built on host only for the unsharded device_put path.
        """
        shape, dtype, shards = info["shape"], np.dtype(info["dtype"]), info["shards"]

        def region(idx):
            idx = idx if isinstance(idx, tuple) else (idx,)
            bounds = [[0 if sl.start is None else int(sl.start),
                       int(n) if sl.stop is None else int(sl.stop)]
                      for sl, n in zip(idx, shape)]
            out = np.empty(_span_shape(bounds), dtype)
            for sb, data in shards:
                _fill_region(out, bounds, sb, data)
            return out

        if sharding is None:
            return jax.device_put(region(tuple(slice(None) for _ in shape)))
        return jax.make_array_from_callback(tuple(shape), sharding, region)

    def restore(
        self,
        template,
        step: Optional[int] = None,
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> Tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``sharding_fn(key, spec)`` (``spec`` a ShapeDtypeStruct of the
        saved leaf) may return a ``jax.sharding.Sharding`` to place each
        leaf on the *current* mesh -- the elastic path: a checkpoint saved
        per-shard on (2, 4) restores onto (1, 4), or onto one CPU device.

        Walks *down* the step ladder: a corrupt or partial step (torn
        manifest, bad CRC, missing shard) is skipped with a warning and
        the ``ckpt/corruptions`` / ``ckpt/fallbacks`` counters bumped;
        only when no durable step validates does this raise.
        """
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s <= step]
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        keys = [k for k, _ in _flatten(template)]
        first = True
        for s in reversed(candidates):
            try:
                manifest, loaded = self._load_step(s, keys)
            except CheckpointCorruption as e:
                self._c_corrupt.inc()
                self._c_fallback.inc()
                warnings.warn(f"skipping corrupt checkpoint: {e}")
                first = False
                continue
            if not first:
                warnings.warn(
                    f"restored step {s} after falling back past corrupt steps")
            t0 = time.perf_counter()
            leaves = []
            for key in keys:
                info = loaded[key]
                sharding = None
                if sharding_fn is not None:
                    spec = jax.ShapeDtypeStruct(
                        tuple(info["shape"]), np.dtype(info["dtype"]))
                    sharding = sharding_fn(key, spec)
                leaves.append(self._place(info, sharding))
            treedef = jax.tree_util.tree_structure(template)
            self._trace_restore(s, time.perf_counter() - t0)
            return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
        raise FileNotFoundError(
            f"no *valid* checkpoint in {self.dir}: all of steps "
            f"{candidates} failed validation")

    def _trace_restore(self, step: int, seconds: float) -> None:
        from repro.obs.trace import get_default_recorder

        rec = get_default_recorder()
        if rec is not None:
            rec.name_thread(90, "ckpt writer")
            rec.complete("ckpt_restore", 90, rec.now_us() - seconds * 1e6,
                         seconds * 1e6, args={"step": step})
