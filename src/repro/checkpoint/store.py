"""Sharded checkpointing: atomic, async, elastic-restorable.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (path-keyed).
Arrays are stored as *global* logical arrays (device shards gathered), so a
checkpoint written on mesh (pod,data,model)=(2,16,16) restores onto
(16,16) -- or onto 1 CPU device -- by re-device_put'ing with the target
sharding: that is the elastic-rescale path (lose a pod, shrink, resume).

Durability: writes go to ``step_<N>.tmp`` and are os.rename'd only after
fsync -- a crash mid-save never corrupts the latest durable step. An async
mode snapshots (device_get) synchronously and writes on a worker thread so
training only blocks for the copy, not the IO (the brief's overlap trick).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


class CheckpointStore:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, meta: Optional[dict] = None, async_: bool = False):
        """Snapshot now; write synchronously or on a background thread."""
        snapshot = [(k, np.asarray(jax.device_get(v))) for k, v in _flatten(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        if async_:
            self.wait()  # one in-flight save at a time
            self._worker = threading.Thread(
                target=self._write, args=(step, snapshot, str(treedef), meta or {}),
                daemon=True,
            )
            self._worker.start()
        else:
            self._write(step, snapshot, str(treedef), meta or {})

    def _write(self, step: int, snapshot, treedef_str: str, meta: dict):
        try:
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {
                "step": step,
                "time": time.time(),
                "meta": meta,
                "treedef": treedef_str,
                "leaves": [],
            }
            for key, arr in snapshot:
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
                )
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e
            raise

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template,
        step: Optional[int] = None,
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> Tuple[Any, dict]:
        """Restore into the structure of ``template``. ``sharding_fn(key,
        array)`` may return a jax.sharding.Sharding to place each leaf on the
        *current* mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        by_key: Dict[str, dict] = {l["key"]: l for l in manifest["leaves"]}
        keys = [k for k, _ in _flatten(template)]
        missing = [k for k in keys if k not in by_key]
        if missing:
            raise KeyError(f"checkpoint {step} missing leaves: {missing[:5]}...")
        leaves = []
        for key, tmpl_leaf in _flatten(template):
            arr = np.load(os.path.join(root, by_key[key]["file"]))
            if sharding_fn is not None:
                sh = sharding_fn(key, arr)
                leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
            else:
                leaves.append(jax.device_put(arr))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["meta"]
