"""Deterministic fault injection for the train loop and checkpoint store.

A :class:`FaultPlan` is a seeded, step-keyed list of fault events.  The
same plan string always produces the same faults at the same steps, so a
kill-and-resume test (or a ``--fault-plan`` debug run) is exactly
reproducible.  Each event fires **once** per process -- a supervisor
restart that replays the faulting step does not re-fire it, so recovery
can actually be observed.

Fault taxonomy (DESIGN.md Section 10):

  step faults (fired by the train loop via :meth:`FaultPlan.fire_step`):
    * ``raise``    -- raise :class:`InjectedFault` inside the step fn
                      (a node failure; the supervisor restores + replays)
    * ``sigterm``  -- deliver SIGTERM to this process (the preemption
                      notice; exercises the grace drain-and-save path)
    * ``sigkill``  -- deliver SIGKILL (the hard preemption; only an
                      external relaunch recovers)

  write faults (consulted by CheckpointStore during ``_write``):
    * ``abort``    -- kill the checkpoint write mid-file: half the shard
                      files exist, the manifest never does, the ``.tmp``
                      is abandoned (exercises async-failure surfacing)

  disk faults (applied to the *durable* ``step_<N>`` dir after rename --
  the states a lying disk / power cut / bitrot leave behind):
    * ``torn``     -- truncate ``manifest.json`` mid-file
    * ``trunc``    -- truncate one shard ``.npy`` file
    * ``drop``     -- delete one shard file (missing leaf)
    * ``corrupt``  -- flip bytes inside one shard (CRC mismatch)

Plan grammar (the ``train.py --fault-plan`` flag)::

    "<kind>@<step>[,<kind>@<step>...]"     e.g.  "raise@5,corrupt@8"
"""

from __future__ import annotations

import dataclasses
import os
import signal
from typing import Dict, List, Optional, Tuple

import numpy as np

STEP_KINDS = ("raise", "sigterm", "sigkill")
WRITE_KINDS = ("abort",)
DISK_KINDS = ("torn", "trunc", "drop", "corrupt")
ALL_KINDS = STEP_KINDS + WRITE_KINDS + DISK_KINDS


class InjectedFault(RuntimeError):
    """A fault raised by the harness (never by real code paths)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str


class FaultPlan:
    """A deterministic (seeded, step-keyed) set of fault events."""

    def __init__(self, events: List[FaultEvent], seed: int = 0):
        for ev in events:
            if ev.kind not in ALL_KINDS:
                raise ValueError(f"unknown fault kind {ev.kind!r}; want {ALL_KINDS}")
        self.events = sorted(events, key=lambda e: (e.step, e.kind))
        self.seed = seed
        self._fired: set = set()

    # ------------------------------------------------------ construction
    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """``"raise@5,corrupt@8"`` -> FaultPlan; empty string -> no faults."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            try:
                kind, at = part.split("@")
                events.append(FaultEvent(int(at), kind.strip()))
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {part!r}; want kind@step") from e
        return cls(events, seed=seed)

    @classmethod
    def random(cls, seed: int, total_steps: int, rate: float = 0.05,
               kinds: Tuple[str, ...] = ("raise", "corrupt", "trunc")) -> "FaultPlan":
        """A seeded random plan: each step draws a fault with prob ``rate``.

        Purely a function of (seed, total_steps, rate, kinds) -- two
        processes with the same arguments build the same plan.
        """
        rng = np.random.default_rng(seed)
        events = []
        for step in range(1, total_steps):
            if rng.random() < rate:
                events.append(FaultEvent(step, kinds[int(rng.integers(len(kinds)))]))
        return cls(events, seed=seed)

    def __repr__(self):
        return ("FaultPlan(" +
                ",".join(f"{e.kind}@{e.step}" for e in self.events) +
                f"; seed={self.seed})")

    # ----------------------------------------------------------- firing
    def rng(self, step: int) -> np.random.Generator:
        """The per-step RNG (picks *which* file a disk fault mutilates)."""
        return np.random.default_rng((self.seed, step))

    def peek(self, step: int, *kinds: str) -> Optional[str]:
        """First un-fired event at ``step`` among ``kinds`` (or any)."""
        for ev in self.events:
            if ev.step == step and (not kinds or ev.kind in kinds) \
                    and ev not in self._fired:
                return ev.kind
        return None

    def take(self, step: int, kind: str) -> None:
        self._fired.add(FaultEvent(step, kind))

    def fire_step(self, step: int) -> None:
        """Called by the train loop at the top of each step."""
        kind = self.peek(step, *STEP_KINDS)
        if kind is None:
            return
        self.take(step, kind)
        if kind == "raise":
            raise InjectedFault(f"raise@{step}: injected step failure")
        if kind == "sigterm":
            os.kill(os.getpid(), signal.SIGTERM)
        elif kind == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)

    def write_fault(self, step: int) -> Optional[str]:
        return self.peek(step, *WRITE_KINDS)

    def post_write_fault(self, step: int) -> Optional[str]:
        kind = self.peek(step, *DISK_KINDS)
        if kind is not None:
            self.take(step, kind)
        return kind


# ---------------------------------------------------------------------------
# Disk-state mutilation: applied to a durable step_<N> directory.  Used by
# the store's post-write hook and directly by tests (corrupt an already
# durable checkpoint).
# ---------------------------------------------------------------------------


def mutilate(step_dir: str, kind: str, rng: np.random.Generator) -> str:
    """Apply one disk fault ``kind`` to ``step_dir``; returns the victim
    file name (deterministic in ``rng``)."""
    if kind not in DISK_KINDS:
        raise ValueError(f"unknown disk fault {kind!r}; want {DISK_KINDS}")
    if kind == "torn":
        victim = os.path.join(step_dir, "manifest.json")
        size = os.path.getsize(victim)
        with open(victim, "r+b") as f:
            f.truncate(max(1, size // 2))
        return "manifest.json"
    shards = sorted(n for n in os.listdir(step_dir) if n.endswith(".npy"))
    if not shards:
        raise ValueError(f"{step_dir} has no shard files to mutilate")
    victim = shards[int(rng.integers(len(shards)))]
    path = os.path.join(step_dir, victim)
    size = os.path.getsize(path)
    if kind == "trunc":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif kind == "drop":
        os.remove(path)
    elif kind == "corrupt":
        with open(path, "r+b") as f:
            f.seek(size // 2)
            block = bytearray(f.read(8))
            f.seek(size // 2)
            f.write(bytes(b ^ 0xFF for b in block) or b"\xff")
    return victim
