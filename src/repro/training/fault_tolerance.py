"""Fault tolerance & straggler machinery for thousand-node runs.

Pieces (each unit-tested; wired together in launch/train.py):
  * StepMonitor -- EWMA/median step-time tracking; flags straggler steps
    (> threshold x rolling median). At fleet scale the same statistic
    per-host identifies slow hosts for eviction; here it feeds telemetry
    and the checkpoint cadence.
  * CheckpointCadence -- Young/Daly optimal interval sqrt(2 * MTBF * C)
    from the observed write cost C and configured/observed MTBF.
  * run_with_restarts -- supervisor loop: run step fn, on failure restore
    the last durable checkpoint and replay. Exercised in tests with fault
    injection (it is the same control flow a pod-failure restart takes).
  * NaN/overflow step-skip lives in optimizer.apply_updates(skip_update=...)
    -- a poisoned gradient never reaches the master weights.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StepMonitor:
    def __init__(self, window: int = 50, straggler_factor: float = 2.0):
        self.window = window
        self.factor = straggler_factor
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self.step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        self.step += 1
        med = statistics.median(self.times)
        if len(self.times) >= 5 and dt > self.factor * med:
            ev = StragglerEvent(self.step, dt, med)
            self.events.append(ev)
            return ev
        return None

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class CheckpointCadence:
    """Young/Daly: checkpoint every sqrt(2 * MTBF * write_cost) seconds."""

    def __init__(self, mtbf_seconds: float, min_interval_steps: int = 10):
        self.mtbf = mtbf_seconds
        self.min_steps = min_interval_steps
        self.write_cost = 1.0  # updated from observed saves
        self._last_ckpt_time = time.monotonic()

    def observe_write(self, seconds: float):
        self.write_cost = 0.5 * self.write_cost + 0.5 * max(seconds, 1e-3)

    @property
    def interval_seconds(self) -> float:
        return math.sqrt(2.0 * self.mtbf * self.write_cost)

    def should_checkpoint(self, step: int, step_time: float) -> bool:
        if step % self.min_steps == 0:
            return True
        return (time.monotonic() - self._last_ckpt_time) >= self.interval_seconds

    def mark(self):
        self._last_ckpt_time = time.monotonic()


def run_with_restarts(
    step_fn: Callable[[int, object], object],
    restore_fn: Callable[[], tuple],
    save_fn: Callable[[int, object], None],
    *,
    total_steps: int,
    checkpoint_every: int,
    max_restarts: int = 3,
):
    """Supervisor: drive step_fn with checkpoint/restart on failure.

    restore_fn() -> (start_step, state); step_fn(step, state) -> state;
    save_fn(step, state). Returns (final_state, n_restarts, telemetry).
    """
    restarts = 0
    monitor = StepMonitor()
    start_step, state = restore_fn()
    step = start_step
    while step < total_steps:
        try:
            monitor.start()
            state = step_fn(step, state)
            monitor.stop()
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                save_fn(step, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            start_step, state = restore_fn()
            step = start_step
    return state, restarts, {"stragglers": monitor.events, "median_step": monitor.median}
