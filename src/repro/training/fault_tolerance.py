"""Fault tolerance & straggler machinery for thousand-node runs.

Pieces (each unit-tested; wired together in launch/train.py):
  * StepMonitor -- EWMA/median step-time tracking; flags straggler steps
    (> threshold x rolling median). At fleet scale the same statistic
    per-host identifies slow hosts for eviction; here it feeds telemetry
    and the checkpoint cadence.
  * CheckpointCadence -- Young/Daly optimal interval sqrt(2 * MTBF * C)
    from the observed write cost C and configured/observed MTBF.
  * run_with_restarts -- supervisor loop: run step fn, on failure restore
    the last durable checkpoint and replay. Exercised in tests with fault
    injection (it is the same control flow a pod-failure restart takes).
  * NaN/overflow step-skip lives in optimizer.apply_updates(skip_update=...)
    -- a poisoned gradient never reaches the master weights.
"""

from __future__ import annotations

import dataclasses
import math
import statistics
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StepMonitor:
    def __init__(self, window: int = 50, straggler_factor: float = 2.0):
        self.window = window
        self.factor = straggler_factor
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self.step = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        self.step += 1
        med = statistics.median(self.times)
        if len(self.times) >= 5 and dt > self.factor * med:
            ev = StragglerEvent(self.step, dt, med)
            self.events.append(ev)
            return ev
        return None

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


class CheckpointCadence:
    """Young/Daly: checkpoint every sqrt(2 * MTBF * write_cost) seconds.

    ``min_interval_steps`` is a *floor* on spacing (never checkpoint
    sooner than this many steps after the last one -- ``--ckpt-every`` in
    launch/train.py); above the floor the Young/Daly interval governs.
    The historical semantics (``step % min_steps == 0``) made the flag a
    maximum interval acting under a minimum's name and ignored
    ``step_time``; now ``step_time`` participates: we checkpoint at the
    step *boundary closest to* the optimal interval -- if the next
    opportunity (one step away) would overshoot the optimum by more than
    we currently undershoot it, checkpoint now instead of mid-burst.

    ``write_cost`` must be fed the worker's *actual* wall write duration
    (CheckpointStore.drain_write_stats), not the blocking snapshot time.
    """

    def __init__(self, mtbf_seconds: float, min_interval_steps: int = 10):
        self.mtbf = mtbf_seconds
        self.min_steps = max(1, min_interval_steps)
        self.write_cost: Optional[float] = None  # unknown until observed
        self._last_ckpt_time = time.monotonic()
        self._last_ckpt_step = 0

    def observe_write(self, seconds: float):
        s = max(seconds, 1e-3)
        self.write_cost = s if self.write_cost is None \
            else 0.5 * self.write_cost + 0.5 * s

    @property
    def interval_seconds(self) -> float:
        return math.sqrt(2.0 * self.mtbf * (self.write_cost or 1.0))

    def should_checkpoint(self, step: int, step_time: float = 0.0) -> bool:
        if step - self._last_ckpt_step < self.min_steps:
            return False  # the floor: ckpt_every is a minimum spacing
        elapsed = time.monotonic() - self._last_ckpt_time
        # Nearest-boundary rule: now is `interval - elapsed` early; the
        # next chance is `elapsed + step_time - interval` late.
        return elapsed + 0.5 * max(step_time, 0.0) >= self.interval_seconds

    def mark(self, step: Optional[int] = None):
        self._last_ckpt_time = time.monotonic()
        if step is not None:
            self._last_ckpt_step = step


def run_with_restarts(
    step_fn: Callable[[int, object], object],
    restore_fn: Callable[[], tuple],
    save_fn: Callable[[int, object], None],
    *,
    total_steps: int,
    checkpoint_every: Optional[int] = None,
    cadence: Optional[CheckpointCadence] = None,
    max_restarts: int = 3,
    should_stop: Optional[Callable[[], bool]] = None,
    registry=None,
):
    """Supervisor: drive step_fn with checkpoint/restart on failure.

    ``restore_fn() -> (start_step, state)`` -- called at start AND after
    every failure; it owns the whole incarnation setup (re-form the mesh,
    re-jit the step, reload the durable checkpoint, reseat the data
    stream).  ``step_fn(step, state) -> state``; ``save_fn(step, state)``.

    Checkpoint policy: ``cadence`` (Young/Daly, step-time aware) if
    given, else fixed ``checkpoint_every`` steps; the final step always
    saves.  ``should_stop()`` checked between steps is the preemption
    notice -- on True the loop saves and returns early (telemetry
    ``preempted=True``); the caller drains the async writer.

    Returns (final_state, n_restarts, telemetry).  Restarts are counted
    into ``registry`` (repro.obs) as ``train/restarts`` when provided.
    """
    if (checkpoint_every is None) == (cadence is None):
        raise ValueError("pass exactly one of checkpoint_every / cadence")
    restarts = 0
    c_restarts = registry.counter("train/restarts") if registry else None
    monitor = StepMonitor()
    start_step, state = restore_fn()
    step = start_step
    preempted = False
    while step < total_steps:
        if should_stop is not None and should_stop():
            preempted = True
            save_fn(step, state)
            break
        try:
            monitor.start()
            state = step_fn(step, state)
            monitor.stop()
            step += 1
            if cadence is not None:
                want = cadence.should_checkpoint(step, monitor.times[-1])
            else:
                want = step % checkpoint_every == 0
            if want or step == total_steps:
                save_fn(step, state)
                if cadence is not None:
                    cadence.mark(step)
        except Exception:
            restarts += 1
            if c_restarts is not None:
                c_restarts.inc()
            if restarts > max_restarts:
                raise
            start_step, state = restore_fn()
            step = start_step
    return state, restarts, {
        "stragglers": monitor.events,
        "median_step": monitor.median,
        "preempted": preempted,
        "last_step": step,
    }
