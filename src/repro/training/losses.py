"""Chunked softmax cross-entropy over a vocab-sharded unembedding.

Materializing (B, S, V) logits for V=262k (gemma3) at 1M tokens/step is
~0.5 TB -- the classic memory wall. We scan over sequence chunks: each chunk
computes (B, chunk, V)-sharded logits, its loss contribution, and is freed
(remat'ed in the backward). This bounds live logits memory by a factor
S/chunk and is one of the beyond-paper memory optimizations recorded in
EXPERIMENTS.md Section Perf.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import unembed


def _chunk_ce(params_embed, tie, hidden_c, targets_c, mask_c, vocab_valid):
    logits = unembed(params_embed, hidden_c, tie).astype(jnp.float32)
    logits = constrain(logits, "batch", None, "vocab")
    # mask padded vocab entries out of the logsumexp
    V = logits.shape[-1]
    if vocab_valid < V:
        pad_mask = jnp.arange(V) < vocab_valid
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets_c[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask_c
    correct = (jnp.argmax(logits, -1) == targets_c) * mask_c
    return jnp.sum(nll), jnp.sum(correct)


def chunked_cross_entropy(
    params_embed: dict,
    tie: bool,
    hidden: jnp.ndarray,  # (B, S, d)
    targets: jnp.ndarray,  # (B, S) int32 (padded-vocab ids never appear)
    *,
    vocab_valid: int,
    mask: Optional[jnp.ndarray] = None,  # (B, S) 1.0 = count this position
    chunk: int = 512,
) -> Tuple[jnp.ndarray, dict]:
    B, S, d = hidden.shape
    # context-parallel archs arrive seq-sharded; the loss chunks over seq, so
    # reshard to batch-only here (one all-to-all) before the chunk scan.
    hidden = constrain(hidden, "batch", None, "embed")
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n = S // chunk if S % chunk == 0 and S > chunk else 1
    c = S // n

    def body(carry, xs):
        nll_acc, correct_acc = carry
        h_c, t_c, m_c = xs
        nll, correct = _chunk_ce(params_embed, tie, h_c, t_c, m_c, vocab_valid)
        return (nll_acc + nll, correct_acc + correct), None

    split = lambda t: t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
    body_fn = jax.checkpoint(body, prevent_cse=False)
    (nll, correct), _ = jax.lax.scan(
        body_fn,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (split(hidden), split(targets), split(mask)),
    )
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll / denom
    return loss, {"nll_sum": nll, "tokens": denom, "accuracy": correct / denom}
