"""AdamW with fp32 master weights and sharded moments (pure JAX).

Mixed-precision contract (DESIGN.md Section 8): model params are compute-
dtype (bf16 on TPU); the optimizer keeps fp32 master copies + moments. The
gradient all-reduce happens in compute dtype (bf16 -- 2x less pod-link
traffic, the "gradient compression" the brief asks for) and is accumulated
into fp32 masters here. Every optimizer-state leaf inherits the parameter's
sharding (handed out by distributed/sharding rules), so with FSDP rules the
optimizer state is fully sharded (ZeRO-3-equivalent).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    master: Any  # fp32 master params
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return OptState(jnp.zeros((), jnp.int32), f32(params), zeros(params), zeros(params))


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _no_decay(path: str) -> bool:
    p = path.lower()
    return any(t in p for t in ("norm", "bias", "scale", "a_log", "dt_bias", "meta", "'d'"))


def apply_updates(
    cfg: AdamWConfig, state: OptState, grads, param_dtype=jnp.bfloat16,
    skip_update: Optional[jnp.ndarray] = None,
) -> Tuple[Any, OptState, dict]:
    """grads in compute dtype -> (new_params (compute dtype), new_state, metrics).

    skip_update: optional () bool -- when True (e.g. non-finite grads, see
    fault_tolerance.py), the step is a no-op except for the step counter.
    """
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    if skip_update is None:
        skip = ~finite
    else:
        skip = skip_update | ~finite
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    step1 = state.step + 1
    bc1 = 1 - b1 ** step1.astype(jnp.float32)
    bc2 = 1 - b2 ** step1.astype(jnp.float32)

    paths_grads = jax.tree_util.tree_flatten_with_path(grads)
    paths = ["/".join(str(k) for k in path) for path, _ in paths_grads[0]]
    flat_g = [g for _, g in paths_grads[0]]
    flat_m, tdef = jax.tree_util.tree_flatten(state.master)
    flat_mu = jax.tree_util.tree_flatten(state.mu)[0]
    flat_nu = jax.tree_util.tree_flatten(state.nu)[0]

    new_m, new_mu, new_nu, new_p = [], [], [], []
    for path, g, m, mu, nu in zip(paths, flat_g, flat_m, flat_mu, flat_nu):
        gf = g.astype(jnp.float32) * clip
        gf = jnp.where(skip, 0.0, gf)
        mu2 = b1 * mu + (1 - b1) * gf
        nu2 = b2 * nu + (1 - b2) * gf * gf
        upd = (mu2 / bc1) / (jnp.sqrt(nu2 / bc2) + cfg.eps)
        if cfg.weight_decay and not _no_decay(path):
            upd = upd + cfg.weight_decay * m
        m2 = m - lr * jnp.where(skip, 0.0, upd)
        mu2 = jnp.where(skip, mu, mu2)
        nu2 = jnp.where(skip, nu, nu2)
        new_m.append(m2)
        new_mu.append(mu2)
        new_nu.append(nu2)
        new_p.append(m2.astype(param_dtype))

    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    new_state = OptState(step1, unf(new_m), unf(new_mu), unf(new_nu))
    metrics = {"grad_norm": gnorm, "lr": lr, "skipped": skip.astype(jnp.float32)}
    return unf(new_p), new_state, metrics
