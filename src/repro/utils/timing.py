"""Interleaved min-of-N wall-clock timing.

The repo's single timing discipline, shared by the benchmark harness
(``benchmarks/timing.py`` re-exports this module) and the kernel autotuner
(``repro.kernels.autotune``). Two rules, both load-bearing:

  * **min, not mean.** On a shared host every timing sample is the true
    cost plus non-negative noise (scheduler preemption, page faults, GC,
    turbo transitions). The minimum over N samples is the best estimator
    of the true cost; the mean is biased upward by exactly the noise we
    want to exclude. The original ``fig4_6_attn_speed._time`` used a
    mean-of-3 and recorded a forward-only row *slower* than the matching
    forward+backward row (BENCH_attn.json, ``ref/causal=0/seq=512``:
    438ms fwd vs 356ms fwd+bwd) -- a physical impossibility that made the
    whole trajectory untrustworthy and blocked the autotuner.
  * **interleave competitors.** When two timings will be *compared*
    (fwd vs fwd+bwd, tuned vs heuristic, fused vs split), round-robin the
    candidates inside each iteration instead of timing them back-to-back
    in blocks. Slow drift (thermal, co-tenant load) then hits every
    candidate equally instead of biasing whichever ran during the bad
    window.

``jax.block_until_ready`` is applied to every call so asynchronous
dispatch never lets a timing stop before the work does.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Mapping

import jax

__all__ = ["TimingResult", "interleaved_timeit", "time_min"]

DEFAULT_ITERS = 5


class TimingResult(Dict[str, float]):
    """``{name: best_seconds}`` plus the discipline that produced it.

    ``iters`` (timed rounds per competitor) and ``warmup`` (untimed
    calls) ride along so BENCH ledger rows can be self-describing about
    their timing provenance -- ``provenance`` renders the canonical
    ``min_of_{iters}w{warmup}`` tag the benchmark modules append to their
    ``derived`` column. Plain-dict semantics are unchanged (drop-in for
    every existing caller).
    """

    def __init__(self, best: Dict[str, float], iters: int, warmup: int):
        super().__init__(best)
        self.iters = iters
        self.warmup = warmup

    @property
    def provenance(self) -> str:
        return f"min_of_{self.iters}w{self.warmup}"


def interleaved_timeit(
    fns: Mapping[str, Callable],
    *args,
    iters: int = DEFAULT_ITERS,
    warmup: int = 1,
) -> TimingResult:
    """Time competing callables interleaved; return best seconds per name.

    Every callable is invoked as ``fn(*args)``; ``warmup`` untimed calls
    each (compilation + first-touch) precede ``iters`` timed rounds. In
    each round the callables run round-robin in insertion order, and each
    keeps the minimum of its per-round samples. The returned mapping is a
    :class:`TimingResult`: a plain dict of best seconds that also carries
    the (iters, warmup) provenance for self-describing ledger rows.
    """
    iters, warmup = max(1, iters), max(1, warmup)
    items = list(fns.items())
    if not items:
        return TimingResult({}, iters, warmup)
    for _, fn in items:
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = {name: float("inf") for name, _ in items}
    for _ in range(iters):
        for name, fn in items:
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.perf_counter() - t0)
    return TimingResult(best, iters, warmup)


def time_min(fn: Callable, *args, iters: int = DEFAULT_ITERS, warmup: int = 1) -> float:
    """Min-of-N timing of a single callable (degenerate interleave)."""
    return interleaved_timeit({"fn": fn}, *args, iters=iters, warmup=warmup)["fn"]
