"""Recompute the roofline block of stored dry-run JSONs from their raw
terms (flops / hbm_bytes / coll_bytes / chips / model_flops).

Used after any change to utils.hlo_analysis.Roofline so the stored
experiments stay consistent with the code without re-lowering 80 cells.

Usage: python -m repro.utils.recompute_roofline experiments/dryrun_*.json
"""

from __future__ import annotations

import json
import sys

from repro.utils.hlo_analysis import Roofline


def recompute(path: str) -> int:
    with open(path) as f:
        data = json.load(f)
    n = 0
    for rec in data.values():
        rl = rec.get("roofline")
        if not rl:
            continue
        new = Roofline(
            flops=rl["flops"],
            hbm_bytes=rl["hbm_bytes"],
            coll_bytes=rl["coll_bytes"],
            chips=rl["chips"],
            model_flops=rl.get("model_flops"),
        )
        rec["roofline"] = new.to_dict()
        n += 1
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
    return n


if __name__ == "__main__":
    for p in sys.argv[1:]:
        print(f"{p}: recomputed {recompute(p)} roofline blocks")
