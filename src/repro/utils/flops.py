"""Analytic model-FLOPs formulas (the paper's Section 4 accounting).

MODEL_FLOPS for training = 6*N*D tokens (dense) or 6*N_active*D (MoE),
plus 12*L*H*S^2-style attention FLOPs (the paper's Megatron formula,
causal halving NOT applied, "for consistency with the literature").
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def param_count(cfg: ModelConfig) -> Tuple[int, int]:
    """(total_params, active_params_per_token) -- analytic, from config."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    kinds = cfg.layer_kinds()
    total = active = V * d  # embed
    if not cfg.tie_embeddings:
        total += V * d
        active += V * d
    for kind in kinds:
        layer_t = layer_a = 0
        if kind.startswith("attn") or kind.startswith("hybrid"):
            attn = d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
            layer_t += attn
            layer_a += attn
        if kind in ("mamba", "hybrid", "hybrid_global") and cfg.ssm:
            s = cfg.ssm
            din = s.expand * d
            dtr = s.dt_rank or (d + 15) // 16
            ssm = (
                d * 2 * din + s.d_conv * din + din * (dtr + 2 * s.d_state)
                + dtr * din + din * s.d_state + din * d
            )
            layer_t += ssm
            layer_a += ssm
        if kind != "mamba":
            if cfg.moe:
                m = cfg.moe
                ffn1 = 3 * d * m.d_expert
                layer_t += m.num_experts * ffn1 + d * m.num_experts
                layer_a += m.top_k * ffn1
            elif cfg.d_ff:
                ffn = (3 if cfg.mlp == "swiglu" else 2) * d * cfg.d_ff
                layer_t += ffn
                layer_a += ffn
        total += layer_t
        active += layer_a
    if cfg.encoder:  # whisper encoder
        enc = cfg.encoder.num_layers * (4 * d * d + 2 * d * cfg.d_ff)
        total += enc
        active += enc
        # decoder cross-attention
        total += cfg.num_layers * 4 * d * d
        active += cfg.num_layers * 4 * d * d
    return total, active


def train_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D + attention term, per training step (paper Sec. 4.2)."""
    tokens = shape.global_batch * shape.seq_len
    _, active = param_count(cfg)
    flops = 6.0 * active * tokens
    # attention: 12 * L_attn * d_attn * S^2 per sequence (fwd 4 + bwd 8)
    s_full = shape.seq_len
    for kind in cfg.layer_kinds():
        if kind.startswith("attn") or kind.startswith("hybrid"):
            w = cfg.kind_window(kind)
            s_eff = min(w, s_full) if w else s_full
            flops += 12.0 * cfg.q_dim * s_eff * s_full * shape.global_batch
    if cfg.encoder:
        flops += cfg.encoder.num_layers * 12.0 * cfg.q_dim * s_full * s_full * shape.global_batch
    return flops


def prefill_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    return train_model_flops(cfg, shape) / 3.0  # fwd only (1 of fwd+2x bwd)


def decode_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """One serve_step: 2*N_active matmul FLOPs + attention over the cache."""
    B = shape.global_batch
    _, active = param_count(cfg)
    flops = 2.0 * active * B
    for kind in cfg.layer_kinds():
        if kind.startswith("attn") or kind.startswith("hybrid"):
            w = cfg.kind_window(kind)
            s_eff = min(w, shape.seq_len) if w else shape.seq_len
            flops += 4.0 * cfg.q_dim * s_eff * B
    return flops


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    if shape.kind == "train":
        return train_model_flops(cfg, shape)
    if shape.kind == "prefill":
        return prefill_model_flops(cfg, shape)
    return decode_model_flops(cfg, shape)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Analytic Pallas-kernel HBM traffic (the kernel-substituted roofline)
# ---------------------------------------------------------------------------
#
# On a real TPU the flash attention region executes as the Pallas kernel
# (kernels/flash_fwd.py, flash_bwd.py): Q tile + accumulator + (m, l) live
# in VMEM across the KV loop, so per (arch x shape) the kernel's HBM traffic
# is exactly the boundary tensors:
#
#   fwd:  read Q once, write O + LSE once, stream K/V once per visible
#         q-row block   (f * t_q * (K + V))
#   bwd:  dKV kernel -- read K/V + write dK/dV once, stream Q/dO/stats per
#         kv block; dQ kernel -- read Q/dO + write dQ once, stream K/V per
#         q block.  (the paper's 5-matmul recompute form, two-kernel TPU
#         split instead of atomic adds)
#
# The dry-run swaps the measured XLA-scan traffic of the tagged 'fa2scan'
# regions for this analytic traffic to produce the deployment roofline
# (EXPERIMENTS.md Section Roofline reports both).


def _visible_fraction(spec_kind: str, window, sink, t_q: int, t_kv: int,
                      bq: int, bk: int, q_offset: int = 0) -> float:
    from repro.core.masks import MaskSpec, tile_visibility

    spec = MaskSpec(
        causal=spec_kind == "causal" or (spec_kind == "window" and True),
        window=window if spec_kind == "window" else None,
        sink=sink,
    )
    if spec.is_trivial:
        return 1.0
    vis = 0
    for i in range(t_q):
        q_lo = i * bq + q_offset
        for j in range(t_kv):
            if tile_visibility(spec, q_lo, q_lo + bq, j * bk, j * bk + bk) != "empty":
                vis += 1
    return vis / max(t_q * t_kv, 1)


def flash_kernel_bytes(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    block_q: int = 1024,
    block_kv: int = 1024,
    multi_pod: bool = False,
    model_axis: int = 16,
    data_axis: int = 16,
) -> float:
    """Per-chip HBM bytes of all flash-attention kernel invocations in one
    step of this cell (train: fwd + remat-fwd + bwd; prefill: fwd).
    Mirrors the sharding rules of distributed.sharding.lm_rules."""
    if shape.kind == "decode":
        return 0.0  # decode uses flash_decode; not substituted
    chips_data = data_axis * (2 if multi_pod else 1)
    B_l = max(shape.global_batch // chips_data, 1)
    seqsh = cfg.attn_sharding in ("sequence", "ring")
    S = shape.seq_len
    D = cfg.head_dim
    dt = 2  # bf16
    if seqsh:
        S_q = max(S // model_axis, 1)
        Hq_l, Hkv_l = cfg.num_heads, cfg.num_kv_heads
    else:
        S_q = S
        Hq_l = cfg.num_heads // model_axis if cfg.num_heads % model_axis == 0 else cfg.num_heads
        # GQA expansion (models/attention_layer._expand_gqa_for_sharding):
        # each chip streams exactly its own q heads' (duplicated) kv heads.
        Hkv_l = Hq_l

    def attn_bytes(s_q, s_kv, hq, hkv, kind_spec, window, sink, train: bool):
        bq = min(block_q, s_q)
        bk = min(block_kv, s_kv)
        t_q = -(-s_q // bq)
        t_kv = -(-s_kv // bk)
        f = _visible_fraction(kind_spec, window, sink, t_q, t_kv, bq, bk)
        q_b = B_l * s_q * hq * D * dt
        o_b = q_b
        lse_b = B_l * hq * s_q * 4
        k_b = B_l * s_kv * hkv * D * dt
        fwd = q_b + o_b + lse_b + f * t_q * 2 * k_b
        if not train:
            return fwd
        # dKV kernel + dQ kernel (Algorithm 2, two-kernel TPU split)
        bwd = (
            2 * k_b + 2 * k_b  # read K,V; write dK,dV
            + f * t_kv * (2 * q_b + 2 * lse_b)  # stream Q,dO + (lse, delta)
            + 2 * q_b + q_b  # read Q,dO; write dQ
            + f * t_q * 2 * k_b  # stream K,V
        )
        # remat: the fwd runs again inside the backward
        return 2 * fwd + bwd

    train = shape.kind == "train"
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "mamba":
            continue
        window = cfg.kind_window(kind)
        sink = cfg.meta_tokens if (window is not None and cfg.meta_tokens) else 0
        spec_kind = "window" if window is not None else "causal"
        total += attn_bytes(S_q, S, Hq_l, Hkv_l, spec_kind, window, sink, train)
    if cfg.encoder:  # whisper: encoder self-attn (full) + decoder cross-attn
        frames = S  # dry-run uses seq_len frames for train/prefill
        fr_q = max(frames // model_axis, 1) if seqsh else frames
        total += cfg.encoder.num_layers * attn_bytes(
            fr_q, frames, Hq_l, Hkv_l, "full", None, 0, train
        )
        total += cfg.num_layers * attn_bytes(
            S_q, frames, Hq_l, Hkv_l, "full", None, 0, train
        )
    return total
