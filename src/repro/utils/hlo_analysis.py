"""HLO-text analysis: collective bytes, op census, roofline terms.

``cost_analysis()`` gives FLOPs and bytes but NOT collective traffic, so we
parse the (stable)HLO text: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op contributes its operand bytes. Hardware
constants are TPU v5e-class per the brief: 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "e4m3": 1, "e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> byte count; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    We count the op's *result* shape (post-HLO convention puts the full
    result shape on the lhs of '='), which upper-bounds moved bytes for
    all-gather and matches operand bytes for the others.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO: '%x = f32[...] all-reduce(...)' ; stableHLO: '"mhlo.all_reduce"'
        for kind in COLLECTIVE_OPS:
            token = f" {kind}(" if "(" in s else kind
            if f" {kind}(" in s or f'"{kind}"' in s or f"{kind}-start(" in s:
                lhs = s.split("=")[0] if "=" in s else s
                rhs_shape = s.split("=", 1)[1] if "=" in s else s
                out[kind] += _shape_bytes(rhs_shape.split(kind)[0])
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def op_census(hlo_text: str, ops=("exponential", "divide", "multiply", "maximum", "log")) -> Dict[str, int]:
    """Count elementwise op *instances* (the non-matmul FLOP census used by
    the FA1-vs-FA2 benchmark)."""
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\b{op}\(", hlo_text)) + len(
            re.findall(rf'"stablehlo\.{op}"', hlo_text)
        )
    return out


@dataclasses.dataclass
class Roofline:
    """All quantities are PER-CHIP (cost_analysis/memory_analysis of an SPMD
    module report the per-partition program -- calibrated against known
    sharded matmuls). ``model_flops`` must likewise be global/chips. The
    brief's formulas ``X / (chips * BW)`` with global X reduce to exactly
    these per-chip ratios."""

    flops: float
    hbm_bytes: float
    coll_bytes: float
    chips: int  # metadata (mesh size); terms below are already per-chip
    model_flops: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> Optional[float]:
        if self.model_flops is None or self.flops == 0:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant-term-bound step achieves on
        *useful* model FLOPs. All fields here are already per-chip (see
        class docstring), so the brief's MODEL_FLOPS/(chips*peak)/step_time
        reduces to mf/peak/step_time -- no further /chips."""
        mf = self.model_flops if self.model_flops is not None else self.flops
        ideal = mf / PEAK_FLOPS
        return ideal / max(self.step_time, 1e-30)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }
