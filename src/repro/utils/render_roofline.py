"""Render experiments/dryrun_*.json as the EXPERIMENTS.md roofline table.

Usage: python -m repro.utils.render_roofline > experiments/roofline_table.md
"""

from __future__ import annotations

import json
import os
import sys

HERE = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments")


def fmt(x, p=3):
    return f"{x:.{p}g}" if x is not None else "—"


def main():
    with open(os.path.join(HERE, "dryrun_singlepod.json")) as f:
        cur = json.load(f)
    base_path = os.path.join(HERE, "dryrun_singlepod_baseline.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else {}

    out = []
    out.append("# Roofline table — single-pod (16x16 = 256 chips), per chip\n")
    out.append(
        "`base frac` = paper-faithful baseline (pre-optimization sweep); "
        "`meas frac` = optimized XLA path; `depl frac` = kernel-substituted "
        "deployment roofline (flash regions at Pallas-kernel traffic). "
        "Terms in seconds. `useful` = MODEL_FLOPS / compiled FLOPs.\n"
    )
    out.append("| cell | GiB/dev | t_comp | t_mem | t_coll | dominant | useful | base frac | meas frac | depl frac | next lever |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")

    levers = {
        "compute": "more chips / lower redundancy (useful ratio)",
        "memory": "bigger tiles; fuse boundary crossings; kernel path",
        "collective": "reduce per-layer grad AR / param AG; bf16 links",
    }
    for key in sorted(cur):
        rec = cur[key]
        if rec.get("status") == "skipped":
            out.append(f"| {key} | — | — | — | — | skipped (by design, DESIGN.md §4) | — | — | — | — | — |")
            continue
        if rec.get("status") != "ok":
            out.append(f"| {key} | {rec.get('status')} |" + " — |" * 10)
            continue
        rl = rec["roofline"]
        rk = rec.get("roofline_kernel") or {}
        b = base.get(key, {}).get("roofline", {})
        gib = rec["memory"]["bytes_per_device"] / 2**30
        dom = rk.get("dominant", rl["dominant"])
        out.append(
            f"| {key} | {gib:.1f} | {fmt(rl['t_compute_s'])} | {fmt(rl['t_memory_s'])} "
            f"| {fmt(rl['t_collective_s'])} | {rl['dominant']} | {fmt(rl['useful_ratio'])} "
            f"| {fmt(b.get('roofline_fraction'))} | {fmt(rl['roofline_fraction'])} "
            f"| {fmt(rk.get('roofline_fraction'))} | {levers.get(dom, '—')} |"
        )

    # multipod summary
    mp = os.path.join(HERE, "dryrun_multipod.json")
    if os.path.exists(mp):
        with open(mp) as f:
            mpd = json.load(f)
        ok = sum(1 for v in mpd.values() if v.get("status") == "ok")
        sk = sum(1 for v in mpd.values() if v.get("status") == "skipped")
        out.append(
            f"\nMulti-pod (2x16x16 = 512 chips): {ok} cells compile ok, "
            f"{sk} skipped by design, {len(mpd) - ok - sk} errors. "
            "Full records in dryrun_multipod.json."
        )
    print("\n".join(out))


if __name__ == "__main__":
    main()
