"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE -- with
scan-over-layers, flash attention KV scans, CE chunking and microbatching,
that undercounts FLOPs/bytes by orders of magnitude (verified: a
scan of 10 matmuls reports 1). This walker reconstructs true per-device
totals from the compiled module text:

  * parses every computation into ops with result shapes,
  * builds the call graph (while/body+condition, fusion/calls, call/
    to_apply, conditional branches, sort comparators...),
  * multiplies while bodies by their ``known_trip_count`` backend config
    (XLA annotates statically-known trip counts; unknown -> 1 + warning),
  * FLOPs: dot ops = 2 * prod(result dims) * K (contraction size from the
    lhs operand shape); convolutions approximated the same way.
  * bytes: operand + result bytes of fusion/dot/copy/dynamic-*/collective
    root ops -- a proxy for HBM traffic under XLA fusion semantics.
  * collective bytes: result bytes of all-gather/all-reduce/
    reduce-scatter/all-to-all/collective-permute (per-device traffic;
    validated against hand-built examples).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_TRANSCENDENTAL = ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_CALL_ATTRS = ("to_apply", "calls", "body", "condition", "branch_computations",
               "called_computations", "comparator", "to_apply")


def _parse_shapes(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((dt, dims))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(s: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    shapes = _parse_shapes(s)
    return shapes[0] if shapes else None


@dataclasses.dataclass
class OpInfo:
    name: str
    result_str: str  # text before the op name (result shape(s))
    op: str
    rest: str  # text from the op name on (operands + attrs)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    transcendentals: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)  # op -> bytes
    # bytes attributable to 'fa2scan'-tagged while loops (the flash attention
    # tile scans). These are the XLA-fallback-path traffic that the Pallas
    # kernel replaces on real TPUs; the kernel-substituted roofline swaps
    # them for the analytic kernel traffic (utils.flops.flash_kernel_bytes).
    flash_bytes: float = 0.0

    def add_kind(self, kind: str, b: float):
        if b:
            self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        self.transcendentals += o.transcendentals
        self.flash_bytes += o.flash_bytes
        for k, v in o.by_kind.items():
            self.by_kind[k] = self.by_kind.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    self.transcendentals * k,
                    {kk: v * k for kk, v in self.by_kind.items()},
                    self.flash_bytes * k)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[OpInfo]] = {}
        self.shapes: Dict[Tuple[str, str], str] = {}  # (comp, op name) -> result str
        self.entry: Optional[str] = None
        self.warnings: List[str] = []
        self._parse(text)
        self._cache: Dict[str, Cost] = {}

    def _parse(self, text: str):
        comp = None
        comment_re = re.compile(r"/\*.*?\*/")
        for raw in text.splitlines():
            line = comment_re.sub("", raw).rstrip()
            hdr = _COMP_HDR_RE.match(line)
            if hdr and "{" in line and "=" not in line.split("{")[0]:
                comp = hdr.group(1)
                self.computations[comp] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = comp
                continue
            if comp is None:
                continue
            if line.strip() == "}":
                comp = None
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            om = _OP_RE.search(rhs)
            if not om:
                continue
            op = om.group(1)
            result_str = rhs[: om.start()]
            self.computations[comp].append(
                OpInfo(name=name, result_str=result_str, op=op, rest=rhs[om.start():])
            )
            self.shapes[(comp, name)] = result_str

    # ------------------------------------------------------------------
    def _operand_names(self, rest: str) -> List[str]:
        inner = rest[rest.find("(") + 1:]
        depth = 1
        buf = []
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf.append(ch)
        args = "".join(buf)
        return re.findall(r"%([\w\.\-]+)", args)

    def _operand_bytes(self, comp: str, rest: str) -> int:
        total = 0
        for name in self._operand_names(rest):
            s = self.shapes.get((comp, name))
            if s:
                total += _shape_bytes(s)
        return total

    def _called(self, rest: str) -> List[str]:
        out = []
        for attr in ("to_apply", "calls", "body", "condition"):
            m = re.search(rf"{attr}=%?([\w\.\-]+)", rest)
            if m:
                out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", rest)
        if m:
            out += re.findall(r"%?([\w\.\-]+)", m.group(1))
        return out

    def _trip_count(self, rest: str) -> Optional[int]:
        m = re.search(r'known_trip_count[^\d]*(\d+)', rest)
        return int(m.group(1)) if m else None

    def _dot_flops(self, comp: str, op: OpInfo) -> float:
        res = _first_shape(op.result_str)
        if res is None:
            return 0.0
        _, rdims = res
        out_elems = 1
        for d in rdims:
            out_elems *= d
        # contraction size from lhs shape + lhs_contracting_dims
        ops = self._operand_names(op.rest)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        k = 1
        if ops and m and m.group(1):
            lhs = self.shapes.get((comp, ops[0]))
            if lhs:
                sh = _first_shape(lhs)
                if sh:
                    for ci in m.group(1).split(","):
                        i = int(ci)
                        if i < len(sh[1]):
                            k *= sh[1][i]
        return 2.0 * out_elems * k

    # -- fusion byte model -------------------------------------------------
    #
    # XLA loop fusions touch HBM only at their boundary, and two boundary
    # patterns access a *slice*, not the whole operand (both verified XLA
    # behaviours on TPU/CPU backends):
    #   * a fusion parameter consumed exclusively by dynamic-slice ops reads
    #     just the slice (the fusion emitter indexes into the operand);
    #   * a fusion whose root is (a bitcast/tuple of) dynamic-update-slice
    #     aliases the input buffer and writes only the updated region --
    #     this is how scan carries update in place.
    # Charging full buffers instead (the naive model) overcounts a
    # flash-attention KV scan by ~the carry/tile ratio (~60x at 32k/512).

    def _fusion_bytes(self, comp: str, op: OpInfo) -> float:
        called = self._called(op.rest)
        body = called[0] if called else None
        ops_in = self.computations.get(body, []) if body else []
        if not ops_in:
            return _shape_bytes(op.result_str) + self._operand_bytes(comp, op.rest)

        by_name = {o.name: o for o in ops_in}
        # parameter index -> list of consuming ops
        param_users: Dict[str, List[OpInfo]] = {}
        param_shapes: Dict[str, str] = {}
        for o in ops_in:
            if o.op == "parameter":
                param_shapes[o.name] = o.result_str
                param_users[o.name] = []
        for o in ops_in:
            if o.op == "parameter":
                continue
            for nm in self._operand_names(o.rest):
                if nm in param_users:
                    param_users[nm].append(o)

        operand_names = self._operand_names(op.rest)
        # map positional params to caller operands for shape fallback
        read_bytes = 0.0
        params_sorted = sorted(
            param_shapes,
            key=lambda n: int(re.search(r"(\d+)", n).group(1)) if re.search(r"(\d+)", n) else 0,
        )
        for i, pname in enumerate(params_sorted):
            users = param_users.get(pname, [])
            full = _shape_bytes(param_shapes[pname])
            if not full and i < len(operand_names):
                s = self.shapes.get((comp, operand_names[i]))
                full = _shape_bytes(s) if s else 0
            if users and all(u.op == "dynamic-slice" for u in users):
                read_bytes += sum(_shape_bytes(u.result_str) for u in users)
            elif users and all(u.op == "dynamic-update-slice" for u in users):
                # the buffer being updated in place: reads nothing extra
                # (untouched regions are aliased, the written region is
                # charged on the write side below)
                pass
            else:
                read_bytes += full

        # write side: DUS roots write the update region only
        root = ops_in[-1]
        write_bytes = self._dus_write_bytes(body, root, by_name)
        if write_bytes is None:
            write_bytes = _shape_bytes(op.result_str)
        return read_bytes + write_bytes

    def _dus_write_bytes(self, body: str, root: OpInfo, by_name) -> Optional[float]:
        """If the fusion root is (a bitcast/tuple/copy chain over)
        dynamic-update-slice ops, return the updated-region bytes."""

        def resolve(name: str, depth=0):
            if depth > 6 or name not in by_name:
                return None
            o = by_name[name]
            if o.op == "dynamic-update-slice":
                ops = self._operand_names(o.rest)
                if len(ops) >= 2:
                    upd = by_name.get(ops[1])
                    if upd is not None:
                        return _shape_bytes(upd.result_str)
                    s = self.shapes.get((body, ops[1]))
                    return _shape_bytes(s) if s else None
                return None
            if o.op in ("bitcast", "copy", "convert", "reshape", "transpose"):
                inner = self._operand_names(o.rest)
                return resolve(inner[0], depth + 1) if inner else None
            if o.op == "tuple":
                total = 0.0
                for nm in self._operand_names(o.rest):
                    b = resolve(nm, depth + 1)
                    if b is None:
                        return None
                    total += b
                return total
            return None

        return resolve(root.name)

    def cost_of(self, comp: str) -> Cost:
        if comp in self._cache:
            return self._cache[comp]
        total = Cost()
        self._cache[comp] = total  # break cycles defensively
        for op in self.computations.get(comp, []):
            c = Cost()
            res_bytes = _shape_bytes(op.result_str)
            if op.op == "dot":
                c.flops += self._dot_flops(comp, op)
                b = res_bytes + self._operand_bytes(comp, op.rest)
                c.bytes += b
                c.add_kind("dot", b)
            elif op.op == "convolution":
                c.flops += self._dot_flops(comp, op)  # approx
                b = res_bytes + self._operand_bytes(comp, op.rest)
                c.bytes += b
                c.add_kind("convolution", b)
            elif op.op == "fusion":
                b = self._fusion_bytes(comp, op)
                c.bytes += b
                c.add_kind("fusion", b)
                for sub in self._called(op.rest):
                    sc = self.cost_of(sub)
                    c.flops += sc.flops
                    c.transcendentals += sc.transcendentals
                    c.coll_bytes += sc.coll_bytes  # none expected
            elif op.op in COLLECTIVES or (
                op.op.endswith("-start") and op.op[: -len("-start")] in COLLECTIVES
            ):
                # count the op (or its async -start form) once; the paired
                # '-done' op below is an alias and must not double-count.
                c.coll_bytes += res_bytes
                c.bytes += res_bytes
                c.add_kind("collective", res_bytes)
            elif op.op.endswith("-done") and op.op[: -len("-done")] in COLLECTIVES:
                pass
            elif op.op == "while":
                trips = self._trip_count(op.rest)
                if trips is None:
                    trips = 1
                    self.warnings.append(f"{comp}: while without known_trip_count")
                is_flash = "fa2scan" in op.rest
                for sub in self._called(op.rest):
                    sc = self.cost_of(sub).scaled(trips)
                    c += sc
                    if is_flash:
                        # attribute this loop's non-collective traffic to the
                        # flash region (avoid double count if nested tags)
                        c.flash_bytes += sc.bytes - sc.coll_bytes - sc.flash_bytes
            elif op.op in ("call", "conditional", "sort", "custom-call",
                           "reduce", "reduce-window", "scatter", "select-and-scatter",
                           "map", "all-reduce", "async-start"):
                for sub in self._called(op.rest):
                    c += self.cost_of(sub)
                if op.op in ("sort", "scatter", "reduce", "custom-call"):
                    b = res_bytes + self._operand_bytes(comp, op.rest)
                    c.bytes += b
                    c.add_kind(op.op, b)
            elif op.op in ("copy", "copy-start", "transpose", "reshape",
                           "dynamic-slice", "dynamic-update-slice", "gather",
                           "concatenate", "broadcast", "iota", "slice", "pad",
                           "convert", "bitcast", "bitcast-convert", "select",
                           "compare", "add", "subtract", "multiply", "divide",
                           "maximum", "minimum", "exponential", "log", "tanh",
                           "rsqrt", "sqrt", "negate", "abs", "and", "or", "not",
                           "xor", "power", "clamp", "floor", "ceil", "sign",
                           "logistic", "reduce-precision", "rng-bit-generator",
                           "tuple", "get-tuple-element", "parameter", "constant",
                           "partition-id", "replica-id", "after-all", "domain",
                           "optimization-barrier", "infeed", "outfeed",
                           "send", "recv", "sine", "cosine", "atan2", "remainder",
                           "shift-left", "shift-right-logical", "shift-right-arithmetic",
                           "is-finite", "round-nearest-afz", "round-nearest-even",
                           "expm1", "log1p", "cbrt", "erf", "stochastic-convert",
                           "dynamic-reshape"):
                if op.op == "dynamic-slice":
                    b = 2 * res_bytes  # reads the slice, writes the slice
                    c.bytes += b
                    c.add_kind(op.op, b)
                elif op.op == "dynamic-update-slice":
                    # in-place: reads the update operand, writes that region
                    ops_ = self._operand_names(op.rest)
                    upd = self.shapes.get((comp, ops_[1])) if len(ops_) > 1 else None
                    b = 2 * _shape_bytes(upd) if upd else 2 * res_bytes
                    c.bytes += b
                    c.add_kind(op.op, b)
                elif op.op in ("copy", "gather", "concatenate", "slice", "pad",
                               "transpose"):
                    b = res_bytes + self._operand_bytes(comp, op.rest)
                    c.bytes += b
                    c.add_kind(op.op, b)
                if op.op in _TRANSCENDENTAL:
                    n = 0
                    sh = _first_shape(op.result_str)
                    if sh:
                        n = 1
                        for d in sh[1]:
                            n *= d
                    c.transcendentals += n
            else:
                # unknown op: count bytes conservatively, recurse if it calls
                for sub in self._called(op.rest):
                    c += self.cost_of(sub)
            total += c
        self._cache[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
