"""Attention mask specifications and block-level mask construction.

FlashAttention-2 works block-by-block; masks are therefore described
*symbolically* (causal flag, window size, query offset) so that:

  * the XLA implementation can build a mask for a (q_block, kv_block) tile
    from iotas (never materializing an N x N mask), and
  * the Pallas kernels can decide statically/per-block whether a tile is
    fully visible (no mask applied), partially visible (apply mask), or
    fully hidden (skip compute) -- the paper's causal block-skipping, Sec 3.1.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")
# Large-but-finite mask value used inside kernels: subtracting true -inf can
# produce NaN via (-inf) - (-inf) in the m-update when an entire row is
# masked. DEFAULT_MASK_VALUE matches common flash implementations.
DEFAULT_MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Symbolic attention mask.

    Attributes:
      causal: apply a causal (lower triangular) mask.
      window: if set, sliding-window attention -- query i sees keys in
        (i - window, i]. Implies causal when ``causal`` is True (the usual
        SWA-in-decoder case, e.g. Mixtral); a non-causal window masks
        |i - j| >= window.
      q_offset: absolute position of the first query row relative to the
        first key row. Used for decode (single query at position `cache_len`)
        and for chunked prefill.
      sink: number of always-visible prefix keys (attention sinks / Hymba
        meta tokens): key j < sink is visible to every query regardless of
        causal/window constraints (but never *beyond* causality -- sinks sit
        at the sequence start, so causality already admits them; the flag
        matters only to *window* masking).
    """

    causal: bool = False
    window: Optional[int] = None
    q_offset: int = 0
    sink: int = 0

    @property
    def is_trivial(self) -> bool:
        return not self.causal and self.window is None

    def with_offset(self, q_offset: int) -> "MaskSpec":
        return dataclasses.replace(self, q_offset=q_offset)


FULL = MaskSpec(causal=False)
CAUSAL = MaskSpec(causal=True)


class SegmentInfo(NamedTuple):
    """Per-token segment ids for packed (varlen) attention.

    A batch row holds several back-to-back sequences ("segments"); query i
    may only attend key j when ``q[.., i] == kv[.., j]`` (on top of whatever
    the MaskSpec imposes on *global* positions -- with contiguous packing,
    global causality coincides with within-segment causality).

    Conventions:
      * ids are arbitrary non-negative ints, constant within a segment;
        contiguous (sorted) packing is assumed by the block-skip heuristics
        (correctness never depends on it -- skipping is range-disjointness,
        which is sound for any layout).
      * id 0 is the padding segment by convention of the data pipeline
        (padding attends only padding; its rows are excluded from the loss).

    Being a NamedTuple it is a pytree: it can be passed through jit
    boundaries, unlike MaskSpec which stays static/hashable.
    """

    q: jnp.ndarray  # (B, Sq) int32
    kv: jnp.ndarray  # (B, Skv) int32

    @classmethod
    def packed(cls, segment_ids: jnp.ndarray) -> "SegmentInfo":
        """Self-attention over one packed layout: q and kv share the ids."""
        return cls(q=segment_ids, kv=segment_ids)


def make_segment_mask(q_segs: jnp.ndarray, kv_segs: jnp.ndarray) -> jnp.ndarray:
    """(.., Sq) x (.., Skv) -> (.., Sq, Skv) bool; True = same segment."""
    return q_segs[..., :, None] == kv_segs[..., None, :]


# Padding sentinels for block-padded segment-id arrays. Both backends (XLA
# flash and the Pallas kernels) rely on the same invariant: the sentinels
# can never equal a real (non-negative) id, nor each other -- so padded
# tiles are cross-segment by construction, and padded q rows attend nothing
# (l = 0 -> o = 0, lse = -inf; the caller trims them).
Q_PAD_SEGMENT = -2
KV_PAD_SEGMENT = -1


def pad_segments(q_seg: jnp.ndarray, kv_seg: jnp.ndarray, Sqp: int, Skp: int):
    """Pad (.., Sq)/(.., Skv) int32 segment ids to the blocked lengths with
    the repo-wide sentinels above."""
    qs = q_seg.astype(jnp.int32)
    ks = kv_seg.astype(jnp.int32)
    if Sqp > qs.shape[-1]:
        pad = [(0, 0)] * (qs.ndim - 1) + [(0, Sqp - qs.shape[-1])]
        qs = jnp.pad(qs, pad, constant_values=Q_PAD_SEGMENT)
    if Skp > ks.shape[-1]:
        pad = [(0, 0)] * (ks.ndim - 1) + [(0, Skp - ks.shape[-1])]
        ks = jnp.pad(ks, pad, constant_values=KV_PAD_SEGMENT)
    return qs, ks


def segment_positions(segment_ids: jnp.ndarray) -> jnp.ndarray:
    """Within-segment positions for a packed row: (B, S) -> (B, S) int32.

    Position resets to 0 at every segment boundary (used for RoPE in
    ``packed`` mode, so each packed document sees positions 0..len-1).
    Assumes contiguous packing (equal ids form runs).
    """
    S = segment_ids.shape[-1]
    idx = jnp.arange(S, dtype=jnp.int32)
    starts = jnp.concatenate(
        [
            jnp.ones_like(segment_ids[..., :1], jnp.bool_),
            segment_ids[..., 1:] != segment_ids[..., :-1],
        ],
        axis=-1,
    )
    start_idx = jnp.where(starts, idx, 0)
    start_idx = jax.lax.associative_scan(jnp.maximum, start_idx, axis=-1)
    return idx - start_idx


def segment_tile_visibility(
    q_segs, kv_segs, q_lo: int, q_hi: int, kv_lo: int, kv_hi: int
) -> str:
    """Static classification of a tile by segment ids alone.

    q_segs/kv_segs are *concrete* (numpy) 1-D id vectors; positions are
    half-open like :func:`tile_visibility`. Used for host-side accounting
    (`_visible_pairs`) -- the kernels make the same decision dynamically
    from per-tile id ranges.
    """
    import numpy as np

    qs = np.asarray(q_segs)[q_lo:q_hi]
    ks = np.asarray(kv_segs)[kv_lo:kv_hi]
    if qs.size == 0 or ks.size == 0:
        return "empty"
    eq = qs[:, None] == ks[None, :]
    if not eq.any():
        return "empty"
    if eq.all():
        return "full"
    return "partial"


def make_tile_mask(
    spec: MaskSpec,
    q_ids: jnp.ndarray,
    kv_ids: jnp.ndarray,
) -> Optional[jnp.ndarray]:
    """Boolean visibility mask for a tile given absolute row/col ids.

    Args:
      spec: the mask spec.
      q_ids: (Bq,) int32 absolute query positions (spec.q_offset already NOT
        applied -- pass absolute ids).
      kv_ids: (Bc,) int32 absolute key positions.

    Returns:
      (Bq, Bc) bool array (True = visible), or None if the tile is fully
      visible (saves the select). Segment (varlen) masking composes on top
      via :func:`make_segment_mask` at the call sites.
    """
    if spec.is_trivial:
        return None
    qi = q_ids[:, None]
    kj = kv_ids[None, :]
    mask = None

    def _and(a, b):
        return b if a is None else (a & b)

    if spec.causal:
        mask = _and(mask, qi >= kj)
        if spec.window is not None:
            in_win = (qi - kj) < spec.window
            if spec.sink:
                in_win = in_win | (kj < spec.sink)
            mask = _and(mask, in_win)
    elif spec.window is not None:
        in_win = jnp.abs(qi - kj) < spec.window
        if spec.sink:
            in_win = in_win | (kj < spec.sink)
        mask = _and(mask, in_win)
    return mask


def tile_visibility(spec: MaskSpec, q_lo: int, q_hi: int, kv_lo: int, kv_hi: int) -> str:
    """Static classification of a tile: 'full' | 'partial' | 'empty'.

    Positions are absolute and half-open: queries in [q_lo, q_hi), keys in
    [kv_lo, kv_hi). This is the block-skipping logic of FA2 Section 3.1:
    'empty' tiles are skipped entirely, 'full' tiles skip the mask apply.
    """
    if spec.is_trivial:
        return "full"
    has_sink = spec.sink > 0 and kv_lo < spec.sink
    if spec.causal:
        # Fully hidden iff even the last query row sees none of the block:
        if q_hi - 1 < kv_lo:
            return "empty"
        if (
            spec.window is not None
            and (q_lo - (kv_hi - 1)) >= spec.window
            and not has_sink
        ):
            return "empty"
        # Fully visible iff first row sees the whole block:
        lo_vis = q_lo >= kv_hi - 1
        if spec.window is not None and not (spec.sink >= kv_hi):
            lo_vis = lo_vis and ((q_hi - 1) - kv_lo) < spec.window
        return "full" if lo_vis else "partial"
    # non-causal window
    assert spec.window is not None
    if (
        (q_lo - (kv_hi - 1)) >= spec.window or (kv_lo - (q_hi - 1)) >= spec.window
    ) and not has_sink:
        return "empty"
    if spec.sink >= kv_hi:
        return "full"
    full = (
        abs(q_lo - (kv_hi - 1)) < spec.window
        and abs((q_hi - 1) - kv_lo) < spec.window
        and abs(q_lo - kv_lo) < spec.window
        and abs((q_hi - 1) - (kv_hi - 1)) < spec.window
    )
    return "full" if full else "partial"


def apply_mask(scores: jnp.ndarray, mask: Optional[jnp.ndarray], value: float = DEFAULT_MASK_VALUE) -> jnp.ndarray:
    if mask is None:
        return scores
    return jnp.where(mask, scores, value)
