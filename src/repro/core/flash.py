"""FlashAttention-2 as an XLA program (lax.scan over tiles).

This is the *algorithmic* reproduction of the paper, independent of the
Pallas kernels in ``repro.kernels``:

  * C1a -- the output accumulator is kept **un-rescaled** through the KV
    loop; we multiply by ``diag(l)^-1`` exactly once at the end
    (``online_softmax.finalize``).
  * C1b -- only the logsumexp ``L = m + log(l)`` is saved for the backward
    pass (not both m and l); the backward recomputes ``P = exp(S - L)``
    (Algorithm 2, line 11).
  * C2  -- causal/window **block skipping**: in ``packed`` mode the scan
    iterates only over visible (q_block, kv_block) tile pairs -- the FLOPs
    XLA sees drop by ~2x for causal (and by ~S/w for windows), mirroring
    the paper's Section 3.1 "skip blocks above the diagonal".
  * The backward is the paper's Algorithm 2 (5 matmuls, recompute-from-LSE).
    TPU adaptation: instead of atomic adds into dQ, tiles accumulate into a
    carried dQ buffer inside a sequential scan (and across the mesh the
    q-block axis is *sharded*, which is the actual parallelism -- see
    distributed/context_parallel.py).

Why an XLA flash at all, when kernels/ has Pallas? (a) it is the CPU
execution path and the dry-run path where ``cost_analysis()`` must see real
FLOPs; (b) it is the oracle-adjacent reference for the kernels; (c) on TPU
it is a respectable fallback (XLA fuses the exp/max chain into the matmul
epilogue reasonably well). One config flag flips to the Pallas kernel.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import (
    DEFAULT_MASK_VALUE,
    MaskSpec,
    SegmentInfo,
    make_segment_mask,
    make_tile_mask,
    pad_segments,
    segment_tile_visibility,
    tile_visibility,
)


@dataclasses.dataclass(frozen=True)
class FlashConfig:
    spec: MaskSpec = MaskSpec()
    block_q: int = 512
    block_kv: int = 512
    mode: str = "auto"  # 'dense' | 'packed' | 'auto'
    scale: Optional[float] = None  # default 1/sqrt(D)

    def resolve_mode(self, t_q: int, t_kv: int) -> str:
        if self.mode != "auto":
            return self.mode
        if self.spec.is_trivial:
            return "dense"
        pairs = _visible_pairs(self.spec, t_q, t_kv, self.block_q, self.block_kv)
        # packed pays a gather/scatter per tile; require a real FLOP win.
        return "packed" if len(pairs[0]) <= 0.75 * t_q * t_kv else "dense"


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _pad_axis(x: jnp.ndarray, axis: int, block: int) -> Tuple[jnp.ndarray, int]:
    pad = (-x.shape[axis]) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, pad


def _visible_pairs(
    spec: MaskSpec, t_q: int, t_kv: int, bq: int, bk: int, segments=None
):
    """Static (i, j) tile pairs that are not fully masked (row-major).

    This is the SHARED SCHEDULE ORACLE (DESIGN.md Section 2.1): the XLA
    packed mode scans exactly these pairs, the Pallas compact schedules
    (kernels/schedule.py) assert their active step count equals this count
    at build time, and the kernels' CostEstimates charge these tiles.

    segments: optional concrete (numpy) segment ids -- either a single
    (Sq,) vector (packed self-attention) or a (q_segs, kv_segs) pair. A
    tile whose every (q, kv) pair crosses a segment boundary is dropped in
    addition to the MaskSpec-empty tiles: this is the accounting mirror of
    the kernels' dynamic cross-segment skip (FA2 Sec 3.1 generalized), so
    a packed batch costs the sum of its per-segment visible tiles rather
    than B x S^2.
    """
    q_segs = kv_segs = None
    if segments is not None:
        if isinstance(segments, tuple):
            q_segs, kv_segs = np.asarray(segments[0]), np.asarray(segments[1])
        else:
            q_segs = kv_segs = np.asarray(segments)
    ii, jj = [], []
    for i in range(t_q):
        q_lo = i * bq + spec.q_offset
        for j in range(t_kv):
            if tile_visibility(spec, q_lo, q_lo + bq, j * bk, j * bk + bk) == "empty":
                continue
            if q_segs is not None:
                # segment positions are layout-local (no q_offset)
                svis = segment_tile_visibility(
                    q_segs, kv_segs, i * bq, i * bq + bq, j * bk, j * bk + bk
                )
                if svis == "empty":
                    continue
            ii.append(i)
            jj.append(j)
    return np.asarray(ii, np.int32), np.asarray(jj, np.int32)


def _classified_pairs(spec: MaskSpec, t_q: int, t_kv: int, bq: int, bk: int, sk: int):
    """Visible tile pairs split into interior (fully visible -- the mask
    apply is skipped, FA2 Section 3.1 point 2) and boundary (partial, or
    touching KV padding). Returns ((ii_f, jj_f), (ii_p, jj_p))."""
    f_ii, f_jj, p_ii, p_jj = [], [], [], []
    for i in range(t_q):
        q_lo = i * bq + spec.q_offset
        for j in range(t_kv):
            vis = tile_visibility(spec, q_lo, q_lo + bq, j * bk, j * bk + bk)
            if vis == "empty":
                continue
            if vis == "full" and (j + 1) * bk <= sk:
                f_ii.append(i)
                f_jj.append(j)
            else:
                p_ii.append(i)
                p_jj.append(j)
    return (
        (np.asarray(f_ii, np.int32), np.asarray(f_jj, np.int32)),
        (np.asarray(p_ii, np.int32), np.asarray(p_jj, np.int32)),
    )


def _blocked(q, k, v, cfg: FlashConfig):
    """Normalize to blocked layout. Returns dict of blocked tensors + meta.

    Layout keeps batch and heads as SEPARATE einsum dims -- q (B, Hk, G,
    Sq, D), k/v (B, Hk, Sk, D). Merging them into one N = B*Hk dim (the
    usual kernel convenience) defeats XLA SPMD: a dim built by merging a
    'data'-sharded batch with a 'model'-sharded head axis cannot be
    sharded, and the whole attention computation silently replicates
    (measured 16x redundant compute on granite/qwen3 -- EXPERIMENTS.md
    Section Perf iterations G1/G2)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hq % Hk == 0, f"GQA requires Hq % Hkv == 0, got {Hq} % {Hk}"
    G = Hq // Hk
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(D)
    bq = min(cfg.block_q, max(Sq, 1))
    bk = min(cfg.block_kv, max(Sk, 1))

    # (B, Sq, Hk, G, D) -> (B, Hk, G, Sq, D)
    qt = q.reshape(B, Sq, Hk, G, D).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hk, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    qt, pad_q = _pad_axis(qt, 3, bq)
    kt, pad_k = _pad_axis(kt, 2, bk)
    vt, _ = _pad_axis(vt, 2, bk)
    t_q, t_kv = qt.shape[3] // bq, kt.shape[2] // bk

    # Pre-scale q (C1 spirit: O(N d) multiplies instead of O(N^2)).
    qt = (qt.astype(jnp.float32) * scale).astype(q.dtype)
    return dict(
        q=qt, k=kt, v=vt, B=B, Sq=Sq, Sk=Sk, Hq=Hq, Hk=Hk, G=G, D=D,
        bq=bq, bk=bk, t_q=t_q, t_kv=t_kv, pad_q=pad_q, pad_k=pad_k, scale=scale,
    )


def _blocked_segments(q_seg, kv_seg, bl):
    """Pad (B, Sq)/(B, Sk) int32 segment ids to the blocked lengths with
    the repo-wide sentinels (masks.pad_segments)."""
    return pad_segments(q_seg, kv_seg, bl["q"].shape[3], bl["k"].shape[2])


def _seg_tile_mask(q_segs, kv_segs):
    """(B, X) x (B, Y) -> (B, 1, 1, X, Y) same-segment mask (broadcasts
    over the (Hk, G) head dims of a score tile)."""
    return make_segment_mask(q_segs, kv_segs)[:, None, None]


def _tile_scores(q_blk, k_blk):
    # (B, H, G, bq, D) x (B, H, bk, D) -> (B, H, G, bq, bk), fp32 accumulation.
    return jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk, preferred_element_type=jnp.float32)


def _tile_mask_bias(spec: MaskSpec, i, j, bq, bk, sq, sk):
    """(bq, bk) bool mask for tile (i, j); i/j may be traced. None if trivial
    and no KV padding can intrude."""
    q_ids = i * bq + jnp.arange(bq, dtype=jnp.int32) + spec.q_offset
    kv_ids = j * bk + jnp.arange(bk, dtype=jnp.int32)
    mask = make_tile_mask(spec, q_ids, kv_ids)
    if sk % bk != 0:
        pad_ok = kv_ids < sk
        mask = pad_ok[None, :] if mask is None else (mask & pad_ok[None, :])
    return mask


def _update(m, l, acc, s, v_blk, mask, p_dtype):
    """One online-softmax tile update (FA2 Algorithm 1, lines 8-10)."""
    if mask is not None:
        s = jnp.where(mask, s, DEFAULT_MASK_VALUE)
    m_tile = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m, m_tile)
    alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_new))
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(p_dtype), v_blk, preferred_element_type=jnp.float32
    )
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


def _finalize(m, l, acc):
    """C1a: the single end-of-loop rescale by diag(l)^-1 (+ LSE for bwd)."""
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = acc / l_safe[..., None]
    lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
    return o, lse


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd(q, k, v, cfg: FlashConfig, q_seg=None, kv_seg=None):
    bl = _blocked(q, k, v, cfg)
    segs = None if q_seg is None else _blocked_segments(q_seg, kv_seg, bl)
    if cfg.resolve_mode(bl["t_q"], bl["t_kv"]) == "packed":
        # Segments compose with the static spec-only tile skip: the skip is
        # data-independent (a sound superset of the segment-visible tiles),
        # the traced segment mask is applied element-wise per kept tile --
        # exactly the Pallas kernels' structure.
        o, lse = _fwd_packed(bl, cfg, segs)
    else:
        o, lse = _fwd_dense(bl, cfg, segs)
    # Back to (B, Sq, Hq, D) / (B, Hq, Sq).
    B, Hk, G, Sq, Hq, D = bl["B"], bl["Hk"], bl["G"], bl["Sq"], bl["Hq"], bl["D"]
    o = o[:, :, :, :Sq].transpose(0, 3, 1, 2, 4)
    o = o.reshape(B, Sq, Hq, D).astype(q.dtype)
    lse = lse[:, :, :, :Sq].reshape(B, Hk * G, Sq)
    return o, lse


def _fwd_dense(bl, cfg: FlashConfig, segs=None):
    B, Hk, G, Sqp, D = bl["q"].shape
    bq, bk, t_kv = bl["bq"], bl["bk"], bl["t_kv"]
    p_dtype = bl["v"].dtype
    k_blocks = bl["k"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    v_blocks = bl["v"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    spec = cfg.spec
    q_segs, kv_segs = segs if segs is not None else (None, None)
    kv_seg_blocks = (
        None if kv_segs is None
        else kv_segs.reshape(B, t_kv, bk).transpose(1, 0, 2)  # (t_kv, B, bk)
    )

    q_all = bl["q"]  # (B, Hk, G, Sqp, D)
    q_ids = jnp.arange(Sqp, dtype=jnp.int32) + spec.q_offset

    def body(carry, xs):
        m, l, acc = carry
        k_j, v_j, kv_seg_j, j = xs
        s = _tile_scores(q_all, k_j)
        kv_ids = j * bk + jnp.arange(bk, dtype=jnp.int32)
        mask = make_tile_mask(spec, q_ids, kv_ids)
        if bl["pad_k"]:
            ok = kv_ids < bl["Sk"]
            mask = ok[None, :] if mask is None else (mask & ok[None, :])
        if kv_seg_j is not None:
            seg = _seg_tile_mask(q_segs, kv_seg_j)  # (B, 1, 1, Sqp, bk)
            mask = seg if mask is None else (mask & seg)
        m, l, acc = _update(m, l, acc, s, v_j, mask, p_dtype)
        return (m, l, acc), None

    m0 = jnp.full((B, Hk, G, Sqp), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Sqp), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, Sqp, D), jnp.float32)
    xs = (k_blocks, v_blocks, kv_seg_blocks, jnp.arange(t_kv, dtype=jnp.int32))
    with jax.named_scope("fa2scan"):  # tagged: kernel-substituted roofline
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    return _finalize(m, l, acc)


def _fwd_packed(bl, cfg: FlashConfig, segs=None):
    """Triangular tile packing: scans over visible (i, j) tile pairs.

    The carried state holds (m, l, acc) for *every* q block -- O(N d) memory,
    same as the output -- and each step touches one (bq x bk) tile. Total
    matmul FLOPs equal the number of visible tiles: the causal/window block
    skipping of FA2 Section 3.1, but expressed so that XLA (and therefore
    cost_analysis and the roofline) sees the reduction.

    Two scans (Section 3.1 point 2): interior tiles (fully visible -- no
    mask is built or applied, saving one S-tile-sized select per step) run
    first, then boundary tiles with the mask. Online-softmax combining is
    order-independent, so the split does not change the result.

    segs: optional blocked (q_segs (B, Sqp), kv_segs (B, Skp)) -- the
    spec-only tile skip stays sound (it is data-independent), but every
    kept tile needs the traced segment element mask, so all tiles run
    through the masked scan.
    """
    B, Hk, G, Sqp, D = bl["q"].shape
    bq, bk, t_q, t_kv = bl["bq"], bl["bk"], bl["t_q"], bl["t_kv"]
    p_dtype = bl["v"].dtype
    spec = cfg.spec
    q_segs, kv_segs = segs if segs is not None else (None, None)
    if segs is None:
        (ii_f, jj_f), (ii_p, jj_p) = _classified_pairs(spec, t_q, t_kv, bq, bk, bl["Sk"])
        q_seg_blocks = kv_seg_blocks = None
    else:
        # a spec-`full` tile may still cross segments -> everything masked
        ii_p, jj_p = _visible_pairs(spec, t_q, t_kv, bq, bk)
        ii_f = jj_f = np.asarray([], np.int32)
        q_seg_blocks = q_segs.reshape(B, t_q, bq).transpose(1, 0, 2)
        kv_seg_blocks = kv_segs.reshape(B, t_kv, bk).transpose(1, 0, 2)

    q_blocks = bl["q"].reshape(B, Hk, G, t_q, bq, D).transpose(3, 0, 1, 2, 4, 5)
    k_blocks = bl["k"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    v_blocks = bl["v"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)

    def make_body(masked: bool):
        def body(carry, xs):
            m, l, acc = carry  # (t_q, B, Hk, G, bq[, D])
            i, j = xs
            q_i = jax.lax.dynamic_index_in_dim(q_blocks, i, 0, keepdims=False)
            k_j = jax.lax.dynamic_index_in_dim(k_blocks, j, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(v_blocks, j, 0, keepdims=False)
            s = _tile_scores(q_i, k_j)
            mask = (
                _tile_mask_bias(spec, i, j, bq, bk, Sqp, bl["Sk"]) if masked else None
            )
            if masked and q_seg_blocks is not None:
                qs_i = jax.lax.dynamic_index_in_dim(q_seg_blocks, i, 0, keepdims=False)
                ks_j = jax.lax.dynamic_index_in_dim(kv_seg_blocks, j, 0, keepdims=False)
                seg = _seg_tile_mask(qs_i, ks_j)  # (B, 1, 1, bq, bk)
                mask = seg if mask is None else (mask & seg)
            m_i = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
            l_i = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
            a_i = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
            m_i, l_i, a_i = _update(m_i, l_i, a_i, s, v_j, mask, p_dtype)
            m = jax.lax.dynamic_update_index_in_dim(m, m_i, i, 0)
            l = jax.lax.dynamic_update_index_in_dim(l, l_i, i, 0)
            acc = jax.lax.dynamic_update_index_in_dim(acc, a_i, i, 0)
            return (m, l, acc), None

        return body

    m0 = jnp.full((t_q, B, Hk, G, bq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((t_q, B, Hk, G, bq), jnp.float32)
    a0 = jnp.zeros((t_q, B, Hk, G, bq, D), jnp.float32)
    carry = (m0, l0, a0)
    with jax.named_scope("fa2scan"):  # tagged: kernel-substituted roofline
        if len(ii_f):
            carry, _ = jax.lax.scan(
                make_body(False), carry, (jnp.asarray(ii_f), jnp.asarray(jj_f))
            )
        if len(ii_p):
            carry, _ = jax.lax.scan(
                make_body(True), carry, (jnp.asarray(ii_p), jnp.asarray(jj_p))
            )
    m, l, acc = carry
    o, lse = _finalize(
        m.transpose(1, 2, 3, 0, 4).reshape(B, Hk, G, Sqp),
        l.transpose(1, 2, 3, 0, 4).reshape(B, Hk, G, Sqp),
        acc.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hk, G, Sqp, D),
    )
    return o, lse


# ---------------------------------------------------------------------------
# Backward: the paper's Algorithm 2 over the same visible-tile schedule.
# ---------------------------------------------------------------------------


def _bwd_dense_unblocked(bl, q, k, v, o, lse, do, cfg: FlashConfig, segs=None):
    """Algorithm 2 with the KV loop outer and Q whole (context-parallel
    friendly). Same 5 matmuls per block; dQ accumulates in a carried fp32
    buffer (the TPU adaptation of the paper's atomic-add dQ)."""
    B, Hk, G, Sqp, D = bl["q"].shape
    bk, t_kv = bl["bk"], bl["t_kv"]
    Sq, Sk, scale = bl["Sq"], bl["Sk"], bl["scale"]
    spec = cfg.spec
    in_dtype = q.dtype
    q_segs, kv_segs = segs if segs is not None else (None, None)
    kv_seg_blocks = (
        None if kv_segs is None
        else kv_segs.reshape(B, t_kv, bk).transpose(1, 0, 2)  # (t_kv, B, bk)
    )

    def to_bhgs(x, Hn):  # (B, S, H, D) -> (B, Hk, G, Sqp, D) fp32
        _, S, _, _ = x.shape
        y = x.reshape(B, S, Hk, Hn // Hk, D).transpose(0, 2, 3, 1, 4)
        y, _ = _pad_axis(y, 3, bl["bq"])
        return y

    do_b = to_bhgs(do, bl["Hq"]).astype(jnp.float32)
    o_b = to_bhgs(o, bl["Hq"]).astype(jnp.float32)
    delta = jnp.sum(do_b * o_b, axis=-1)  # (B, Hk, G, Sqp): Alg 2 line 4
    lse_b = lse.reshape(B, Hk, G, Sq)
    lse_b, _ = _pad_axis(lse_b, 3, bl["bq"])
    lse_b = jnp.where(jnp.isneginf(lse_b), 0.0, lse_b)

    q_all = bl["q"]  # (B, Hk, G, Sqp, D), pre-scaled
    k_blocks = bl["k"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    v_blocks = bl["v"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    q_ids = jnp.arange(Sqp, dtype=jnp.int32) + spec.q_offset

    def body(dq, xs):
        k_j, v_j, kv_seg_j, j = xs
        s = _tile_scores(q_all, k_j)
        kv_ids = j * bk + jnp.arange(bk, dtype=jnp.int32)
        mask = make_tile_mask(spec, q_ids, kv_ids)
        if bl["pad_k"]:
            ok = kv_ids < Sk
            mask = ok[None, :] if mask is None else (mask & ok[None, :])
        if kv_seg_j is not None:
            seg = _seg_tile_mask(q_segs, kv_seg_j)
            mask = seg if mask is None else (mask & seg)
        if mask is not None:
            s = jnp.where(mask, s, DEFAULT_MASK_VALUE)
        p = jnp.exp(s - lse_b[..., None])  # line 11: recompute from LSE only
        dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, do_b, preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_b, v_j, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])  # line 14
        dq = dq + jnp.einsum(
            "bhgqk,bhkd->bhgqd", ds.astype(in_dtype), k_j, preferred_element_type=jnp.float32
        )
        dk_j = jnp.einsum(
            "bhgqk,bhgqd->bhkd", ds.astype(in_dtype), q_all, preferred_element_type=jnp.float32
        )
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Hk, G, Sqp, D), jnp.float32)
    xs = (k_blocks, v_blocks, kv_seg_blocks, jnp.arange(t_kv, dtype=jnp.int32))
    with jax.named_scope("fa2scan"):  # tagged: kernel-substituted roofline
        dq, (dk, dv) = jax.lax.scan(body, dq0, xs)

    dq = dq[:, :, :, :Sq].transpose(0, 3, 1, 2, 4)
    dq = dq.reshape(B, Sq, bl["Hq"], D) * scale
    def from_kv(x):  # (t_kv, B, Hk, bk, D) -> (B, Sk, Hk, D)
        y = x.transpose(1, 2, 0, 3, 4).reshape(B, Hk, t_kv * bk, D)[:, :, :Sk]
        return y.transpose(0, 2, 1, 3)

    return dq.astype(q.dtype), from_kv(dk).astype(k.dtype), from_kv(dv).astype(v.dtype)


def _bwd_impl(q, k, v, o, lse, do, cfg: FlashConfig, q_seg=None, kv_seg=None):
    bl = _blocked(q, k, v, cfg)  # note: bl['q'] is pre-scaled by `scale`
    B, Hk, G, Sqp, D = bl["q"].shape
    bq, bk, t_q, t_kv = bl["bq"], bl["bk"], bl["t_q"], bl["t_kv"]
    Sq, Sk, scale = bl["Sq"], bl["Sk"], bl["scale"]
    spec = cfg.spec

    segs = None if q_seg is None else _blocked_segments(q_seg, kv_seg, bl)
    mode = cfg.resolve_mode(t_q, t_kv)
    if mode != "packed":
        # Dense backward keeps Q *unblocked*: one scan over KV blocks, dQ
        # carried whole, (dK_j, dV_j) emitted as stacked scan outputs. No
        # dynamic indexing touches the (possibly sequence-sharded) Q axis,
        # so under context parallelism XLA SPMD keeps every tensor sharded
        # (the blocked formulation forced a full f32 all-gather of q_blocks
        # on every tile step -- see EXPERIMENTS.md Section Perf, deepseek).
        return _bwd_dense_unblocked(bl, q, k, v, o, lse, do, cfg, segs)
    if segs is None:
        (ii_f, jj_f), (ii_p, jj_p) = _classified_pairs(spec, t_q, t_kv, bq, bk, Sk)
        q_seg_blocks = kv_seg_blocks = None
    else:
        # spec-only skip is a sound superset; every kept tile gets the
        # traced segment element mask (see _fwd_packed).
        ii_p, jj_p = _visible_pairs(spec, t_q, t_kv, bq, bk)
        ii_f = jj_f = np.asarray([], np.int32)
        q_seg_blocks = segs[0].reshape(B, t_q, bq).transpose(1, 0, 2)
        kv_seg_blocks = segs[1].reshape(B, t_kv, bk).transpose(1, 0, 2)

    def to_bhgs(x, Hn):  # (B, S, H, D) -> (B, Hk, G, Sqp, D)
        _, S, _, _ = x.shape
        y = x.reshape(B, S, Hk, Hn // Hk, D).transpose(0, 2, 3, 1, 4)
        y, _ = _pad_axis(y, 3, bq)
        return y

    do_b = to_bhgs(do, bl["Hq"]).astype(jnp.float32)
    o_b = to_bhgs(o, bl["Hq"]).astype(jnp.float32)
    # D = rowsum(dO o O)  (Algorithm 2, line 4)
    delta = jnp.sum(do_b * o_b, axis=-1)  # (B, Hk, G, Sqp)
    lse_b = lse.reshape(B, Hk, G, Sq)
    lse_b, _ = _pad_axis(lse_b, 3, bq)
    lse_b = jnp.where(jnp.isneginf(lse_b), 0.0, lse_b)

    q_blocks = bl["q"].reshape(B, Hk, G, t_q, bq, D).transpose(3, 0, 1, 2, 4, 5)
    k_blocks = bl["k"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    v_blocks = bl["v"].reshape(B, Hk, t_kv, bk, D).transpose(2, 0, 1, 3, 4)
    do_blocks = do_b.reshape(B, Hk, G, t_q, bq, D).transpose(3, 0, 1, 2, 4, 5)
    lse_blocks = lse_b.reshape(B, Hk, G, t_q, bq).transpose(3, 0, 1, 2, 4)
    delta_blocks = delta.reshape(B, Hk, G, t_q, bq).transpose(3, 0, 1, 2, 4)
    in_dtype = q.dtype

    def make_body(masked: bool):
        def body(carry, xs):
            dq, dk, dv = carry
            i, j = xs
            q_i = jax.lax.dynamic_index_in_dim(q_blocks, i, 0, keepdims=False)
            do_i = jax.lax.dynamic_index_in_dim(do_blocks, i, 0, keepdims=False)
            lse_i = jax.lax.dynamic_index_in_dim(lse_blocks, i, 0, keepdims=False)
            dl_i = jax.lax.dynamic_index_in_dim(delta_blocks, i, 0, keepdims=False)
            k_j = jax.lax.dynamic_index_in_dim(k_blocks, j, 0, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(v_blocks, j, 0, keepdims=False)

            s = _tile_scores(q_i, k_j)  # q pre-scaled -> s is scaled scores
            if masked:
                mask = _tile_mask_bias(spec, i, j, bq, bk, Sqp, Sk)
                if q_seg_blocks is not None:
                    qs_i = jax.lax.dynamic_index_in_dim(q_seg_blocks, i, 0, keepdims=False)
                    ks_j = jax.lax.dynamic_index_in_dim(kv_seg_blocks, j, 0, keepdims=False)
                    seg = _seg_tile_mask(qs_i, ks_j)  # (B, 1, 1, bq, bk)
                    mask = seg if mask is None else (mask & seg)
                if mask is not None:
                    s = jnp.where(mask, s, DEFAULT_MASK_VALUE)
            p = jnp.exp(s - lse_i[..., None])  # line 11: recompute from LSE only
            # dV_j += P^T dO_i    (line 12; sums over G: GQA grad note, Sec 3.1)
            dv_j = jnp.einsum("bhgqk,bhgqd->bhkd", p, do_i, preferred_element_type=jnp.float32)
            # dP = dO_i V_j^T     (line 13)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_i, v_j, preferred_element_type=jnp.float32)
            # dS = P o (dP - D_i) (line 14)
            ds = p * (dp - dl_i[..., None])
            # dQ_i += dS K_j      (line 15)  [scale folded at the end]
            dq_i = jnp.einsum("bhgqk,bhkd->bhgqd", ds.astype(in_dtype), k_j, preferred_element_type=jnp.float32)
            # dK_j += dS^T Q_i    (line 16)
            dk_j = jnp.einsum("bhgqk,bhgqd->bhkd", ds.astype(in_dtype), q_i, preferred_element_type=jnp.float32)

            dq = jax.lax.dynamic_update_index_in_dim(
                dq, jax.lax.dynamic_index_in_dim(dq, i, 0, keepdims=False) + dq_i, i, 0
            )
            dk = jax.lax.dynamic_update_index_in_dim(
                dk, jax.lax.dynamic_index_in_dim(dk, j, 0, keepdims=False) + dk_j, j, 0
            )
            dv = jax.lax.dynamic_update_index_in_dim(
                dv, jax.lax.dynamic_index_in_dim(dv, j, 0, keepdims=False) + dv_j, j, 0
            )
            return (dq, dk, dv), None

        return body

    dq0 = jnp.zeros((t_q, B, Hk, G, bq, D), jnp.float32)
    dk0 = jnp.zeros((t_kv, B, Hk, bk, D), jnp.float32)
    dv0 = jnp.zeros((t_kv, B, Hk, bk, D), jnp.float32)
    carry = (dq0, dk0, dv0)
    with jax.named_scope("fa2scan"):  # tagged: kernel-substituted roofline
        if len(ii_f):
            carry, _ = jax.lax.scan(
                make_body(False), carry, (jnp.asarray(ii_f), jnp.asarray(jj_f))
            )
        if len(ii_p):
            carry, _ = jax.lax.scan(
                make_body(True), carry, (jnp.asarray(ii_p), jnp.asarray(jj_p))
            )
    dq, dk, dv = carry

    def from_q_blocks(x):  # (t_q, B, Hk, G, bq, D) -> (B, Sq, Hq, D)
        y = x.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hk, G, Sqp, D)[:, :, :, :Sq]
        y = y.transpose(0, 3, 1, 2, 4)
        return y.reshape(B, Sq, Hk * G, D)

    def from_kv_blocks(x):  # (t_kv, B, Hk, bk, D) -> (B, Sk, Hk, D)
        y = x.transpose(1, 2, 0, 3, 4).reshape(B, Hk, t_kv * bk, D)[:, :, :Sk]
        return y.transpose(0, 2, 1, 3)

    # q was pre-scaled: dS was computed w.r.t. scaled scores, so dq here is
    # d/d(q*scale) -> multiply by scale; dk already correct because q_i used
    # in line 16 carries the scale.
    dq = from_q_blocks(dq) * scale
    return dq.astype(q.dtype), from_kv_blocks(dk).astype(k.dtype), from_kv_blocks(dv).astype(v.dtype)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, cfg: FlashConfig):
    return _fwd(q, k, v, cfg)[0]


def _flash_vjp_fwd(q, k, v, cfg: FlashConfig):
    o, lse = _fwd(q, k, v, cfg)
    return o, (q, k, v, o, lse)


def _flash_vjp_bwd(cfg: FlashConfig, res, do):
    q, k, v, o, lse = res
    return _bwd_impl(q, k, v, o, lse, do, cfg)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _flash_varlen(q, k, v, q_seg, kv_seg, cfg: FlashConfig):
    return _fwd(q, k, v, cfg, q_seg, kv_seg)[0]


def _flash_varlen_vjp_fwd(q, k, v, q_seg, kv_seg, cfg: FlashConfig):
    o, lse = _fwd(q, k, v, cfg, q_seg, kv_seg)
    return o, (q, k, v, q_seg, kv_seg, o, lse)


def _flash_varlen_vjp_bwd(cfg: FlashConfig, res, do):
    q, k, v, q_seg, kv_seg, o, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, o, lse, do, cfg, q_seg, kv_seg)
    return dq, dk, dv, None, None  # integer segment ids carry no gradient


_flash_varlen.defvjp(_flash_varlen_vjp_fwd, _flash_varlen_vjp_bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: MaskSpec = MaskSpec(causal=True),
    *,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 512,
    mode: str = "auto",
    segment_ids: Optional[jnp.ndarray] = None,
    kv_segment_ids: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Differentiable FlashAttention-2 (XLA path). q (B,Sq,Hq,D); k/v GQA.

    segment_ids (B, Sq) int32 (or a SegmentInfo) enables packed varlen
    semantics (query i sees key j only within its segment);
    kv_segment_ids defaults to segment_ids.
    """
    cfg = FlashConfig(spec=spec, block_q=block_q, block_kv=block_kv, mode=mode, scale=scale)
    if segment_ids is None:
        return _flash(q, k, v, cfg)
    if isinstance(segment_ids, SegmentInfo):
        segment_ids, kv_segment_ids = segment_ids.q, segment_ids.kv
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    return _flash_varlen(
        q, k, v, segment_ids.astype(jnp.int32), kv_segment_ids.astype(jnp.int32), cfg
    )


def flash_attention_with_lse(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *, scale=None,
    block_q: int = 512, block_kv: int = 512, mode: str = "auto",
    segment_ids=None, kv_segment_ids=None,
):
    """Forward-only (serving / context-parallel): returns (o, lse)."""
    cfg = FlashConfig(spec=spec, block_q=block_q, block_kv=block_kv, mode=mode, scale=scale)
    if segment_ids is None:
        return _fwd(q, k, v, cfg)
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    return _fwd(
        q, k, v, cfg, segment_ids.astype(jnp.int32), kv_segment_ids.astype(jnp.int32)
    )
