"""Unified attention entry point -- the framework's first-class feature.

Every model in ``repro.models`` calls :func:`attention` / :func:`decode_attention`;
the backend is selected by config, never by model code:

  impl = 'ref'           naive O(N^2)-memory attention (oracle / paper baseline)
  impl = 'flash_xla'     FA2 algorithm as XLA scans (CPU + dry-run path)
  impl = 'flash_pallas'  FA2 Pallas TPU kernel (interpret mode auto-enables
                         off-TPU; kernels/compat.resolve_interpret)

All three are exact and interchangeable; tests assert pairwise agreement.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core import flash as _flash
from repro.core import decode as _decode
from repro.core.masks import MaskSpec


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    impl: str = "flash_xla"  # 'ref' | 'flash_xla' | 'flash_pallas'
    # None -> tuned cache (kernels/autotune), then shape-aware defaults
    # (kernels/ops.default_block_sizes) on the Pallas path; the XLA scan
    # path falls back to its fixed 512.
    block_q: Optional[int] = None
    block_kv: Optional[int] = None
    mode: str = "auto"  # tile schedule for flash_xla: 'dense' | 'packed' | 'auto'
    # flash_pallas tile schedule / backward: None -> tuned cache, then
    # 'compact' / 'fused'. Explicit strings override everywhere.
    schedule: Optional[str] = None  # 'compact' | 'dense'
    bwd: Optional[str] = None  # 'fused' (one-pass) | 'split'
    # Forward occupancy partitioning (flash_pallas, compact schedule):
    # None -> tuned cache, then shape-aware auto
    # (kernels/ops.default_forward_partitions); explicit ints override
    # (1 disables).
    num_q_bands: Optional[int] = None
    kv_splits: Optional[int] = None
    # Split-KV decode fan-out: None -> tuned cache
    # (kernels/autotune.resolve_decode_splits), then 8.
    decode_splits: Optional[int] = None
    # Tuned-knob cache switch: None -> env REPRO_TUNED_CACHE (on by
    # default); False forces pure-heuristic knob resolution.
    use_tuned: Optional[bool] = None
    # Pallas interpret mode: None = auto (off on real TPUs, on elsewhere --
    # resolved in one place, kernels/compat.resolve_interpret).
    interpret: Optional[bool] = None


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: MaskSpec,
    cfg: AttentionConfig = AttentionConfig(),
    *,
    scale: Optional[float] = None,
    segment_ids: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Differentiable attention. q (B,Sq,Hq,D); k/v (B,Skv,Hkv,D) GQA.

    segment_ids (B, S) int32 enables packed varlen semantics on every
    backend (self-attention over one packed layout: q and kv share ids).

    Under ``attn_sharding='ring'`` rules (distributed/sharding.use_rules
    with a >1-wide model axis), self-attention calls route to the
    context-parallel ring implementation (distributed/ring_attention.py):
    same math, KV sharded instead of gathered. Cross-attention
    (Sq != Skv / q_offset) keeps the local path — its KV is encoder-sized
    and the 'sequence' gather handles it.
    """
    from repro.distributed.context_parallel import attn_context_mode

    if (
        attn_context_mode() == "ring"
        and cfg.impl in ("flash_pallas", "flash_xla")  # 'ref' stays the oracle
        and q.shape[1] == k.shape[1]
        and spec.q_offset == 0
    ):
        if segment_ids is not None:
            raise ValueError(
                "packed (varlen) attention does not compose with "
                "attn_sharding='ring' -- pack per data shard instead"
            )
        from repro.distributed.ring_attention import ring_flash_attention

        return ring_flash_attention(
            q, k, v, spec, impl=cfg.impl, scale=scale, block_q=cfg.block_q,
            block_kv=cfg.block_kv, interpret=cfg.interpret,
            schedule=cfg.schedule, bwd=cfg.bwd,
            num_q_bands=cfg.num_q_bands, kv_splits=cfg.kv_splits,
            use_tuned=cfg.use_tuned,
        )
    if cfg.impl == "ref":
        from repro.kernels.ref import attention_reference

        return attention_reference(q, k, v, spec, scale=scale, segment_ids=segment_ids)[0]
    if cfg.impl == "flash_xla":
        return _flash.flash_attention(
            q, k, v, spec, scale=scale, block_q=cfg.block_q or 512,
            block_kv=cfg.block_kv or 512, mode=cfg.mode, segment_ids=segment_ids,
        )
    if cfg.impl == "flash_pallas":
        if segment_ids is not None:
            from repro.kernels.ops import flash_attention_pallas_varlen

            return flash_attention_pallas_varlen(
                q, k, v, segment_ids, spec, scale=scale, block_q=cfg.block_q,
                block_kv=cfg.block_kv, interpret=cfg.interpret,
                schedule=cfg.schedule, bwd=cfg.bwd,
                num_q_bands=cfg.num_q_bands, kv_splits=cfg.kv_splits,
                use_tuned=cfg.use_tuned,
            )
        from repro.kernels.ops import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, spec, scale=scale, block_q=cfg.block_q, block_kv=cfg.block_kv,
            interpret=cfg.interpret, schedule=cfg.schedule, bwd=cfg.bwd,
            num_q_bands=cfg.num_q_bands, kv_splits=cfg.kv_splits,
            use_tuned=cfg.use_tuned,
        )
    raise ValueError(f"unknown attention impl: {cfg.impl}")


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_length: jnp.ndarray,
    cfg: AttentionConfig = AttentionConfig(),
    *,
    window: Optional[int] = None,
    sink: int = 0,
    scale: Optional[float] = None,
    kv_segment_ids: Optional[jnp.ndarray] = None,
    q_segment: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Single-token decode against a padded KV cache. Returns (B,1,Hq,D).

    kv_segment_ids (B, S) + q_segment (B,) restrict the query to its own
    segment of a packed cache (see flash_decode / flash_decode_pallas).

    ``cfg.decode_splits=None`` resolves the split-KV fan-out from the tuned
    cache (keyed on the static padded cache size) with the same precedence
    as the training knobs: explicit > tuned > default (8).
    """
    splits = cfg.decode_splits
    if splits is None:
        from repro.kernels import autotune

        splits = autotune.resolve_decode_splits(
            k_cache.shape[1], q.shape[2], q.shape[3], q.dtype,
            use_tuned=cfg.use_tuned,
        )
    else:
        from repro.obs.metrics import count_knob

        count_knob("flash_decode", "explicit")
    if cfg.impl == "flash_pallas":
        from repro.kernels.ops import flash_decode_pallas

        return flash_decode_pallas(
            q, k_cache, v_cache, cache_length, window=window, sink=sink, scale=scale,
            num_splits=splits, kv_segment_ids=kv_segment_ids,
            q_segment=q_segment, interpret=cfg.interpret,
        )[0]
    return _decode.flash_decode(
        q, k_cache, v_cache, cache_length, window=window, sink=sink, scale=scale,
        num_splits=splits, kv_segment_ids=kv_segment_ids,
        q_segment=q_segment,
    )[0]


def decode_attention_paged(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k_pages: jnp.ndarray,  # (Hkv, P, page_size, D) pool planes
    v_pages: jnp.ndarray,
    cache_length: jnp.ndarray,  # (B,) int32 logical lengths
    block_table: jnp.ndarray,  # (B, n_pages) int32
    cfg: AttentionConfig = AttentionConfig(),
    *,
    window: Optional[int] = None,
    sink: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token decode against a *paged* KV cache. Returns (B,1,Hq,D).

    The cache is the pool's physical page planes plus a per-sequence block
    table (serving/kv_pool.py); rows with ``cache_length == 0`` (all-null
    table) read no KV at all on the Pallas path. ``cfg.decode_splits=None``
    resolves the split fan-out from the tuned cache keyed on the *logical*
    capacity ``n_pages * page_size`` and the page size
    (kernels/autotune.resolve_decode_splits)."""
    ps = k_pages.shape[2]
    logical = block_table.shape[1] * ps
    splits = cfg.decode_splits
    if splits is None:
        from repro.kernels import autotune

        splits = autotune.resolve_decode_splits(
            logical, q.shape[2], q.shape[3], q.dtype,
            page_size=ps, use_tuned=cfg.use_tuned,
        )
    else:
        from repro.obs.metrics import count_knob

        count_knob(f"flash_decode_paged{ps}", "explicit")
    if cfg.impl == "flash_pallas":
        from repro.kernels.ops import flash_decode_paged_pallas

        return flash_decode_paged_pallas(
            q, k_pages, v_pages, cache_length, block_table,
            window=window, sink=sink, scale=scale, num_splits=splits,
            interpret=cfg.interpret,
        )[0]
    return _decode.flash_decode_paged(
        q, k_pages, v_pages, cache_length, block_table,
        window=window, sink=sink, scale=scale, num_splits=splits,
    )[0]
