"""The paper's primary contribution: the FlashAttention-2 stack.

masks.py / online_softmax.py   symbolic masks + the associative combine algebra
flash.py                        FA2 fwd/bwd as XLA scans (packed causal tiles)
flash_v1.py                     FA1-style baseline (for the C1 comparison)
decode.py                       split-KV flash decode (C2 applied to inference)
attention.py                    backend-dispatching public API
"""

from repro.core.attention import AttentionConfig, attention, decode_attention
from repro.core.flash import FlashConfig, flash_attention, flash_attention_with_lse
from repro.core.masks import CAUSAL, FULL, MaskSpec

__all__ = [
    "AttentionConfig",
    "attention",
    "decode_attention",
    "FlashConfig",
    "flash_attention",
    "flash_attention_with_lse",
    "MaskSpec",
    "CAUSAL",
    "FULL",
]
