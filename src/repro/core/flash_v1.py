"""FlashAttention-1-style forward loop -- the paper's baseline for C1.

Differences from ``core.flash`` (deliberate, per FA1 [Dao et al. 2022]):

  * the output accumulator is **rescaled to a normalized state on every KV
    block** (two extra O(Br x d) divides/multiplies per block: `diag(l)^-1`
    re-applied), instead of FA2's single end-of-loop rescale;
  * both row-max ``m`` and row-sum ``l`` are kept as residuals (FA2 keeps
    only ``L = m + log l``).

Numerically both are exact; the difference is pure non-matmul FLOPs, which
is precisely the paper's point (Section 3.1). ``benchmarks/nonmatmul_census``
counts the exp/div/mul ops in the lowered HLO of the two and times them.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec, make_tile_mask


def flash_v1_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: MaskSpec = MaskSpec(causal=True),
    *,
    scale: Optional[float] = None,
    block_kv: int = 512,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (o, m, l) -- FA1 keeps both softmax statistics."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bk = min(block_kv, Sk)
    assert Sk % bk == 0, "flash_v1 baseline: Sk must divide block_kv"
    t_kv = Sk // bk

    qt = q.reshape(B, Sq, Hk, G, D).transpose(0, 2, 3, 1, 4).reshape(B * Hk, G, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hk, t_kv, bk, D).transpose(1, 0, 2, 3)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hk, t_kv, bk, D).transpose(1, 0, 2, 3)
    q_ids = jnp.arange(Sq, dtype=jnp.int32) + spec.q_offset

    def body(carry, xs):
        m, l, o = carry  # o is *normalized* at every step: the FA1 invariant
        k_j, v_j, j = xs
        s = jnp.einsum("ngqd,nkd->ngqk", qt, k_j, preferred_element_type=jnp.float32) * scale
        kv_ids = j * bk + jnp.arange(bk, dtype=jnp.int32)
        mask = make_tile_mask(spec, q_ids, kv_ids)
        if mask is not None:
            s = jnp.where(mask, s, DEFAULT_MASK_VALUE)
        m_tile = jnp.max(s, axis=-1)
        p = jnp.exp(s - m_tile[..., None])
        l_tile = jnp.sum(p, axis=-1)
        m_new = jnp.maximum(m, m_tile)
        alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_new))
        beta = jnp.exp(m_tile - m_new)
        l_new = alpha * l + beta * l_tile
        pv = jnp.einsum("ngqk,nkd->ngqd", p.astype(v.dtype), v_j, preferred_element_type=jnp.float32)
        # FA1: renormalize the running output every block ->
        #   o <- diag(l_new)^-1 (diag(l) alpha o + beta P V)
        l_safe = jnp.where(l_new == 0.0, 1.0, l_new)
        o_new = (l[..., None] * alpha[..., None] * o + beta[..., None] * pv) / l_safe[..., None]
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B * Hk, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B * Hk, G, Sq), jnp.float32)
    o0 = jnp.zeros((B * Hk, G, Sq, D), jnp.float32)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kt, vt, jnp.arange(t_kv, dtype=jnp.int32)))
    o = o.reshape(B, Hk, G, Sq, D).transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return o.astype(q.dtype), m.reshape(B, Hq, Sq), l.reshape(B, Hq, Sq)
