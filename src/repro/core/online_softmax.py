"""Online-softmax algebra (Milakov & Gimelshein 2018; FA2 Section 3.1).

The core state for a row block is a triple ``(m, l, o_unscaled)``:

  m           running row max of scores seen so far                (fp32)
  l           running row sum of exp(scores - m)                   (fp32)
  o_unscaled  sum_j exp(S_j - m) @ V_j  -- NOT divided by l        (fp32)

FlashAttention-2's tweak C1: keep ``o_unscaled`` through the loop and divide
by ``l`` exactly once at the end (one non-matmul rescale instead of one per
block), and persist only the logsumexp ``L = m + log l`` for the backward
pass. The ``combine`` below is associative and commutative, which is what
makes both the kernel-level KV-loop *and* the split-KV decode tree *and* the
mesh-level context-parallel reduction correct. ``tests/test_properties.py``
checks associativity with hypothesis.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SoftmaxState(NamedTuple):
    m: jnp.ndarray  # (..., rows)
    l: jnp.ndarray  # (..., rows)
    o: jnp.ndarray  # (..., rows, d) -- unscaled


def init_state(rows_shape, d, dtype=jnp.float32) -> SoftmaxState:
    return SoftmaxState(
        m=jnp.full(rows_shape, -jnp.inf, dtype=dtype),
        l=jnp.zeros(rows_shape, dtype=dtype),
        o=jnp.zeros((*rows_shape, d), dtype=dtype),
    )


def block_state(s: jnp.ndarray, v: jnp.ndarray) -> SoftmaxState:
    """State for a single block of scores s (..., rows, cols) against v."""
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...rc,...cd->...rd", p, v)
    return SoftmaxState(m=m, l=l, o=o)


def combine(a: SoftmaxState, b: SoftmaxState) -> SoftmaxState:
    """Merge two online-softmax states (associative)."""
    m = jnp.maximum(a.m, b.m)
    # exp(-inf - -inf) guard: where both are -inf the alphas are 0 via where.
    alpha_a = jnp.where(jnp.isneginf(a.m), 0.0, jnp.exp(a.m - m))
    alpha_b = jnp.where(jnp.isneginf(b.m), 0.0, jnp.exp(b.m - m))
    l = a.l * alpha_a + b.l * alpha_b
    o = a.o * alpha_a[..., None] + b.o * alpha_b[..., None]
    return SoftmaxState(m=m, l=l, o=o)


def finalize(s: SoftmaxState):
    """-> (o, lse): the softmax-weighted output and the row logsumexp."""
    l_safe = jnp.where(s.l == 0.0, 1.0, s.l)
    o = s.o / l_safe[..., None]
    lse = s.m + jnp.log(l_safe)
    lse = jnp.where(s.l == 0.0, -jnp.inf, lse)
    return o, lse


def merge_partials(o_a, lse_a, o_b, lse_b):
    """Pairwise merge of two *finalized* attention partials.

    Each partial is the exact attention output over a subset of the keys,
    already normalized, together with its row logsumexp:
      o:   (..., rows, d)
      lse: (..., rows)       -- -inf marks rows that saw no keys
    Returns (o, lse) equivalent to attention over the union of the two key
    sets. Associative and commutative (it is ``combine`` expressed on
    finalized states), which is what lets split-KV decode merge in any tree
    order and ring attention fold shards in ring order — THE shared merge
    primitive for both (tests/test_ring.py checks associativity and the
    split/merge roundtrip). An all -inf partial (e.g. a fully masked shard,
    or the ring's initial accumulator) is the identity; garbage in its ``o``
    is erased by the zero weight as long as it is finite.
    """
    m = jnp.maximum(lse_a, lse_b)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    w_a = jnp.where(jnp.isneginf(lse_a), 0.0, jnp.exp(lse_a - m_safe))
    w_b = jnp.where(jnp.isneginf(lse_b), 0.0, jnp.exp(lse_b - m_safe))
    l = w_a + w_b
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = (o_a * w_a[..., None] + o_b * w_b[..., None]) / l_safe[..., None]
    lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
    return o, lse


def combine_lse_outputs(o_parts: jnp.ndarray, lse_parts: jnp.ndarray):
    """Combine per-part *finalized* outputs using their LSEs.

    Used by split-KV decode and context-parallel attention where each worker
    produces a locally-normalized (o_i, lse_i). Stacked along axis 0:
      o_parts:   (P, ..., rows, d)
      lse_parts: (P, ..., rows)
    Returns (o, lse) equivalent to attention over the concatenated KV.

    Implemented as a balanced tree reduction of :func:`merge_partials` (the
    halves merge vectorized), so the split-KV merge and the ring-attention
    accumulation share one tested implementation.
    """
    o, lse = o_parts, lse_parts
    while o.shape[0] > 1:
        h = o.shape[0] // 2
        o_m, lse_m = merge_partials(o[:h], lse[:h], o[h : 2 * h], lse[h : 2 * h])
        if o.shape[0] % 2:
            o_m = jnp.concatenate([o_m, o[2 * h :]], axis=0)
            lse_m = jnp.concatenate([lse_m, lse[2 * h :]], axis=0)
        o, lse = o_m, lse_m
    return o[0], lse[0]
