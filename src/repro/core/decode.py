"""Split-KV flash decode: FA2's sequence-dimension parallelism (C2) applied
to autoregressive inference.

At decode there is a single query per sequence, so the (batch x heads) grid
alone under-fills the device exactly as the paper describes for long
sequences. The fix is the paper's: split the *KV* axis into ``num_splits``
chunks, compute a locally-normalized (o_i, lse_i) per chunk in parallel, and
merge with the associative online-softmax combine
(``online_softmax.combine_lse_outputs``). The same function serves as the
merge step for mesh-level context-parallel decode (KV cache sharded over the
`model` axis -- see distributed/context_parallel.py).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.core.online_softmax import SoftmaxState, combine_lse_outputs, finalize


def flash_decode(
    q: jnp.ndarray,  # (B, 1, Hq, D) -- single new token per sequence
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    cache_length: jnp.ndarray,  # (B,) int32: number of valid cache entries
    *,
    window: Optional[int] = None,
    sink: int = 0,
    scale: Optional[float] = None,
    num_splits: int = 8,
    kv_segment_ids: Optional[jnp.ndarray] = None,  # (B, S) int32
    q_segment: Optional[jnp.ndarray] = None,  # (B,) int32
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact attention of one query against a (padded) KV cache.

    The query attends to cache positions [max(0, L - window), L) where
    L = cache_length[b] (the query sits at position L - 1 *after* the new
    token's KV has been appended -- append before calling).

    kv_segment_ids/q_segment restrict attention to the query's own segment
    in a *packed* cache (several sequences back-to-back in one cache row):
    only positions with kv_segment_ids[b, j] == q_segment[b] are visible.
    The window (if any) still counts global tail positions, which matches
    the packed-decode case of generating into the trailing segment.

    Returns (o (B, 1, Hq, D), lse (B, Hq, 1)).
    """
    B, one, Hq, D = q.shape
    assert one == 1, "flash_decode is a single-step primitive; loop outside"
    _, S, Hk, _ = k_cache.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    ns = num_splits
    while S % ns != 0:  # static; S is padded cache capacity
        ns -= 1
    sc = S // ns

    qf = (q.astype(jnp.float32) * scale).reshape(B, Hk, G, D)
    kc = k_cache.transpose(0, 2, 1, 3).reshape(B, Hk, ns, sc, D)
    vc = v_cache.transpose(0, 2, 1, 3).reshape(B, Hk, ns, sc, D)

    # (B, Hk, G, ns, sc): every split computed in parallel -- C2 for decode.
    s = jnp.einsum("bhgd,bhcsd->bhgcs", qf, kc.astype(qf.dtype))
    pos = jnp.arange(S, dtype=jnp.int32).reshape(ns, sc)
    valid = pos[None] < cache_length[:, None, None]  # (B, ns, sc)
    if kv_segment_ids is not None:
        assert q_segment is not None, "packed decode needs the query's segment id"
        same_seg = kv_segment_ids.reshape(B, ns, sc) == q_segment[:, None, None]
        valid = valid & same_seg
    if window is not None:
        in_win = pos[None] >= (cache_length[:, None, None] - window)
        if sink:
            in_win = in_win | (pos[None] < sink)
        valid = valid & in_win
    s = jnp.where(valid[:, None, None], s, DEFAULT_MASK_VALUE)

    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    # Zero fully-masked splits (their m == MASK_VALUE -> p == 1 garbage).
    any_valid = jnp.any(valid, axis=-1)[:, None, None]  # (B, 1, 1, ns)
    l = jnp.where(any_valid, jnp.sum(p, axis=-1), 0.0)
    o_unscaled = jnp.einsum("bhgcs,bhcsd->bhgcd", p.astype(v_cache.dtype), vc,
                            preferred_element_type=jnp.float32)
    # Finalize each split with the shared online-softmax helper (l = 0 ->
    # lse = -inf, so fully-masked splits vanish in the merge below).
    o_part, lse_part = finalize(SoftmaxState(m=m, l=l, o=o_unscaled))

    # Merge the splits: associative combine over axis `ns`.
    o_parts = jnp.moveaxis(o_part, 3, 0)  # (ns, B, Hk, G, D)
    lse_parts = jnp.moveaxis(lse_part, 3, 0)  # (ns, B, Hk, G)
    o, lse = combine_lse_outputs(o_parts, lse_parts)
    return (
        o.reshape(B, 1, Hq, D).astype(q.dtype),
        lse.reshape(B, Hq, 1),
    )


def flash_decode_paged(
    q: jnp.ndarray,  # (B, 1, Hq, D)
    k_pages: jnp.ndarray,  # (Hkv, P, page_size, D) physical page planes
    v_pages: jnp.ndarray,
    cache_length: jnp.ndarray,  # (B,) int32 logical lengths
    block_table: jnp.ndarray,  # (B, n_pages) int32 logical -> physical page
    *,
    window: Optional[int] = None,
    sink: int = 0,
    scale: Optional[float] = None,
    num_splits: int = 8,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """XLA fallback for page-indirect decode: gather the block table's
    pages into a contiguous (B, n_pages*ps, Hkv, D) view, then run the
    plain split-KV decode. Functionally the oracle for the Pallas kernel
    (tests assert parity); positions >= cache_length are masked, so stale
    or null-page contents never contribute."""
    B = q.shape[0]
    Hk, _, ps, D = k_pages.shape
    n_pages = block_table.shape[1]
    tbl = block_table.astype(jnp.int32)
    # (Hk, B, n_pages, ps, D) -> (B, n_pages*ps, Hk, D)
    def gather(pages):
        g = pages[:, tbl]
        return jnp.moveaxis(g, 0, 3).reshape(B, n_pages * ps, Hk, D)

    return flash_decode(
        q, gather(k_pages), gather(v_pages), cache_length,
        window=window, sink=sink, scale=scale, num_splits=num_splits,
    )
