"""The 10 assigned architectures, exact configs from the public sources
cited in the assignment. One ``ModelConfig`` each; see registry.py for
lookup, shape applicability, and input specs.

Sharding notes (DESIGN.md Section 3): archs whose q-head count does not
divide the 16-way `model` axis use attn_sharding='sequence' (context
parallelism -- the mesh-level form of the paper's sequence-dimension
parallelism); the rest shard heads.
"""

from __future__ import annotations

from repro.configs.base import EncoderConfig, ModelConfig, MoEConfig, SSMConfig

WHISPER_BASE = ModelConfig(
    # [arXiv:2212.04356] enc-dec; conv/mel frontend stubbed to frame embeddings.
    name="whisper-base",
    family="encdec",
    num_layers=6,  # decoder layers; encoder below
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51_865,
    mlp="gelu",
    norm="layernorm",
    attn_bias=True,
    tie_embeddings=True,  # whisper ties the decoder unembedding
    learned_pos_embed=32_768 + 8,  # stress-sized for decode_32k (real model: 448)
    encoder=EncoderConfig(num_layers=6, max_frames=32_768),
    frontend="audio",
    rope_theta=10_000.0,  # unused (learned positions); kept for uniformity
    attn_sharding="sequence",  # 8 heads < 16-way model axis
    max_seq_len=32_768,
)

GRANITE_MOE_1B = ModelConfig(
    # [hf:ibm-granite/granite-3.0-1b-a400m-base] 32 experts, top-8.
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,  # per-expert FFN width
    vocab_size=49_155,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
    tie_embeddings=True,
    rope_theta=10_000.0,
    attn_sharding="heads",
    max_seq_len=32_768,
)

MIXTRAL_8X22B = ModelConfig(
    # [arXiv:2401.04088 / hf:mistralai] 8 experts top-2, sliding-window attn.
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=32_768,
    layer_pattern=("attn_local",),
    window=4_096,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16_384),
    rope_theta=1_000_000.0,
    attn_sharding="heads",
    max_seq_len=524_288,
)

GEMMA3_1B = ModelConfig(
    # [hf:google/gemma-3-1b-pt] 5:1 local:global, 512-token window, 1kv head.
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=("attn_local",) * 5 + ("attn",),
    window=512,
    qk_norm=True,
    tie_embeddings=True,
    embed_scale_by_dim=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    attn_sharding="sequence",  # 4 heads < 16
    max_seq_len=524_288,
)

QWEN3_8B = ModelConfig(
    # [hf:Qwen/Qwen3-8B] qk-norm GQA.
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    attn_sharding="heads",
    max_seq_len=32_768,
)

DEEPSEEK_CODER_33B = ModelConfig(
    # [arXiv:2401.14196] llama-arch dense.
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    rope_theta=100_000.0,
    attn_sharding="sequence",  # 56 heads % 16 != 0
    max_seq_len=32_768,
)

STABLELM_12B = ModelConfig(
    # [hf:stabilityai/stablelm-2-12b] per-head qk-layernorm, GQA.
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13_824,
    vocab_size=100_352,
    qk_norm=True,
    rope_theta=10_000.0,
    attn_sharding="heads",
    max_seq_len=32_768,
)

FALCON_MAMBA_7B = ModelConfig(
    # [arXiv:2410.05355] attention-free Mamba-1; B/C/dt RMS norms.
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,
    num_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=65_024,
    layer_pattern=("mamba",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256, bcdt_norm=True),
    attn_sharding="heads",  # no attention anywhere; `model` shards d_inner
    max_seq_len=524_288,
)

INTERNVL2_76B = ModelConfig(
    # [arXiv:2404.16821] InternViT (stubbed to patch embeddings) + llama3-70B-class LM.
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    frontend="vision",
    num_patches=256,  # 448px / 14 patch, 1/4 pixel-shuffle
    rope_theta=500_000.0,
    attn_sharding="heads",
    max_seq_len=32_768,
)

# Hymba: 3 full-attention layers at {first, middle, last}; the rest SWA.
# The pattern spans all 32 layers, so the stack is unrolled (num_groups=1).
_HYMBA_PATTERN = tuple(
    "hybrid_global" if i in (0, 15, 31) else "hybrid" for i in range(32)
)

HYMBA_1_5B = ModelConfig(
    # [arXiv:2411.13676] parallel attn+SSM heads, 128 meta tokens, SWA 1024.
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    layer_pattern=_HYMBA_PATTERN,
    window=1024,
    meta_tokens=128,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=100),
    rope_theta=10_000.0,
    attn_sharding="sequence",  # 25 heads % 16 != 0
    max_seq_len=524_288,
)

ALL = [
    WHISPER_BASE,
    GRANITE_MOE_1B,
    MIXTRAL_8X22B,
    GEMMA3_1B,
    QWEN3_8B,
    DEEPSEEK_CODER_33B,
    STABLELM_12B,
    FALCON_MAMBA_7B,
    INTERNVL2_76B,
    HYMBA_1_5B,
]
