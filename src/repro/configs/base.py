"""Config system: one frozen dataclass tree describes a model + its sharding.

Design notes:
  * Everything needed to build params, lower train/serve steps, and shard
    them lives here -- configs are hashable and printable, and the
    checkpoint manifest stores a fingerprint of them.
  * ``layer_pattern`` is the repeating unit of layer kinds; models scan over
    groups of the unit (HLO size independent of depth). If the pattern
    length equals ``num_layers`` the stack is unrolled (used by hymba whose
    3 global layers are at {first, middle, last}).
  * vocab is padded up to a multiple of ``vocab_pad_to`` so the `model` mesh
    axis always divides the embedding table; the loss masks padded ids.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds usable in layer_pattern:
#   'attn'         full (global) attention
#   'attn_local'   sliding-window attention (window = cfg.window)
#   'mamba'        Mamba1 SSM block (attention-free)
#   'hybrid'       Hymba-style parallel attention + SSM heads (SWA)
#   'hybrid_global'same, with global attention
LAYER_KINDS = ("attn", "attn_local", "mamba", "hybrid", "hybrid_global")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)
    bcdt_norm: bool = False  # falcon-mamba's RMSNorm on B/C/dt


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder half of an encoder-decoder stack (whisper)."""

    num_layers: int
    max_frames: int  # positional table size for the (stubbed) frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- optional architecture features -------------------------------
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None
    qk_norm: bool = False
    attn_bias: bool = False
    mlp: str = "swiglu"  # 'swiglu' | 'gelu'
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: Optional[float] = None  # gemma3: different base for SWA layers
    learned_pos_embed: Optional[int] = None  # whisper decoder: table size
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    embed_scale_by_dim: bool = False  # gemma: embeddings *= sqrt(d_model)
    meta_tokens: int = 0  # hymba: learnable always-visible prefix (sinks)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    max_seq_len: int = 524_288
    # --- modality frontend stubs --------------------------------------
    frontend: Optional[str] = None  # 'audio' | 'vision' (input_specs provides embeddings)
    num_patches: int = 0  # vision: patch embeddings prepended to the text sequence
    # --- numerics / sharding ------------------------------------------
    dtype: str = "bfloat16"  # activation/param compute dtype
    vocab_pad_to: int = 256
    # 'heads' | 'sequence' (context parallel, KV all-gathered) | 'ring'
    # (context parallel, KV sharded + rotated -- distributed/ring_attention)
    attn_sharding: str = "heads"
    scan_layers: bool = True
    remat: bool = True

    # -------------------------------------------------------------- utils
    @property
    def padded_vocab(self) -> int:
        v, m = self.vocab_size, self.vocab_pad_to
        return (v + m - 1) // m * m

    @property
    def group_size(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.group_size

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        rem = self.num_layers % self.group_size
        return self.layer_pattern[:rem]

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Kind of every layer, in order."""
        kinds = self.layer_pattern * self.num_groups + self.tail_pattern
        assert len(kinds) == self.num_layers
        return kinds

    def kind_window(self, kind: str) -> Optional[int]:
        if kind in ("attn_local", "hybrid"):
            return self.window
        return None

    def validate(self) -> None:
        assert all(k in LAYER_KINDS for k in self.layer_pattern), self.layer_pattern
        if any(k.startswith("attn") or k.startswith("hybrid") for k in self.layer_pattern):
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if any(k in ("mamba", "hybrid", "hybrid_global") for k in self.layer_pattern):
            assert self.ssm is not None, f"{self.name}: ssm config required"
        if "attn_local" in self.layer_pattern or "hybrid" in self.layer_pattern:
            assert self.window is not None, f"{self.name}: window required"
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "encdec":
            assert self.encoder is not None
        assert self.padded_vocab % self.vocab_pad_to == 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str  # 'train_4k' | 'prefill_32k' | 'decode_32k' | 'long_500k'
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
