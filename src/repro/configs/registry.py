"""Arch registry: ``--arch`` lookup, shape applicability, input/cache specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell -- weak-type-correct, shardable, no
device allocation -- exactly what ``jit(...).lower()`` needs for the
multi-pod dry-run. ``cache_specs`` mirrors the model's decode-cache pytree
structure without running prefill.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import archs
from repro.configs.base import SHAPES, ModelConfig, MoEConfig, SSMConfig, ShapeConfig

_BY_NAME = {c.name: c for c in archs.ALL}


def names():
    return list(_BY_NAME)


def get(name: str) -> ModelConfig:
    if name not in _BY_NAME:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_BY_NAME)}")
    return _BY_NAME[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# --------------------------------------------------------------------------
# Shape applicability (DESIGN.md Section 4)
# --------------------------------------------------------------------------

_PURE_FULL_ATTN = {
    "qwen3-8b",
    "deepseek-coder-33b",
    "stablelm-12b",
    "internvl2-76b",
    "granite-moe-1b-a400m",
    "whisper-base",
}


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and cfg.name in _PURE_FULL_ATTN:
        return (
            "pure full-attention arch: long_500k requires sub-quadratic "
            "attention (skip noted in DESIGN.md Section 4)"
        )
    return None


# --------------------------------------------------------------------------
# Input specs
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _enc_frames(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Encoder frame count for whisper per shape. Decode uses the 30s
    window (1500 frames) padded to 1536 so the context-parallel cache
    sharding (16-way seq split) divides evenly."""
    return 1536 if shape.kind == "decode" else shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for the given cell (train batch / prefill batch /
    decode step). Keys match launch.train/launch.serve signatures."""
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        specs = {
            "inputs": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
        if cfg.family == "encdec":
            specs["frames"] = _sds((B, _enc_frames(cfg, shape), cfg.d_model), act)
        if cfg.family == "vlm":
            specs["patches"] = _sds((B, cfg.num_patches, cfg.d_model), act)
        return specs
    if shape.kind == "prefill":
        specs = {"inputs": _sds((B, S), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = _sds((B, _enc_frames(cfg, shape), cfg.d_model), act)
        if cfg.family == "vlm":
            specs["patches"] = _sds((B, cfg.num_patches, cfg.d_model), act)
        return specs
    # decode: one token against a cache of S entries
    return {
        "token": _sds((B, 1), jnp.int32),
        "caches": cache_specs(cfg, B, S, enc_frames=_enc_frames(cfg, shape)),
        "cache_len": _sds((B,), jnp.int32),
    }


# --------------------------------------------------------------------------
# Cache specs (mirror lm.prefill / whisper.prefill output structure)
# --------------------------------------------------------------------------


def _layer_cache_spec(kind: str, cfg: ModelConfig, B: int, cache: int, act):
    kv = {
        "k": _sds((B, cache, cfg.num_kv_heads, cfg.head_dim), act),
        "v": _sds((B, cache, cfg.num_kv_heads, cfg.head_dim), act),
    }
    if kind in ("attn", "attn_local"):
        return {"kv": kv}
    if kind == "mamba":
        return {"ssm": _ssm_state_spec(cfg, B, act)}
    return {"kv": kv, "ssm": _ssm_state_spec(cfg, B, act)}


def _ssm_state_spec(cfg: ModelConfig, B: int, act):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "h": _sds((B, d_in, s.d_state), jnp.float32),
        "conv": _sds((B, s.d_conv - 1, d_in), act),
    }


def _stack(tree, n):
    return jax.tree.map(lambda x: _sds((n, *x.shape), x.dtype), tree)


def cache_specs(cfg: ModelConfig, B: int, cache: int, enc_frames: int = 1500):
    act = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        per_layer = {
            "kv": {
                "k": _sds((B, cache, cfg.num_kv_heads, cfg.head_dim), act),
                "v": _sds((B, cache, cfg.num_kv_heads, cfg.head_dim), act),
            },
            "cross": {
                "k": _sds((B, enc_frames, cfg.num_kv_heads, cfg.head_dim), act),
                "v": _sds((B, enc_frames, cfg.num_kv_heads, cfg.head_dim), act),
            },
        }
        return _stack(per_layer, cfg.num_layers)
    caches: Dict[str, Any] = {}
    if cfg.num_groups:
        group = {
            f"slot_{u}": _layer_cache_spec(k, cfg, B, cache, act)
            for u, k in enumerate(cfg.layer_pattern)
        }
        if cfg.scan_layers and cfg.num_groups > 1:
            caches["groups"] = _stack(group, cfg.num_groups)
        else:
            caches["groups"] = [group for _ in range(cfg.num_groups)]
    if cfg.tail_pattern:
        caches["tail"] = [
            _layer_cache_spec(k, cfg, B, cache, act) for k in cfg.tail_pattern
        ]
    return caches


def paged_cache_specs(cfg: ModelConfig, num_pages: int, page_size: int):
    """Cache tree for the *paged* serving engine: per-layer physical page
    planes ``(Hkv, num_pages, page_size, head_dim)`` shared by all resident
    sequences through one block table (serving/kv_pool.py). Same tree
    structure as :func:`cache_specs` (scan-stacked groups + tail) so
    lm.decode_step's scan machinery is unchanged; the leaf layout is
    kernel-native for kernels/flash_decode.flash_decode_paged_kernel (the
    contiguous path's per-step (B,S,Hk,D) -> head-major transpose is gone).
    Attention-only: a page holds no recurrent SSM state."""
    act = jnp.dtype(cfg.dtype)
    kinds = tuple(cfg.layer_pattern) + tuple(cfg.tail_pattern)
    assert cfg.family != "encdec" and cfg.ssm is None and all(
        k in ("attn", "attn_local") for k in kinds
    ), "paged caches serve attention-only decoder configs"

    def layer_spec():
        shape = (cfg.num_kv_heads, num_pages, page_size, cfg.head_dim)
        return {"kv": {"k": _sds(shape, act), "v": _sds(shape, act)}}

    caches: Dict[str, Any] = {}
    if cfg.num_groups:
        group = {f"slot_{u}": layer_spec()
                 for u in range(len(cfg.layer_pattern))}
        if cfg.scan_layers and cfg.num_groups > 1:
            caches["groups"] = _stack(group, cfg.num_groups)
        else:
            caches["groups"] = [group for _ in range(cfg.num_groups)]
    if cfg.tail_pattern:
        caches["tail"] = [layer_spec() for _ in cfg.tail_pattern]
    return caches


# --------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# --------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/features, tiny dims: one fwd/train step runs on CPU."""
    heads = min(cfg.num_heads, 4) or 1
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    kw: Dict[str, Any] = dict(
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_to=64,
        window=32 if cfg.window else None,
        meta_tokens=8 if cfg.meta_tokens else 0,
        learned_pos_embed=128 if cfg.learned_pos_embed else None,
        max_seq_len=256,
        dtype="float32",
        num_patches=4 if cfg.num_patches else 0,
    )
    unit = cfg.layer_pattern
    if len(unit) == cfg.num_layers:  # unrolled pattern (hymba): shrink it
        kinds = sorted(set(unit), reverse=True)
        pattern = tuple(kinds) + (unit[1],) * (4 - len(set(unit)))
        kw["layer_pattern"] = pattern[:4]
        kw["num_layers"] = 4
    else:
        kw["layer_pattern"] = unit
        kw["num_layers"] = len(unit) * 2 + (1 if cfg.tail_pattern else 0)
    if cfg.moe:
        kw["moe"] = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=64,
            capacity_factor=2.0,
        )
    if cfg.ssm:
        kw["ssm"] = SSMConfig(
            d_state=8, d_conv=4, expand=2,
            dt_rank=8, bcdt_norm=cfg.ssm.bcdt_norm,
        )
    if cfg.encoder:
        from repro.configs.base import EncoderConfig

        kw["encoder"] = EncoderConfig(num_layers=2, max_frames=64)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **kw)
