"""Unified host-side telemetry: metrics registry, lifecycle tracing, MFU.

Three pillars (ISSUE 8), all host-side Python around the jitted steps --
attaching any of them is guaranteed not to add compiles or perturb traced
shapes (pinned by tests/test_obs.py):

  * :mod:`repro.obs.metrics` -- counters / gauges / fixed-bucket
    histograms behind one registry with a flat-dict ``snapshot()``
    schema. Both serving engines, the KV page pool, the kernel-knob
    resolution path and the train loop register into it.
  * :mod:`repro.obs.trace`   -- span-based request-lifecycle and
    train-step event log exported as Chrome/Perfetto ``trace_event``
    JSON (``--trace-out`` on launch/serve.py and launch/train.py).
  * :mod:`repro.obs.mfu`     -- analytic model-FLOPs (utils/flops) +
    the visible-tile census folded into live achieved-vs-model FLOPs,
    tokens/s and MFU gauges for train and decode (the paper's Table 1
    metric as a counter rather than a one-off benchmark).
"""

from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    count_knob,
    default_registry,
    reset_default_registry,
)
from repro.obs.mfu import (  # noqa: F401
    DecodeEfficiency,
    TrainEfficiency,
    peak_flops,
)
from repro.obs.trace import (  # noqa: F401
    TraceRecorder,
    get_default_recorder,
    set_default_recorder,
    validate_trace,
)
