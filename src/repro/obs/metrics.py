"""Metrics registry: counters, gauges, fixed-bucket histograms.

One registry per process component (each serving engine owns one; the
train loop builds one per run) plus a process-wide *default* registry for
code that has no owner to hand it one (the kernel knob-resolution
counters). Everything is plain host-side Python -- no jax arrays, no
tracing interaction -- so attaching a registry to a jitted loop can never
add a compile or change a traced shape (tests/test_obs.py pins this).

Snapshot schema (the single flat dict every exporter consumes):

  * counter ``name``      -> ``{name: float}``
  * gauge ``name``        -> ``{name: float}`` (callable gauges are
    sampled at snapshot time; a raising sampler yields ``nan``, never an
    exception -- a metrics read must not take the server down)
  * histogram ``name``    -> ``{name/le_B: count}`` per finite bucket
    bound ``B``, plus ``{name/le_inf, name/count, name/sum}``

Names are flat ``component/metric`` strings (the same ``/`` convention as
the BENCH ledger's ``bench/config`` keys). Re-requesting a name returns
the existing instrument; re-requesting it as a *different kind* raises.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "reset_default_registry",
    "count_knob",
]


class Counter:
    """Monotonically increasing count (events, tokens, hits)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += n


class Gauge:
    """Last-written point-in-time value (occupancy, MFU, queue depth)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: cumulative-style ``le`` bucket counts.

    ``buckets`` are the finite upper bounds, ascending; an implicit
    ``+inf`` bucket always exists. ``observe(v)`` increments the count of
    every bucket whose bound is >= v (Prometheus cumulative semantics, so
    quantile estimates need no re-summing).
    """

    __slots__ = ("name", "buckets", "counts", "inf_count", "total", "sum")

    def __init__(self, name: str, buckets: Sequence[float]):
        bounds = [float(b) for b in buckets]
        if not bounds or bounds != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(
                f"histogram {name}: buckets must be non-empty, ascending, "
                f"unique finite bounds, got {buckets!r}"
            )
        self.name = name
        self.buckets = bounds
        self.counts = [0] * len(bounds)
        self.inf_count = 0
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.total += 1
        self.sum += v
        self.inf_count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1


def _fmt_bound(b: float) -> str:
    return str(int(b)) if float(b).is_integer() else repr(b)


class MetricsRegistry:
    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._gauge_fns: Dict[str, Callable[[], float]] = {}
        self._histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------- registration
    def _claim(self, name: str, kind: str) -> None:
        kinds = {
            "counter": self._counters,
            "gauge": self._gauges,
            "gauge_fn": self._gauge_fns,
            "histogram": self._histograms,
        }
        for k, store in kinds.items():
            if k != kind and name in store:
                raise ValueError(
                    f"metric {name!r} already registered as a {k}, "
                    f"cannot re-register as a {kind}"
                )

    def counter(self, name: str) -> Counter:
        self._claim(name, "counter")
        return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        self._claim(name, "gauge")
        return self._gauges.setdefault(name, Gauge(name))

    def gauge_fn(self, name: str, fn: Callable[[], float]) -> None:
        """Register a lazily sampled gauge: ``fn`` runs at snapshot time.

        The natural fit for state someone else already owns (pool
        occupancy, queue depth) -- no per-event write traffic, the
        snapshot reads the live value. Re-registering a name replaces the
        sampler (an engine rebuilt on the same registry wins).
        """
        self._claim(name, "gauge_fn")
        self._gauge_fns[name] = fn

    def histogram(self, name: str, buckets: Sequence[float]) -> Histogram:
        self._claim(name, "histogram")
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, buckets)
        elif list(h.buckets) != [float(b) for b in buckets]:
            raise ValueError(
                f"histogram {name!r} re-requested with different buckets"
            )
        return h

    # ------------------------------------------------------------ export
    def snapshot(self) -> Dict[str, float]:
        """The flat-dict schema documented in the module docstring."""
        out: Dict[str, float] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for name, fn in self._gauge_fns.items():
            try:
                out[name] = float(fn())
            except Exception:
                out[name] = math.nan
        for name, h in self._histograms.items():
            for b, n in zip(h.buckets, h.counts):
                out[f"{name}/le_{_fmt_bound(b)}"] = float(n)
            out[f"{name}/le_inf"] = float(h.inf_count)
            out[f"{name}/count"] = float(h.total)
            out[f"{name}/sum"] = h.sum
        return out

    def names(self) -> List[str]:
        return sorted(
            list(self._counters) + list(self._gauges)
            + list(self._gauge_fns) + list(self._histograms)
        )


# ---------------------------------------------------------------------------
# Process-wide default registry (kernel knob-source counters live here: the
# knob resolution path runs deep inside tracing with no registry argument).
# ---------------------------------------------------------------------------

_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _default


def reset_default_registry() -> MetricsRegistry:
    """Fresh default registry (tests); returns the new one."""
    global _default
    _default = MetricsRegistry()
    return _default


_KNOB_SOURCES = ("explicit", "tuned", "heuristic")


def count_knob(family: str, source: str, n: int = 1,
               registry: Optional[MetricsRegistry] = None) -> None:
    """Count one kernel-knob resolution hit: ``knobs/<family>/<source>``.

    ``family`` is the kernel family (``flash_pallas``, ``flash_decode``,
    ``flash_decode_paged<ps>``); ``source`` is which precedence tier won
    (explicit > tuned > heuristic). Called from
    ``kernels/ops.resolve_pallas_knobs`` and the decode-splits resolution
    at *trace* time -- each jit trace counts once, cached executions do
    not re-resolve (by design: resolution cost, like compile cost, is
    per-trace).
    """
    if source not in _KNOB_SOURCES:
        raise ValueError(f"unknown knob source {source!r}; want {_KNOB_SOURCES}")
    (registry or _default).counter(f"knobs/{family}/{source}").inc(n)
