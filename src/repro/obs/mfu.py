"""Live efficiency accounting: achieved-vs-model FLOPs, tokens/s, MFU.

The paper's headline metric (Table 1: 72% model-FLOPs utilization
end-to-end) folded into gauges a running system updates every step/tick
instead of a one-off benchmark:

  * **model FLOPs** come from the analytic formulas in
    ``utils/flops.py`` (6*N_active*D + the 12*L*H*S^2 Megatron attention
    term, causal halving deliberately NOT applied -- the literature's
    convention, and the MFU numerator the paper reports);
  * **hardware FLOPs** apply the visible-tile census
    (``utils/flops._visible_fraction``, the same oracle
    ``kernels/schedule.py`` builds its compact grids from) to the
    attention term -- causal/windowed masks shrink the work the kernels
    actually launch, so HFU > MFU on masked workloads;
  * **MFU / HFU** divide by the chip's peak FLOPs/s
    (:func:`peak_flops`: ``REPRO_PEAK_FLOPS`` env override, else a
    per-backend table).

All accounting is host-side arithmetic on numbers the loop already has
(config, cache lengths, wall time) -- nothing here touches a traced
value, so attaching a meter cannot add compiles (tests/test_obs.py).
"""

from __future__ import annotations

import os
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.obs.metrics import MetricsRegistry
from repro.utils import flops as F

__all__ = ["peak_flops", "mfu", "TrainEfficiency", "DecodeEfficiency"]

# Per-backend peak FLOPs/s (per chip). TPU matches utils/hlo_analysis
# (bf16); gpu is the paper's A100 bf16 peak; cpu is an order-of-magnitude
# figure for a few AVX cores -- on the CI host MFU is a sanity signal
# (finite, > 0), not a hardware claim. REPRO_PEAK_FLOPS overrides.
PEAK_FLOPS_BY_BACKEND: Dict[str, float] = {
    "tpu": 197e12,
    "gpu": 312e12,
    "cpu": 1e11,
}


def peak_flops(backend: Optional[str] = None) -> float:
    env = os.environ.get("REPRO_PEAK_FLOPS")
    if env:
        return float(env)
    if backend is None:
        try:
            import jax

            backend = jax.default_backend()
        except Exception:
            backend = "cpu"
    return PEAK_FLOPS_BY_BACKEND.get(backend, PEAK_FLOPS_BY_BACKEND["cpu"])


def mfu(model_flops: float, seconds: float, peak: Optional[float] = None) -> float:
    """Model-FLOPs utilization of ``model_flops`` of work done in
    ``seconds`` on one chip; 0.0 when no time has elapsed."""
    if seconds <= 0:
        return 0.0
    return model_flops / seconds / (peak or peak_flops())


def _attn_layer_dims(cfg: ModelConfig) -> Sequence[Tuple[Optional[int], int]]:
    """(window, sink) per attention-bearing layer, precomputed once."""
    dims = []
    for kind in cfg.layer_kinds():
        if kind.startswith("attn") or kind.startswith("hybrid"):
            w = cfg.kind_window(kind)
            sink = cfg.meta_tokens if (w is not None and cfg.meta_tokens) else 0
            dims.append((w, sink))
    return dims


class TrainEfficiency:
    """Per-step train gauges: ``<prefix>/mfu``, ``/hfu``, ``/tokens_per_s``.

    Model FLOPs per step are fixed by (config, batch, seq) and computed
    once; hardware FLOPs scale the attention term by the visible-tile
    fraction of each layer's mask (causal ~ 1/2, window ~ W/S) at the
    128-token tile granularity the census uses elsewhere. ``step(dt)``
    feeds one measured step; gauges report *cumulative* utilization (the
    Table 1 convention -- noise-robust), counters carry the raw totals.
    """

    def __init__(self, cfg: ModelConfig, batch_size: int, seq_len: int,
                 registry: MetricsRegistry, prefix: str = "train",
                 peak: Optional[float] = None):
        self.registry = registry
        self.prefix = prefix
        self.peak = peak or peak_flops()
        self.tokens_per_step = batch_size * seq_len
        shape = ShapeConfig("live_train", "train", seq_len, batch_size)
        self.model_flops_per_step = F.train_model_flops(cfg, shape)
        # hardware = model with each layer's attention term rescaled by
        # its visible fraction (the schedule census, bq = bk = 128 tiles)
        bq = bk = min(128, max(8, seq_len))
        t = -(-seq_len // bq)
        hw = self.model_flops_per_step
        for window, sink in _attn_layer_dims(cfg):
            kind = "window" if window is not None else "causal"
            vf = F._visible_fraction(kind, window, sink, t, t, bq, bk)
            s_eff = min(window, seq_len) if window else seq_len
            term = 12.0 * cfg.q_dim * s_eff * seq_len * batch_size
            hw -= (1.0 - vf) * term
        self.hardware_flops_per_step = hw
        self._steps = registry.counter(f"{prefix}/steps")
        self._tok = registry.counter(f"{prefix}/tokens")
        self._flops = registry.counter(f"{prefix}/model_flops")
        self._secs = registry.counter(f"{prefix}/compute_seconds")
        self._g_mfu = registry.gauge(f"{prefix}/mfu")
        self._g_hfu = registry.gauge(f"{prefix}/hfu")
        self._g_tps = registry.gauge(f"{prefix}/tokens_per_s")
        self._g_tflops = registry.gauge(f"{prefix}/model_tflops_per_s")

    def step(self, seconds: float) -> None:
        self._steps.inc()
        self._tok.inc(self.tokens_per_step)
        self._flops.inc(self.model_flops_per_step)
        self._secs.inc(seconds)
        secs = self._secs.value
        if secs > 0:
            achieved = self._flops.value / secs
            self._g_mfu.set(achieved / self.peak)
            self._g_hfu.set(
                achieved / self.peak
                * self.hardware_flops_per_step / self.model_flops_per_step
            )
            self._g_tps.set(self._tok.value / secs)
            self._g_tflops.set(achieved / 1e12)


class DecodeEfficiency:
    """Per-tick decode gauges: ``<prefix>/mfu``, ``/tokens_per_s``.

    A decode tick's model FLOPs depend on the *live* cache lengths (each
    row re-reads its whole cache), so the meter takes them per tick:
    2*N_active per live row plus the 4*d_q*L attention read per attention
    layer -- the decode analogue of ``utils/flops.decode_model_flops``
    summed over heterogeneous rows. Decode reads every cached key, so
    hardware == model FLOPs here (windows still clip).
    """

    def __init__(self, cfg: ModelConfig, registry: MetricsRegistry,
                 prefix: str = "decode", peak: Optional[float] = None):
        self.registry = registry
        self.prefix = prefix
        self.peak = peak or peak_flops()
        _, self._active_params = F.param_count(cfg)
        self._q_dim = cfg.q_dim
        self._attn_dims = _attn_layer_dims(cfg)
        self._ticks = registry.counter(f"{prefix}/ticks")
        self._tok = registry.counter(f"{prefix}/tokens")
        self._flops = registry.counter(f"{prefix}/model_flops")
        self._secs = registry.counter(f"{prefix}/compute_seconds")
        self._g_mfu = registry.gauge(f"{prefix}/mfu")
        self._g_tps = registry.gauge(f"{prefix}/tokens_per_s")

    def tick_model_flops(self, cache_lens: Sequence[int]) -> float:
        """Model FLOPs of one decode step over rows with these live cache
        lengths (zero-length rows are dead slots and charge nothing)."""
        live = [int(l) for l in cache_lens if int(l) > 0]
        total = 2.0 * self._active_params * len(live)
        for L in live:
            for window, _sink in self._attn_dims:
                s_eff = min(window, L) if window else L
                total += 4.0 * self._q_dim * s_eff
        return total

    def tick(self, cache_lens: Sequence[int], seconds: float) -> int:
        """Feed one measured decode tick; returns the live-row count."""
        live = sum(1 for l in cache_lens if int(l) > 0)
        self._ticks.inc()
        self._tok.inc(live)
        self._flops.inc(self.tick_model_flops(cache_lens))
        self._secs.inc(seconds)
        secs = self._secs.value
        if secs > 0:
            self._g_mfu.set(self._flops.value / secs / self.peak)
            self._g_tps.set(self._tok.value / secs)
        return live
