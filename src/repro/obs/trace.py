"""Span-based event log exported as Chrome/Perfetto ``trace_event`` JSON.

One :class:`TraceRecorder` per run collects events host-side (a plain
list of dicts -- no jax interaction, so recording around a jitted step
cannot add compiles) and serializes to the JSON Object Format the
Perfetto UI / ``chrome://tracing`` load directly::

    {"traceEvents": [{"name", "ph", "ts", "pid", "tid", ...}, ...],
     "displayTimeUnit": "ms"}

Event vocabulary used by the repo (DESIGN.md §9 span taxonomy):

  * serving (pid ``serve``): per-request *tracks* (tid = request id)
    carry ``queue_wait`` -> ``prefill`` -> ``decode`` complete spans plus
    ``submit`` / ``retire`` / ``preempt`` / ``resume`` instants; the
    engine track (tid 0) carries per-tick ``decode_tick`` spans,
    ``admit`` batch spans and ``page_oom`` instants.
  * training (pid ``train``): per-step ``step`` spans with nested
    ``data`` / ``compute`` / ``checkpoint`` child spans on one track.

Timestamps are microseconds from the recorder's construction
(``time.perf_counter`` based -- monotonic, so spans always nest even
across NTP adjustments). Durations use ``X`` (complete) events recorded
at span *exit* with the entry timestamp carried along: emission order
never has to match nesting order, and a crashed span simply never emits
(the trace stays schema-valid).
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

__all__ = ["TraceRecorder", "set_default_recorder", "get_default_recorder"]


class TraceRecorder:
    def __init__(self, process: str = "repro", pid: int = 1, clock=None):
        self.pid = pid
        self.events: List[dict] = []
        self._clock = clock or time.perf_counter
        self._t0 = self._clock()
        self._thread_names: Dict[int, str] = {}
        self.events.append({
            "name": "process_name", "ph": "M", "ts": 0, "pid": pid, "tid": 0,
            "args": {"name": process},
        })

    # ------------------------------------------------------------- clock
    def now_us(self) -> float:
        """Microseconds since recorder construction (event timebase)."""
        return (self._clock() - self._t0) * 1e6

    # ------------------------------------------------------------ events
    def name_thread(self, tid: int, name: str) -> None:
        """Label a track (idempotent; Perfetto shows it as the row name)."""
        if self._thread_names.get(tid) == name:
            return
        self._thread_names[tid] = name
        self.events.append({
            "name": "thread_name", "ph": "M", "ts": 0, "pid": self.pid,
            "tid": tid, "args": {"name": name},
        })

    def complete(self, name: str, tid: int, ts_us: float, dur_us: float,
                 cat: str = "repro", args: Optional[dict] = None) -> None:
        """A finished span: ``X`` event with explicit start + duration."""
        ev = {
            "name": name, "ph": "X", "ts": ts_us, "dur": max(0.0, dur_us),
            "pid": self.pid, "tid": tid, "cat": cat,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, tid: int, cat: str = "repro",
                args: Optional[dict] = None) -> None:
        ev = {
            "name": name, "ph": "i", "ts": self.now_us(), "pid": self.pid,
            "tid": tid, "cat": cat, "s": "t",
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, values: Dict[str, float], tid: int = 0,
                cat: str = "repro") -> None:
        """A ``C`` sample: Perfetto renders these as stacked area tracks."""
        self.events.append({
            "name": name, "ph": "C", "ts": self.now_us(), "pid": self.pid,
            "tid": tid, "cat": cat,
            "args": {k: float(v) for k, v in values.items()},
        })

    @contextmanager
    def span(self, name: str, tid: int = 0, cat: str = "repro",
             args: Optional[dict] = None):
        """Context-managed span; emits one ``X`` event on normal exit."""
        t0 = self.now_us()
        try:
            yield
        finally:
            self.complete(name, tid, t0, self.now_us() - t0, cat=cat, args=args)

    # ------------------------------------------------------------ export
    def to_json(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


# ---------------------------------------------------------------------------
# Process-wide default recorder. Mirrors metrics.default_registry(): code
# that runs deep inside tracing with no recorder argument (the ring
# schedule's per-step spans) emits here when a run has installed one
# (launch/train.py --trace-out), and stays silent otherwise.
# ---------------------------------------------------------------------------

_default: Optional["TraceRecorder"] = None


def set_default_recorder(rec: Optional["TraceRecorder"]) -> None:
    """Install (or clear, with ``None``) the process-wide recorder."""
    global _default
    _default = rec


def get_default_recorder() -> Optional["TraceRecorder"]:
    return _default


def validate_trace(doc: dict) -> List[dict]:
    """Schema-check a trace document; returns the event list.

    Every event must carry ``ph``/``ts``/``pid`` (the fields the Perfetto
    JSON importer requires), ``X`` events a non-negative ``dur``, and on
    each (pid, tid) track the ``X`` spans must properly nest (equal-time
    zero-duration overlaps allowed). Raises ``ValueError`` on violation.
    Used by tests and the CI smoke -- an exporter regression fails fast
    instead of producing a trace the UI silently refuses.
    """
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError("trace must be {'traceEvents': [...]}")
    events = doc["traceEvents"]
    for ev in events:
        for field in ("ph", "ts", "pid"):
            if field not in ev:
                raise ValueError(f"event missing {field!r}: {ev}")
        if ev["ph"] == "X" and not (isinstance(ev.get("dur"), (int, float))
                                    and ev["dur"] >= 0):
            raise ValueError(f"X event needs dur >= 0: {ev}")
    tracks: Dict[tuple, List[tuple]] = {}
    for ev in events:
        if ev["ph"] == "X":
            tracks.setdefault((ev["pid"], ev.get("tid", 0)), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]), ev)
            )
    for key, spans in tracks.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: List[tuple] = []
        for t0, t1, ev in spans:
            while stack and stack[-1][1] <= t0:
                stack.pop()
            if stack and t1 > stack[-1][1]:
                raise ValueError(
                    f"spans overlap without nesting on track {key}: "
                    f"{stack[-1][2].get('name')} vs {ev.get('name')}"
                )
            stack.append((t0, t1, ev))
    return events
