import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Per-cell HLO byte/FLOP breakdown -- the 'profiler' of the perf loop.

Lowers one (arch x shape) cell exactly like launch.dryrun, then reports the
trip-aware walker totals split by op kind, the largest collectives, and the
roofline terms. This is the evidence each EXPERIMENTS.md Section-Perf
iteration cites.

Usage: python -m repro.launch.analyze_cell --arch qwen3-8b --shape prefill_32k
"""

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch import dryrun  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--block-q", type=int, default=None)
    ap.add_argument("--block-kv", type=int, default=None)
    ap.add_argument("--mode", default=None)
    ap.add_argument("--save", default=None, help="also write the record to this json")
    args = ap.parse_args()

    overrides = {}
    if args.block_q:
        overrides["block_q"] = args.block_q
    if args.block_kv:
        overrides["block_kv"] = args.block_kv
    if args.mode:
        overrides["mode"] = args.mode

    rec = dryrun.lower_cell(
        args.arch, args.shape, multi_pod=args.multi_pod,
        attn_overrides=overrides or None,
    )
    rl = rec["roofline"]
    print(f"== {args.arch}::{args.shape} chips={rec['chips']} ==")
    print(f"mem/dev       {rec['memory']['bytes_per_device']/2**30:.2f} GiB")
    print(f"flops/chip    {rl['flops']:.3e}   model {rl['model_flops']:.3e} "
          f"(useful {rl['useful_ratio']:.3f})")
    print(f"hbm bytes     {rl['hbm_bytes']:.3e}")
    print(f"coll bytes    {rl['coll_bytes']:.3e}")
    print(f"t_compute     {rl['t_compute_s']:.3f} s")
    print(f"t_memory      {rl['t_memory_s']:.3f} s")
    print(f"t_collective  {rl['t_collective_s']:.3f} s")
    print(f"dominant      {rl['dominant']}   roofline_fraction {rl['roofline_fraction']:.5f}")
    fr = rec.get("flash_region") or {}
    rk = rec.get("roofline_kernel")
    if rk:
        print(f"-- kernel-substituted (deployment) roofline --")
        print(f"flash region  measured_xla={fr['measured_xla_bytes']:.3e}  "
              f"analytic_kernel={fr['analytic_kernel_bytes']:.3e}")
        print(f"t_mem {rk['t_memory_s']:.3f}s  dominant {rk['dominant']}  "
              f"fraction {rk['roofline_fraction']:.5f}")
    kinds = rec.get("bytes_by_kind") or {}
    if kinds:
        print("-- bytes by op kind (trip-aware) --")
        for k, v in sorted(kinds.items(), key=lambda kv: -kv[1]):
            print(f"  {k:24s} {v:.3e}  ({v/max(rl['hbm_bytes'],1):5.1%})")
    print("-- collectives (per-kind, single-visit) --")
    for k, v in rec["collectives"].items():
        if isinstance(v, (int, float)) and v and k not in ("count",):
            print(f"  {k:24s} {v:.3e}")
    if args.save:
        with open(args.save, "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
