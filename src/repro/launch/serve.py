"""Serving driver: load (or init) a model and run the continuous-batching
engine over a file or synthetic stream of requests.

Usage:
  python -m repro.launch.serve --arch qwen3-8b --reduce --requests 8
  python -m repro.launch.serve --arch hymba-1.5b --reduce --ckpt-dir /ck
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.models import lm
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--attn", default="flash_xla")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = registry.reduce_config(cfg)
    assert cfg.family != "encdec", "serve driver covers decoder-only families"
    params = lm.init_lm(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)
        if store.latest_step() is not None:
            (params, _), meta = store.restore((params, None))
            print(f"[serve] restored step {meta.get('step')} from {args.ckpt_dir}")

    # Knobs left at None so prefill block sizes and the decode split fan-out
    # resolve from the committed tuned cache (kernels/autotune) per shape.
    attn_cfg = AttentionConfig(impl=args.attn)
    engine = ServingEngine(cfg, params, attn_cfg, max_batch=args.max_batch,
                           cache_size=args.cache)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 1000),
                              size=int(rng.integers(2, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    finished = engine.run(max_ticks=10_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in finished.values())
    print(json.dumps({
        "requests": len(finished), "ticks": engine.ticks,
        "generated_tokens": toks, "tok_per_s": round(toks / dt, 1),
    }))
    for rid in sorted(finished)[:4]:
        print(f"  req {rid}: {finished[rid].generated}")


if __name__ == "__main__":
    main()
