"""Serving driver: load (or init) a model and run the continuous-batching
engine over a file or synthetic stream of requests.

Usage:
  python -m repro.launch.serve --arch qwen3-8b --reduce --requests 8
  python -m repro.launch.serve --arch hymba-1.5b --reduce --ckpt-dir /ck
  python -m repro.launch.serve --arch qwen3-8b --reduce --engine paged \
      --num-pages 128 --page-size 16
  python -m repro.launch.serve --arch qwen3-8b --reduce --engine paged \
      --arrival-rate 1.0 --trace-out trace.json --metrics-out metrics.json

``--engine fixed`` (default) reserves a worst-case contiguous cache slice
per slot; ``--engine paged`` serves from a shared page pool with
block-table indirect flash decode (attention-only archs).

Observability (repro.obs): every run collects the unified metrics
registry (printed as the ``metrics`` block of the JSON summary, written
to ``--metrics-out``); ``--trace-out PATH`` additionally records the
request lifecycle (submit -> queue_wait -> prefill -> per-tick decode ->
retire, plus preempt/resume) as Chrome/Perfetto ``trace_event`` JSON --
load the file at https://ui.perfetto.dev for a tick-by-tick timeline.
``--arrival-rate R`` replays a Poisson arrival process (R requests per
expected tick) instead of submitting everything upfront, so queue-wait
spans reflect admission pressure rather than a thundering herd.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.models import lm
from repro.obs import MetricsRegistry, TraceRecorder, default_registry
from repro.serving.engine import PagedServingEngine, Request, ServingEngine


def _drive_poisson(engine, requests, rate: float, seed: int,
                   max_ticks: int) -> None:
    """Submit ``requests`` on a Poisson schedule (in engine ticks) while
    ticking; an idle engine fast-forwards to the next arrival."""
    rng = np.random.default_rng(seed)
    arrivals = []
    tick = 0
    for req in requests:
        tick += int(rng.poisson(1.0 / rate))
        arrivals.append((tick, req))
    it = iter(arrivals)
    pending = next(it, None)
    while engine.ticks < max_ticks:
        while pending is not None and pending[0] <= engine.ticks:
            engine.submit(pending[1])
            pending = next(it, None)
        idle = not engine.queue and not any(
            s is not None for s in engine.slots
        )
        if idle:
            if pending is None:
                break
            engine.submit(pending[1])  # fast-forward to the next arrival
            pending = next(it, None)
            continue
        engine.tick()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--attn", default="flash_xla")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("fixed", "paged"), default="fixed")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged: pool size; default matches the fixed "
                         "engine's HBM (max_batch * cache / page_size + 1)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages-per-seq", type=int, default=None,
                    help="paged: block-table width; default cache/page_size")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrivals (requests per expected tick); "
                         "default submits every request upfront")
    ap.add_argument("--trace-out", default=None,
                    help="write the request-lifecycle Perfetto trace here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot (JSON) here")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = registry.reduce_config(cfg)
    assert cfg.family != "encdec", "serve driver covers decoder-only families"
    params = lm.init_lm(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)
        if store.latest_step() is not None:
            (params, _), meta = store.restore((params, None))
            print(f"[serve] restored step {meta.get('step')} from {args.ckpt_dir}")

    # Knobs left at None so prefill block sizes and the decode split fan-out
    # resolve from the committed tuned cache (kernels/autotune) per shape.
    attn_cfg = AttentionConfig(impl=args.attn)
    obs_registry = MetricsRegistry()
    tracer = TraceRecorder(process=f"serve:{args.engine}") if args.trace_out else None
    if args.engine == "paged":
        num_pages = args.num_pages or (
            args.max_batch * args.cache // args.page_size + 1
        )
        n_max = args.pages_per_seq or max(1, args.cache // args.page_size)
        engine = PagedServingEngine(
            cfg, params, attn_cfg, max_batch=args.max_batch,
            num_pages=num_pages, page_size=args.page_size,
            pages_per_seq_max=n_max, registry=obs_registry, tracer=tracer,
        )
    else:
        engine = ServingEngine(cfg, params, attn_cfg, max_batch=args.max_batch,
                               cache_size=args.cache,
                               registry=obs_registry, tracer=tracer)
    rng = np.random.default_rng(args.seed)
    requests = [
        Request(rid=rid,
                prompt=rng.integers(1, min(cfg.vocab_size, 1000),
                                    size=int(rng.integers(2, 12))).tolist(),
                max_new_tokens=args.max_new)
        for rid in range(args.requests)
    ]

    t0 = time.perf_counter()
    if args.arrival_rate:
        _drive_poisson(engine, requests, args.arrival_rate, args.seed,
                       max_ticks=10_000)
    else:
        for req in requests:
            engine.submit(req)
        engine.run(max_ticks=10_000)
    dt = time.perf_counter() - t0
    finished = engine.finished
    toks = sum(len(r.generated) for r in finished.values())
    snap = engine.snapshot()
    # the kernel knob-source counters live on the process-wide default
    # registry (they increment deep inside tracing); fold them in so the
    # exported snapshot answers "which tier did that kernel launch with"
    snap.update(default_registry().snapshot())
    summary = {
        "engine": args.engine, "requests": len(finished),
        "ticks": engine.ticks, "generated_tokens": toks,
        "tok_per_s": round(toks / dt, 1),
        "decode_compiles": engine.decode_compiles,
        "decode_mfu": snap["decode/mfu"],
        "decode_tok_per_s": round(snap["decode/tokens_per_s"], 1),
    }
    if args.engine == "paged":
        summary["preemptions"] = engine.preemptions
    print(json.dumps(summary))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[serve] wrote metrics snapshot to {args.metrics_out}")
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"[serve] wrote Perfetto trace ({len(tracer.events)} events) "
              f"to {args.trace_out}")
    for rid in sorted(finished)[:4]:
        print(f"  req {rid}: {finished[rid].generated}")


if __name__ == "__main__":
    main()
