"""Serving driver: load (or init) a model and run the continuous-batching
engine over a file or synthetic stream of requests.

Usage:
  python -m repro.launch.serve --arch qwen3-8b --reduce --requests 8
  python -m repro.launch.serve --arch hymba-1.5b --reduce --ckpt-dir /ck
  python -m repro.launch.serve --arch qwen3-8b --reduce --engine paged \
      --num-pages 128 --page-size 16

``--engine fixed`` (default) reserves a worst-case contiguous cache slice
per slot; ``--engine paged`` serves from a shared page pool with
block-table indirect flash decode (attention-only archs).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.models import lm
from repro.serving.engine import PagedServingEngine, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=256)
    ap.add_argument("--attn", default="flash_xla")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("fixed", "paged"), default="fixed")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged: pool size; default matches the fixed "
                         "engine's HBM (max_batch * cache / page_size + 1)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pages-per-seq", type=int, default=None,
                    help="paged: block-table width; default cache/page_size")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduce:
        cfg = registry.reduce_config(cfg)
    assert cfg.family != "encdec", "serve driver covers decoder-only families"
    params = lm.init_lm(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        store = CheckpointStore(args.ckpt_dir)
        if store.latest_step() is not None:
            (params, _), meta = store.restore((params, None))
            print(f"[serve] restored step {meta.get('step')} from {args.ckpt_dir}")

    # Knobs left at None so prefill block sizes and the decode split fan-out
    # resolve from the committed tuned cache (kernels/autotune) per shape.
    attn_cfg = AttentionConfig(impl=args.attn)
    if args.engine == "paged":
        num_pages = args.num_pages or (
            args.max_batch * args.cache // args.page_size + 1
        )
        n_max = args.pages_per_seq or max(1, args.cache // args.page_size)
        engine = PagedServingEngine(
            cfg, params, attn_cfg, max_batch=args.max_batch,
            num_pages=num_pages, page_size=args.page_size,
            pages_per_seq_max=n_max,
        )
    else:
        engine = ServingEngine(cfg, params, attn_cfg, max_batch=args.max_batch,
                               cache_size=args.cache)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 1000),
                              size=int(rng.integers(2, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    finished = engine.run(max_ticks=10_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in finished.values())
    summary = {
        "engine": args.engine, "requests": len(finished),
        "ticks": engine.ticks, "generated_tokens": toks,
        "tok_per_s": round(toks / dt, 1),
    }
    if args.engine == "paged":
        summary["decode_compiles"] = engine.decode_compiles
        summary["preemptions"] = engine.preemptions
    print(json.dumps(summary))
    for rid in sorted(finished)[:4]:
        print(f"  req {rid}: {finished[rid].generated}")


if __name__ == "__main__":
    main()
