"""Production trainer driver: data pipeline -> sharded train step ->
supervised checkpoint/restart -> telemetry. The end-to-end entry point
(examples/train_gpt.py is a thin wrapper).

The loop runs *under* training.fault_tolerance.run_with_restarts:
  * restore_fn owns a whole incarnation -- it (re-)enters the mesh
    context, re-jits the step, restores the latest durable checkpoint
    onto the *current* mesh (per-shard elastic restore via
    distributed/params.tree_shardings) and reseats the packed-data
    stream position; a step failure replays from there,
  * per-shard async atomic saves on a Young/Daly cadence fed the
    worker's *actual* write duration (store.drain_write_stats),
  * a SIGTERM/SIGINT grace handler (the preemption notice): finish the
    in-flight step, drain the async writer, write a final checkpoint,
    exit cleanly,
  * --fault-plan injects deterministic faults (training/fault_injection)
    for end-to-end recovery drills,
  * StepMonitor straggler telemetry + NaN step-skip inside apply_updates.

Usage:
  python -m repro.launch.train --arch qwen3-8b --reduce --steps 100
  python -m repro.launch.train --preset gpt-100m --steps 300 --seq 512
  python -m repro.launch.train --preset gpt-20m --ckpt-dir /tmp/ckpt \\
      --fault-plan raise@5,corrupt@8   # recovery drill
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import signal
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore, _flatten
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.data.pipeline import DataConfig, make_source
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.training.fault_injection import FaultPlan
from repro.training.fault_tolerance import CheckpointCadence, run_with_restarts
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.utils import flops as F

PRESETS: Dict[str, ModelConfig] = {
    # ~verifiable-on-CPU GPT-style models (paper Table 1 scale ladder)
    "gpt-20m": ModelConfig(
        name="gpt-20m", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=1024,
        vocab_size=8192, vocab_pad_to=256, dtype="float32", remat=False,
    ),
    "gpt-100m": ModelConfig(
        name="gpt-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=32768, vocab_pad_to=256, dtype="float32", remat=False,
    ),
}


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    seq_len: int = 512
    batch_size: int = 8
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    # ckpt_every is a FLOOR on checkpoint spacing (a minimum number of
    # steps between saves); above it the Young/Daly interval computed
    # from mtbf_seconds and the observed write cost decides when to
    # actually save. Small mtbf_seconds => save at every floor boundary
    # (what the deterministic kill-and-resume tests use).
    ckpt_every: int = 50
    mtbf_seconds: float = 3600.0
    max_restarts: int = 3
    # Deterministic fault injection: a FaultPlan or a plan spec string
    # ("raise@5,corrupt@8" -- training/fault_injection.py grammar).
    fault_plan: Optional[Any] = None
    history_out: Optional[str] = None
    attn_impl: str = "flash_xla"
    log_every: int = 10
    seed: int = 0
    packed: bool = False  # varlen sequence packing (segment-masked attention)
    # Mesh: model_axis > 1 (or data_axis > 1) builds a (data, model) host
    # mesh and installs sharding rules for the run. data_axis = 0 derives
    # the data axis as devices / model_axis; > 0 pins it (the 2D
    # data x ring composition -- batch over 'data', ring context
    # parallelism over 'model' inside each data group). attn_sharding
    # overrides the arch default: 'heads' | 'sequence' (all-gather
    # context parallel) | 'ring' (KV-sharded context parallel --
    # distributed/ring_attention.py).
    model_axis: int = 1
    data_axis: int = 0
    attn_sharding: Optional[str] = None
    # Observability (repro.obs): metrics always collect into `registry`
    # (or a fresh one); trace_out records step -> data/compute/checkpoint
    # spans as Perfetto JSON. Both host-side: zero extra compiles.
    trace_out: Optional[str] = None
    metrics_out: Optional[str] = None
    registry: Optional[Any] = None


def resolve_model(arch: Optional[str], preset: Optional[str], reduce: bool) -> ModelConfig:
    if preset:
        return PRESETS[preset]
    assert arch, "--arch or --preset required"
    cfg = registry.get(arch)
    return registry.reduce_config(cfg) if reduce else cfg


def _mesh_context(cfg: ModelConfig, loop: TrainLoopConfig):
    """The sharding context for the run: a (data, model) host mesh +
    lm_rules when model_axis > 1 (or data_axis pinned > 1), else a no-op.
    Entered around tracing AND execution so `constrain` / the
    ring-attention route see the rules. ``--data-axis N --model-axis M``
    composes batch/FSDP data parallelism with ring/sequence context
    parallelism on the same (N, M) mesh."""
    if loop.model_axis <= 1 and loop.data_axis <= 1:
        return contextlib.nullcontext()
    from repro.distributed.sharding import lm_rules, use_rules
    from repro.launch.mesh import make_host_mesh, make_long_context_mesh

    if loop.data_axis > 0:
        mesh = make_long_context_mesh(loop.data_axis, loop.model_axis)
    else:
        mesh = make_host_mesh(model_axis=loop.model_axis)
    rules = lm_rules(cfg, model_axis=loop.model_axis,
                     data_axis=mesh.shape["data"],
                     batch_size=loop.batch_size)
    stack = contextlib.ExitStack()
    stack.enter_context(mesh)
    stack.enter_context(use_rules(mesh, rules))
    print(f"[train] mesh {dict(mesh.shape)} attn_sharding={cfg.attn_sharding}")
    return stack


def train(cfg: ModelConfig, loop: TrainLoopConfig, opt_cfg: Optional[AdamWConfig] = None):
    """Run the loop; returns (params, opt_state, history dict).

    The mesh context is NOT entered here: the supervisor's restore_fn
    enters (and on restart re-enters) _mesh_context per incarnation, so
    a restore genuinely re-forms the mesh."""
    if loop.attn_sharding is not None:
        if loop.model_axis <= 1:
            raise ValueError(
                f"--attn-sharding {loop.attn_sharding} needs --model-axis > 1 "
                "(no mesh is built otherwise, so the flag would do nothing)"
            )
        # Applied to THE cfg (not a rules-local copy) so everything
        # cfg-derived downstream (flops accounting, rules) sees the mode.
        cfg = dataclasses.replace(cfg, attn_sharding=loop.attn_sharding)
    return _train(cfg, loop, opt_cfg)


class _GraceHandler:
    """SIGTERM/SIGINT -> graceful stop flag (the preemption notice).

    First signal sets the flag: the loop finishes the in-flight step,
    drains the async writer, writes a final checkpoint and exits
    cleanly. A second signal escalates (KeyboardInterrupt). Installing
    outside the main thread (tests calling train() from a worker) is a
    silent no-op -- the flag just never fires.
    """

    def __init__(self):
        self.flag = False
        self._prev: Dict[int, Any] = {}

    def _on(self, signum, frame):
        if self.flag:
            raise KeyboardInterrupt(f"second signal {signum}: hard stop")
        self.flag = True
        print(f"[train] caught {signal.Signals(signum).name}: finishing step, "
              "draining async save, writing final checkpoint", flush=True)

    def install(self) -> "_GraceHandler":
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._prev[sig] = signal.signal(sig, self._on)
            except ValueError:  # not the main thread
                pass
        return self

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev = {}


def _current_sharding_fn(template):
    """Elastic-restore placement for the *current* mesh: leaf key ->
    NamedSharding from distributed/params.tree_shardings under the active
    rules, or None outside a mesh context (plain device_put)."""
    from repro.distributed import sharding as dist_sharding

    state = dist_sharding.current()
    if state is None:
        return None, None
    from repro.distributed.params import tree_shardings

    mesh, rules = state
    shardings = tree_shardings(template, mesh, rules)
    table = dict(_flatten(shardings))
    return (lambda key, spec: table.get(key)), shardings


def _train(cfg: ModelConfig, loop: TrainLoopConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop.steps)
    # Block sizes left at None so training picks up tuned knobs (or the
    # shape-aware heuristics) per shape instead of a hardcoded 256.
    attn_cfg = AttentionConfig(impl=loop.attn_impl, mode="auto")
    data = make_source(DataConfig(
        batch_size=loop.batch_size, seq_len=loop.seq_len,
        vocab_size=cfg.vocab_size, seed=loop.seed,
        source="packed" if loop.packed else "synthetic",
    ))
    fault_plan = loop.fault_plan
    if isinstance(fault_plan, str):
        fault_plan = FaultPlan.parse(fault_plan, seed=loop.seed)

    cadence = CheckpointCadence(loop.mtbf_seconds, min_interval_steps=loop.ckpt_every)
    n_params, _ = F.param_count(cfg)

    # Telemetry (repro.obs): registry + MFU meter always on (host-side
    # arithmetic around the jitted step -- the jaxpr is pinned identical
    # with/without them by tests/test_obs.py); span tracing when asked.
    from repro.obs import MetricsRegistry, TraceRecorder, TrainEfficiency

    obs = loop.registry if loop.registry is not None else MetricsRegistry()
    eff = TrainEfficiency(cfg, loop.batch_size, loop.seq_len, obs)
    c_stragglers = obs.counter("train/stragglers")
    c_ckpts = obs.counter("train/checkpoints")
    c_preempt = obs.counter("train/preemptions")
    obs.counter("train/restarts")  # pre-register: snapshot carries 0
    g_loss = obs.gauge("train/loss")
    tracer = TraceRecorder(process="train") if loop.trace_out else None
    if tracer is not None:
        # Ring attention + the checkpoint store emit spans into the
        # process default recorder (obs.trace); install this run's
        # recorder so they land in the same --trace-out file.
        from repro.obs import set_default_recorder

        set_default_recorder(tracer)

    store = CheckpointStore(loop.ckpt_dir, registry=obs,
                            fault_plan=fault_plan) if loop.ckpt_dir else None

    loss_by_step: Dict[int, float] = {}
    time_by_step: Dict[int, float] = {}
    history = {"loss": [], "step_time": [], "stragglers": 0,
               "restored_at": 0, "restarts": 0, "preempted": False,
               "registry": obs}
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{loop.steps} steps x {loop.batch_size}x{loop.seq_len} tokens, attn={loop.attn_impl}")

    # --- incarnation: everything a restart must rebuild --------------------
    # restore_fn owns it: close the old mesh context, re-enter
    # _mesh_context (re-forming the mesh), re-jit the step, restore the
    # latest durable checkpoint onto the *current* mesh, reseat the data
    # stream. The same path serves cold start, in-process replay after a
    # step failure, and the elastic relaunch after a preemption.
    inc: Dict[str, Any] = {"ctx": None, "step_fn": None, "restores": 0}

    def _close_incarnation():
        if inc["ctx"] is not None:
            inc["ctx"].__exit__(None, None, None)
            inc["ctx"] = None

    def restore_fn():
        if store is not None:
            # Drain the in-flight async write before listing steps: the
            # worker renames + GCs concurrently, and a half-written .tmp
            # must never race the restore scan. A *failed* write was
            # already surfaced (warning + ckpt/async_failures); it must
            # not abort the restart itself.
            try:
                store.wait()
            except RuntimeError:
                pass
        _close_incarnation()
        inc["ctx"] = _mesh_context(cfg, loop)
        inc["ctx"].__enter__()
        inc["step_fn"] = jax.jit(build_train_step(
            cfg, attn_cfg, opt_cfg, microbatches=loop.microbatches, ce_chunk=512,
        ))
        params = lm.init_lm(cfg, jax.random.PRNGKey(loop.seed))
        opt_state = init_opt_state(params)
        sharding_fn, shardings = _current_sharding_fn((params, opt_state))
        if shardings is not None:
            # Place the fresh init per the rules so every save (including
            # one before the first step output) is per-shard.
            params, opt_state = jax.tree.map(
                jax.device_put, (params, opt_state), shardings)
        start_step = 0
        if store is not None and store.steps():
            try:
                (params, opt_state), meta = store.restore(
                    (params, opt_state), sharding_fn=sharding_fn)
                start_step = meta["step"]
                data.restore(meta["data"])
                print(f"[train] restored step {start_step} from {loop.ckpt_dir}")
            except FileNotFoundError as e:
                import warnings

                warnings.warn(
                    f"every checkpoint in {loop.ckpt_dir} failed validation "
                    f"({e}); starting FRESH from step 0")
        if inc["restores"] == 0:
            history["restored_at"] = start_step
        inc["restores"] += 1
        return start_step, (params, opt_state)

    def step_body(step, state):
        params, opt_state = state
        if fault_plan is not None:
            fault_plan.fire_step(step)
        t_step0 = tracer.now_us() if tracer else 0.0
        t_data0 = time.perf_counter()
        out = data.batch(step)
        if not isinstance(out, dict):
            out = {"inputs": out[0], "targets": out[1]}
        batch = {k: jnp.asarray(v) for k, v in out.items()}
        t_data = time.perf_counter() - t_data0
        t_c0 = time.perf_counter()
        params, opt_state, metrics = inc["step_fn"](params, opt_state, batch)
        loss = float(metrics["loss"])
        t_compute = time.perf_counter() - t_c0
        loss_by_step[step] = loss
        time_by_step[step] = t_compute
        eff.step(t_compute)
        g_loss.set(loss)
        if tracer:
            tracer.complete("data", 0, t_step0, t_data * 1e6)
            tracer.complete("compute", 0, t_step0 + t_data * 1e6,
                            t_compute * 1e6, args={"loss": loss, "step": step})
            tracer.complete("step", 0, t_step0, tracer.now_us() - t_step0,
                            args={"step": step})
        if step % loop.log_every == 0 or step == loop.steps - 1:
            snap = obs.snapshot()
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"{snap['train/tokens_per_s']:8.0f} tok/s "
                  f"mfu {snap['train/mfu']:.4f}", flush=True)
        if store is not None:
            # Young/Daly write cost = the worker's actual wall duration
            # (the blocking save() call only measures the snapshot).
            for _s, dt in store.drain_write_stats():
                cadence.observe_write(dt)
        return params, opt_state

    def save_fn(step, state):
        if store is None:
            return
        t_ckpt0 = time.perf_counter()
        t_ckpt0_us = tracer.now_us() if tracer else 0.0
        data_state = dict(data.state())
        data_state["step"] = step
        store.save(step, state,
                   meta={"step": step, "data": data_state,
                         "config": cfg.name}, async_=True)
        c_ckpts.inc()
        if tracer:
            # the *blocking* portion only: local-shard snapshot + handoff
            tracer.complete("checkpoint", 0, t_ckpt0_us,
                            (time.perf_counter() - t_ckpt0) * 1e6,
                            args={"step": step})

    grace = _GraceHandler().install()
    try:
        (params, opt_state), restarts, telem = run_with_restarts(
            step_body, restore_fn, save_fn,
            total_steps=loop.steps, cadence=cadence,
            max_restarts=loop.max_restarts,
            should_stop=lambda: grace.flag, registry=obs,
        )
    finally:
        grace.uninstall()
        if store is not None:
            store.wait()  # drain the in-flight async save
        _close_incarnation()
    for _s, dt in store.drain_write_stats() if store is not None else ():
        cadence.observe_write(dt)
    if telem["preempted"]:
        c_preempt.inc()
        print(f"[train] preempted: drained async writer; final checkpoint at "
              f"step {telem['last_step']}", flush=True)

    done = sorted(loss_by_step)
    history["loss"] = [loss_by_step[s] for s in done]
    history["step_time"] = [time_by_step[s] for s in done]
    history["steps"] = done
    history["restarts"] = restarts
    history["preempted"] = telem["preempted"]
    history["stragglers"] = len(telem["stragglers"])
    for _ in telem["stragglers"]:
        c_stragglers.inc()
    if loop.history_out:
        with open(loop.history_out, "w") as f:
            json.dump({"loss": history["loss"], "steps": done,
                       "restored_at": history["restored_at"],
                       "restarts": restarts,
                       "preempted": history["preempted"]}, f)
        print(f"[train] wrote loss history to {loop.history_out}")
    if loop.metrics_out:
        from repro.obs import default_registry

        snap = obs.snapshot()
        snap.update(default_registry().snapshot())  # kernel knob counters
        with open(loop.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[train] wrote metrics snapshot to {loop.metrics_out}")
    if tracer is not None:
        from repro.obs import set_default_recorder

        set_default_recorder(None)
        tracer.save(loop.trace_out)
        print(f"[train] wrote Perfetto trace ({len(tracer.events)} events) "
              f"to {loop.trace_out}")
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--preset", default=None, choices=sorted(PRESETS))
    ap.add_argument("--reduce", action="store_true", help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn", default="flash_xla", choices=("ref", "flash_xla", "flash_pallas"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="FLOOR on checkpoint spacing in steps; above it "
                         "the Young/Daly interval (from --mtbf and the "
                         "observed async write cost) decides when to save")
    ap.add_argument("--mtbf", type=float, default=3600.0,
                    help="assumed mean time between failures (seconds) for "
                         "the Young/Daly checkpoint interval; tiny values "
                         "pin saves to every --ckpt-every boundary")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="in-process supervisor restarts before giving up")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection, e.g. "
                         "'raise@5,corrupt@8' (kinds: raise, sigterm, "
                         "sigkill, abort, torn, trunc, drop, corrupt)")
    ap.add_argument("--history-out", default=None,
                    help="write the per-step loss history + restore "
                         "telemetry (JSON) here -- what the "
                         "kill-and-resume continuity checks diff")
    ap.add_argument("--packed", action="store_true",
                    help="varlen sequence packing (segment-masked attention)")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="model-axis width of the (data, model) host mesh")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-axis width of the (data, model) mesh; 0 "
                         "derives it as devices / model-axis. Composes "
                         "batch/FSDP parallelism with the ring: "
                         "--data-axis 2 --model-axis 4 runs two 4-wide "
                         "rings side by side on 8 devices")
    ap.add_argument("--attn-sharding", default=None,
                    choices=("heads", "sequence", "ring"),
                    help="override the arch's attention sharding strategy")
    ap.add_argument("--trace-out", default=None,
                    help="write step/data/compute/checkpoint spans as "
                         "Perfetto trace_event JSON here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot (JSON) here")
    args = ap.parse_args()

    cfg = resolve_model(args.arch, args.preset, args.reduce)
    loop = TrainLoopConfig(
        steps=args.steps, seq_len=args.seq, batch_size=args.batch,
        microbatches=args.microbatches, attn_impl=args.attn, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, mtbf_seconds=args.mtbf,
        max_restarts=args.max_restarts, fault_plan=args.fault_plan,
        history_out=args.history_out,
        packed=args.packed, model_axis=args.model_axis,
        data_axis=args.data_axis, attn_sharding=args.attn_sharding,
        trace_out=args.trace_out, metrics_out=args.metrics_out,
    )
    _, _, history = train(cfg, loop)
    first = np.mean(history["loss"][:5]) if history["loss"] else float("nan")
    last = np.mean(history["loss"][-5:]) if history["loss"] else float("nan")
    snap = history["registry"].snapshot()
    print(json.dumps({"first5_loss": round(float(first), 4),
                      "last5_loss": round(float(last), 4),
                      "median_step_s": round(float(np.median(history['step_time'])), 4),
                      "stragglers": history["stragglers"],
                      "mfu": snap.get("train/mfu"),
                      "tokens_per_s": round(snap.get("train/tokens_per_s", 0.0), 1)}))


if __name__ == "__main__":
    main()
