"""Production trainer driver: data pipeline -> sharded train step ->
checkpoint/restart -> telemetry. The end-to-end entry point
(examples/train_gpt.py is a thin wrapper).

Wires every fault-tolerance piece from training/fault_tolerance.py:
  * restore-from-latest on start (elastic: the checkpoint restores onto
    whatever mesh is current),
  * async atomic saves on a Young/Daly cadence,
  * StepMonitor straggler telemetry,
  * NaN step-skip inside apply_updates.

Usage:
  python -m repro.launch.train --arch qwen3-8b --reduce --steps 100
  python -m repro.launch.train --preset gpt-100m --steps 300 --seq 512
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.data.pipeline import DataConfig, make_source
from repro.launch.steps import build_train_step
from repro.models import lm
from repro.training.fault_tolerance import CheckpointCadence, StepMonitor
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.utils import flops as F

PRESETS: Dict[str, ModelConfig] = {
    # ~verifiable-on-CPU GPT-style models (paper Table 1 scale ladder)
    "gpt-20m": ModelConfig(
        name="gpt-20m", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=4, head_dim=64, d_ff=1024,
        vocab_size=8192, vocab_pad_to=256, dtype="float32", remat=False,
    ),
    "gpt-100m": ModelConfig(
        name="gpt-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=32768, vocab_pad_to=256, dtype="float32", remat=False,
    ),
}


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    seq_len: int = 512
    batch_size: int = 8
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    mtbf_seconds: float = 3600.0
    attn_impl: str = "flash_xla"
    log_every: int = 10
    seed: int = 0
    packed: bool = False  # varlen sequence packing (segment-masked attention)
    # Mesh: model_axis > 1 (or data_axis > 1) builds a (data, model) host
    # mesh and installs sharding rules for the run. data_axis = 0 derives
    # the data axis as devices / model_axis; > 0 pins it (the 2D
    # data x ring composition -- batch over 'data', ring context
    # parallelism over 'model' inside each data group). attn_sharding
    # overrides the arch default: 'heads' | 'sequence' (all-gather
    # context parallel) | 'ring' (KV-sharded context parallel --
    # distributed/ring_attention.py).
    model_axis: int = 1
    data_axis: int = 0
    attn_sharding: Optional[str] = None
    # Observability (repro.obs): metrics always collect into `registry`
    # (or a fresh one); trace_out records step -> data/compute/checkpoint
    # spans as Perfetto JSON. Both host-side: zero extra compiles.
    trace_out: Optional[str] = None
    metrics_out: Optional[str] = None
    registry: Optional[Any] = None


def resolve_model(arch: Optional[str], preset: Optional[str], reduce: bool) -> ModelConfig:
    if preset:
        return PRESETS[preset]
    assert arch, "--arch or --preset required"
    cfg = registry.get(arch)
    return registry.reduce_config(cfg) if reduce else cfg


def _mesh_context(cfg: ModelConfig, loop: TrainLoopConfig):
    """The sharding context for the run: a (data, model) host mesh +
    lm_rules when model_axis > 1 (or data_axis pinned > 1), else a no-op.
    Entered around tracing AND execution so `constrain` / the
    ring-attention route see the rules. ``--data-axis N --model-axis M``
    composes batch/FSDP data parallelism with ring/sequence context
    parallelism on the same (N, M) mesh."""
    if loop.model_axis <= 1 and loop.data_axis <= 1:
        return contextlib.nullcontext()
    from repro.distributed.sharding import lm_rules, use_rules
    from repro.launch.mesh import make_host_mesh, make_long_context_mesh

    if loop.data_axis > 0:
        mesh = make_long_context_mesh(loop.data_axis, loop.model_axis)
    else:
        mesh = make_host_mesh(model_axis=loop.model_axis)
    rules = lm_rules(cfg, model_axis=loop.model_axis,
                     data_axis=mesh.shape["data"],
                     batch_size=loop.batch_size)
    stack = contextlib.ExitStack()
    stack.enter_context(mesh)
    stack.enter_context(use_rules(mesh, rules))
    print(f"[train] mesh {dict(mesh.shape)} attn_sharding={cfg.attn_sharding}")
    return stack


def train(cfg: ModelConfig, loop: TrainLoopConfig, opt_cfg: Optional[AdamWConfig] = None):
    """Run the loop; returns (params, opt_state, history dict)."""
    if loop.attn_sharding is not None:
        if loop.model_axis <= 1:
            raise ValueError(
                f"--attn-sharding {loop.attn_sharding} needs --model-axis > 1 "
                "(no mesh is built otherwise, so the flag would do nothing)"
            )
        # Applied to THE cfg (not a rules-local copy) so everything
        # cfg-derived downstream (flops accounting, rules) sees the mode.
        cfg = dataclasses.replace(cfg, attn_sharding=loop.attn_sharding)
    with _mesh_context(cfg, loop):
        return _train(cfg, loop, opt_cfg)


def _train(cfg: ModelConfig, loop: TrainLoopConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop.steps)
    # Block sizes left at None so training picks up tuned knobs (or the
    # shape-aware heuristics) per shape instead of a hardcoded 256.
    attn_cfg = AttentionConfig(impl=loop.attn_impl, mode="auto")
    data = make_source(DataConfig(
        batch_size=loop.batch_size, seq_len=loop.seq_len,
        vocab_size=cfg.vocab_size, seed=loop.seed,
        source="packed" if loop.packed else "synthetic",
    ))
    step_fn = jax.jit(build_train_step(
        cfg, attn_cfg, opt_cfg, microbatches=loop.microbatches, ce_chunk=512,
    ))

    store = CheckpointStore(loop.ckpt_dir) if loop.ckpt_dir else None
    start_step = 0
    params = lm.init_lm(cfg, jax.random.PRNGKey(loop.seed))
    opt_state = init_opt_state(params)
    if store is not None and store.latest_step() is not None:
        (params, opt_state), meta = store.restore((params, opt_state))
        start_step = meta["step"]
        data.restore(meta["data"])
        print(f"[train] restored step {start_step} from {loop.ckpt_dir}")

    monitor = StepMonitor()
    cadence = CheckpointCadence(loop.mtbf_seconds, min_interval_steps=loop.ckpt_every)
    n_params, _ = F.param_count(cfg)

    # Telemetry (repro.obs): registry + MFU meter always on (host-side
    # arithmetic around the jitted step -- the jaxpr is pinned identical
    # with/without them by tests/test_obs.py); span tracing when asked.
    from repro.obs import MetricsRegistry, TraceRecorder, TrainEfficiency

    obs = loop.registry if loop.registry is not None else MetricsRegistry()
    eff = TrainEfficiency(cfg, loop.batch_size, loop.seq_len, obs)
    c_stragglers = obs.counter("train/stragglers")
    c_ckpts = obs.counter("train/checkpoints")
    g_loss = obs.gauge("train/loss")
    tracer = TraceRecorder(process="train") if loop.trace_out else None
    if tracer is not None:
        # Ring attention emits per-step spans + hop instants into the
        # process default recorder at trace time (obs.trace); install this
        # run's recorder so they land in the same --trace-out file.
        from repro.obs import set_default_recorder

        set_default_recorder(tracer)

    history = {"loss": [], "step_time": [], "stragglers": 0,
               "restored_at": start_step, "registry": obs}
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{loop.steps} steps x {loop.batch_size}x{loop.seq_len} tokens, attn={loop.attn_impl}")

    for step in range(start_step, loop.steps):
        t_step0 = tracer.now_us() if tracer else 0.0
        t_data0 = time.perf_counter()
        out = data.batch(step)
        if not isinstance(out, dict):
            out = {"inputs": out[0], "targets": out[1]}
        batch = {k: jnp.asarray(v) for k, v in out.items()}
        t_data = time.perf_counter() - t_data0
        monitor.start()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        ev = monitor.stop()
        if ev is not None:
            history["stragglers"] += 1
            c_stragglers.inc()
        history["loss"].append(loss)
        history["step_time"].append(monitor.times[-1])
        eff.step(monitor.times[-1])
        g_loss.set(loss)
        if tracer:
            tracer.complete("data", 0, t_step0, t_data * 1e6)
            tracer.complete("compute", 0, t_step0 + t_data * 1e6,
                            monitor.times[-1] * 1e6,
                            args={"loss": loss, "step": step})
        if step % loop.log_every == 0 or step == loop.steps - 1:
            snap = obs.snapshot()
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"{snap['train/tokens_per_s']:8.0f} tok/s "
                  f"mfu {snap['train/mfu']:.4f}", flush=True)
        t_ckpt0, t_ckpt0_us = time.perf_counter(), (tracer.now_us() if tracer else 0.0)
        if store is not None and cadence.should_checkpoint(step + 1, monitor.median):
            data_state = dict(data.state())
            data_state["step"] = step + 1
            store.save(step + 1, (params, opt_state),
                       meta={"step": step + 1, "data": data_state,
                             "config": cfg.name}, async_=True)
            cadence.observe_write(time.perf_counter() - t_ckpt0)
            cadence.mark()
            c_ckpts.inc()
            if tracer:
                tracer.complete("checkpoint", 0, t_ckpt0_us,
                                (time.perf_counter() - t_ckpt0) * 1e6,
                                args={"step": step + 1})
        if tracer:
            tracer.complete("step", 0, t_step0, tracer.now_us() - t_step0,
                            args={"step": step})
    if store is not None:
        store.wait()
    if loop.metrics_out:
        from repro.obs import default_registry

        snap = obs.snapshot()
        snap.update(default_registry().snapshot())  # kernel knob counters
        with open(loop.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[train] wrote metrics snapshot to {loop.metrics_out}")
    if tracer is not None:
        from repro.obs import set_default_recorder

        set_default_recorder(None)
        tracer.save(loop.trace_out)
        print(f"[train] wrote Perfetto trace ({len(tracer.events)} events) "
              f"to {loop.trace_out}")
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--preset", default=None, choices=sorted(PRESETS))
    ap.add_argument("--reduce", action="store_true", help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn", default="flash_xla", choices=("ref", "flash_xla", "flash_pallas"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--packed", action="store_true",
                    help="varlen sequence packing (segment-masked attention)")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="model-axis width of the (data, model) host mesh")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="data-axis width of the (data, model) mesh; 0 "
                         "derives it as devices / model-axis. Composes "
                         "batch/FSDP parallelism with the ring: "
                         "--data-axis 2 --model-axis 4 runs two 4-wide "
                         "rings side by side on 8 devices")
    ap.add_argument("--attn-sharding", default=None,
                    choices=("heads", "sequence", "ring"),
                    help="override the arch's attention sharding strategy")
    ap.add_argument("--trace-out", default=None,
                    help="write step/data/compute/checkpoint spans as "
                         "Perfetto trace_event JSON here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics snapshot (JSON) here")
    args = ap.parse_args()

    cfg = resolve_model(args.arch, args.preset, args.reduce)
    loop = TrainLoopConfig(
        steps=args.steps, seq_len=args.seq, batch_size=args.batch,
        microbatches=args.microbatches, attn_impl=args.attn, ckpt_dir=args.ckpt_dir,
        packed=args.packed, model_axis=args.model_axis,
        data_axis=args.data_axis, attn_sharding=args.attn_sharding,
        trace_out=args.trace_out, metrics_out=args.metrics_out,
    )
    _, _, history = train(cfg, loop)
    first = np.mean(history["loss"][:5]) if history["loss"] else float("nan")
    last = np.mean(history["loss"][-5:]) if history["loss"] else float("nan")
    snap = history["registry"].snapshot()
    print(json.dumps({"first5_loss": round(float(first), 4),
                      "last5_loss": round(float(last), 4),
                      "median_step_s": round(float(np.median(history['step_time'])), 4),
                      "stragglers": history["stragglers"],
                      "mfu": snap.get("train/mfu"),
                      "tokens_per_s": round(snap.get("train/tokens_per_s", 0.0), 1)}))


if __name__ == "__main__":
    main()
