"""Production mesh builders.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the `pod`
axis carries only the once-per-step gradient all-reduce (it crosses the
slow pod-to-pod links), `data` is FSDP + batch, `model` is tensor/context
parallelism within a pod's fast ICI.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* first jax use).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU).

    Multi-device on a CPU host needs the devices *before* first jax use:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI
    multidevice job and tests/test_ring.py run this way).
    """
    n = len(jax.devices())
    if n % model_axis != 0 or n < model_axis:
        raise ValueError(
            f"model_axis={model_axis} does not fit the {n} visible devices "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_long_context_mesh(data: int = 1, model: int = None):
    """2D (data x ring) mesh for long-context runs: ring context
    parallelism over ``model`` *inside each of* ``data`` data-parallel /
    FSDP groups. The default (data=1, model=all devices) is the
    single-group layout where one long sequence is the whole workload
    (examples/long_context.py, ring benchmarks); ``train.py --data-axis
    N --model-axis M`` builds the composed mesh so the trainer scales
    past one model-axis group."""
    n = len(jax.devices())
    if model is None:
        if data <= 0 or n % data != 0:
            raise ValueError(
                f"data={data} does not divide the {n} visible devices "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
            )
        model = n // data
    if data * model != n:
        raise ValueError(
            f"mesh (data={data}) x (model={model}) != {n} visible devices"
        )
    return jax.make_mesh((data, model), ("data", "model"))
