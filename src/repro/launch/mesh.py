"""Production mesh builders.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the `pod`
axis carries only the once-per-step gradient all-reduce (it crosses the
slow pod-to-pod links), `data` is FSDP + batch, `model` is tensor/context
parallelism within a pod's fast ICI.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* first jax use).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
