import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST execute before any jax import (jax locks the
device count at first init): 512 host platform devices let jax.make_mesh
build the production 16x16 and 2x16x16 meshes on this CPU-only box.

Per cell this records: memory_analysis (fits-per-device proof),
cost_analysis (FLOPs/bytes for the roofline), and the collective bytes
parsed from the compiled HLO. Results append incrementally to
experiments/dryrun_<mesh>.json so interrupted sweeps resume.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.core.attention import AttentionConfig  # noqa: E402
from repro.distributed import params as P  # noqa: E402
from repro.distributed.sharding import lm_rules, use_rules  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm, whisper  # noqa: E402
from repro.training.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from repro.utils import flops as F  # noqa: E402
from repro.utils.hlo_analysis import Roofline, collective_bytes  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments")


def attention_config(cfg, overrides: Optional[dict] = None) -> AttentionConfig:
    """Dry-run attention config: flash_xla so cost_analysis sees the FLOPs.
    Context-parallel (sequence-sharded) archs use the dense tile schedule;
    heads-sharded archs use packed causal tiles (block skipping visible)."""
    kw = dict(
        impl="flash_xla",
        mode="dense" if cfg.attn_sharding in ("sequence", "ring") else "packed",
        # 1024x1024 from the Section-Perf block sweep (EXPERIMENTS.md):
        # -18% memory term vs 512^2; 2048^2 gains only a further -7% while
        # quadrupling the S-tile working set.
        block_q=1024,
        block_kv=1024,
        decode_splits=16,
    )
    if overrides:
        kw.update(overrides)
    return AttentionConfig(**kw)


def param_shapes(cfg):
    init = whisper.init_whisper if cfg.family == "encdec" else lm.init_lm
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    attn_overrides: Optional[dict] = None,
    ce_chunk: int = 512,
    compile_: bool = True,
):
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    skip = registry.skip_reason(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = lm_rules(
        cfg, pods=multi_pod, decode=(shape.kind == "decode"),
        batch_size=shape.global_batch,
    )
    attn_cfg = attention_config(cfg, attn_overrides)
    specs = registry.input_specs(cfg, shape)
    t0 = time.time()
    with mesh, use_rules(mesh, rules):
        p_shapes = param_shapes(cfg)
        p_shard = P.tree_shardings(p_shapes, mesh, rules)
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(init_opt_state, p_shapes)
            opt_shard = P.tree_shardings(opt_shapes, mesh, rules)
            batch_shard = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), P.batch_specs(specs, rules)
            )
            step = steps.build_train_step(
                cfg, attn_cfg, AdamWConfig(), ce_chunk=ce_chunk
            )
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, batch_shard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_shapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            batch_shard = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), P.batch_specs(specs, rules)
            )
            step = steps.build_prefill_step(cfg, attn_cfg, cache_size=shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_shard, batch_shard))
            lowered = jitted.lower(p_shapes, specs)
        else:  # decode
            arg_shard = jax.tree.map(
                lambda s: jax.sharding.NamedSharding(mesh, s), P.batch_specs(specs, rules)
            )
            step = steps.build_serve_step(cfg, attn_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, arg_shard["token"], arg_shard["caches"],
                              arg_shard["cache_len"]),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(
                p_shapes, specs["token"], specs["caches"], specs["cache_len"]
            )
        t_lower = time.time() - t0
        rec = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "chips": chips, "status": "lowered", "t_lower_s": round(t_lower, 1),
            "attn": dataclasses_dict(attn_cfg), "ce_chunk": ce_chunk,
        }
        if not compile_:
            return rec, lowered, None
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t0 - t_lower, 1)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        # Trip-count-aware walk of the compiled module: XLA's own
        # cost_analysis counts while bodies once (verified), which
        # undercounts every scan (layers, flash KV loop, CE chunks).
        from repro.utils.hlo_walker import HloModule

        walker = HloModule(hlo_text)
        wcost = walker.entry_cost()
        coll = collective_bytes(hlo_text)  # unscaled per-kind breakdown
        n_params, n_active = F.param_count(cfg)
        rl = Roofline(
            flops=wcost.flops,  # per-chip (SPMD partition program)
            hbm_bytes=wcost.bytes,
            coll_bytes=wcost.coll_bytes,
            chips=chips,
            model_flops=F.model_flops(cfg, shape) / chips,
        )
        # Deployment roofline: swap the measured XLA-fallback traffic of the
        # tagged flash regions for the Pallas kernel's analytic traffic
        # (utils.flops.flash_kernel_bytes; see EXPERIMENTS.md Section Roofline).
        kernel_bytes = F.flash_kernel_bytes(
            cfg, shape, block_q=attn_cfg.block_q, block_kv=attn_cfg.block_kv,
            multi_pod=multi_pod,
        )
        rl_kernel = None
        if kernel_bytes > 0 and wcost.flash_bytes > 0:
            rl_kernel = Roofline(
                flops=wcost.flops,
                hbm_bytes=max(wcost.bytes - wcost.flash_bytes, 0.0) + kernel_bytes,
                coll_bytes=wcost.coll_bytes,
                chips=chips,
                model_flops=F.model_flops(cfg, shape) / chips,
            )
        rec.update(
            status="ok",
            params_total=n_params,
            params_active=n_active,
            memory={
                "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
                "temp": getattr(mem, "temp_size_in_bytes", None),
                "args": getattr(mem, "argument_size_in_bytes", None),
                "output": getattr(mem, "output_size_in_bytes", None),
                "alias": getattr(mem, "alias_size_in_bytes", None),
                "generated_code": getattr(mem, "generated_code_size_in_bytes", None),
            },
            cost={
                "flops": wcost.flops,
                "bytes": wcost.bytes,
                "transcendentals": wcost.transcendentals,
                "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
                "xla_cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
                "walker_warnings": walker.warnings[:5],
            },
            collectives={**coll, "trip_aware_total": wcost.coll_bytes},
            bytes_by_kind=wcost.by_kind,
            flash_region={"measured_xla_bytes": wcost.flash_bytes,
                          "analytic_kernel_bytes": kernel_bytes},
            roofline=rl.to_dict(),
            roofline_kernel=rl_kernel.to_dict() if rl_kernel else None,
        )
        return rec


def dataclasses_dict(dc):
    import dataclasses as _d

    return {f.name: getattr(dc, f.name) for f in _d.fields(dc)}


def results_path(multi_pod: bool) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"dryrun_{'multipod' if multi_pod else 'singlepod'}.json")


def load_results(multi_pod: bool) -> dict:
    path = results_path(multi_pod)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(rec: dict, multi_pod: bool):
    all_ = load_results(multi_pod)
    all_[f"{rec['arch']}::{rec['shape']}"] = rec
    with open(results_path(multi_pod), "w") as f:
        json.dump(all_, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--ce-chunk", type=int, default=512)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in registry.names():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch + --shape or --all"
        cells = [(args.arch, args.shape)]

    done = load_results(args.multi_pod) if args.skip_done else {}
    for arch, shape in cells:
        key = f"{arch}::{shape}"
        if key in done and done[key].get("status") in ("ok", "skipped"):
            print(f"[dryrun] {key}: already done, skipping")
            continue
        print(f"[dryrun] {key} multi_pod={args.multi_pod} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod, ce_chunk=args.ce_chunk)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "multi_pod": args.multi_pod,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        save_result(rec, args.multi_pod)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (f" mem/dev={rec['memory']['bytes_per_device']/2**30:.2f}GiB"
                     f" flops={rec['cost']['flops']:.3e}"
                     f" coll={rec['collectives']['total']:.3e}B"
                     f" dom={rec['roofline']['dominant']}")
        elif status == "error":
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {key}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
