"""Step builders: the jit'd train / prefill / serve step for any arch.

These close over (ModelConfig, AttentionConfig, AdamWConfig) and present
uniform signatures across all 10 architectures:

  train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill_step(params, batch)                 -> (next_token, caches, lens)
  serve_step(params, token, caches, cache_len)-> (next_token, new_caches)

Gradient accumulation: ``microbatches > 1`` scans over batch slices
accumulating fp32 grads (same numerics as one big batch; the loss is
token-mean so we average the per-micro grads).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.models import lm, whisper
from repro.training.losses import chunked_cross_entropy
from repro.training.optimizer import AdamWConfig, OptState, apply_updates


def _embed_params(cfg: ModelConfig, params):
    return params["decoder"]["embed"] if cfg.family == "encdec" else params["embed"]


def loss_fn(cfg: ModelConfig, attn_cfg: AttentionConfig, params, batch, ce_chunk: int = 512):
    if cfg.family == "encdec":
        hidden, aux, nprefix = whisper.forward(
            cfg, params, batch["frames"], batch["inputs"], attn_cfg
        )
    else:
        hidden, aux, nprefix = lm.forward(
            cfg, params, batch["inputs"], attn_cfg, patches=batch.get("patches"),
            segment_ids=batch.get("segment_ids"),
        )
    if nprefix:
        hidden = hidden[:, nprefix:]
    loss, metrics = chunked_cross_entropy(
        _embed_params(cfg, params), cfg.tie_embeddings, hidden, batch["targets"],
        vocab_valid=cfg.vocab_size, mask=batch.get("loss_mask"), chunk=ce_chunk,
    )
    return loss + aux, {"ce_loss": loss, "aux_loss": aux, **metrics}


def build_train_step(
    cfg: ModelConfig,
    attn_cfg: AttentionConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    ce_chunk: int = 512,
):
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg, attn_cfg, ce_chunk=ce_chunk),
        argnums=0, has_aux=True,
    )

    def train_step(params, opt_state: OptState, batch):
        if microbatches > 1:
            B = batch["inputs"].shape[0]
            assert B % microbatches == 0

            def split(t):
                return t.reshape(microbatches, B // microbatches, *t.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                g_acc, l_acc, m_acc = acc
                (l, m), g = grad_fn(params, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g, l_acc + l, {k: m_acc[k] + m[k] for k in m_acc}), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            m0 = {k: jnp.zeros((), jnp.float32)
                  for k in ("ce_loss", "aux_loss", "nll_sum", "tokens", "accuracy")}
            (grads, loss, metrics), _ = jax.lax.scan(
                body, (g0, jnp.zeros(()), m0), micro
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {k: v / microbatches for k, v in metrics.items()}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        # Gradient sync dtype: grads reach here already in the compute dtype
        # (bf16 -- jax cotangent dtype rules), which is the brief's gradient
        # compression. NOTE (EXPERIMENTS.md Section Perf, deepseek iters 5a/5b):
        # XLA's partitioner still all-reduces the per-layer partials in fp32
        # inside the backward scan; neither a post-hoc cast nor an
        # optimization_barrier moved it -- both hypotheses refuted, recorded.
        new_params, new_opt, om = apply_updates(
            opt_cfg, opt_state, grads, param_dtype=jnp.dtype(cfg.dtype)
        )
        return new_params, new_opt, {"loss": loss, **metrics, **om}

    return train_step


def build_prefill_step(cfg: ModelConfig, attn_cfg: AttentionConfig, cache_size: int):
    def prefill_step(params, batch):
        if cfg.family == "encdec":
            from repro.models.layers import unembed

            h_last, caches, tlen = whisper.prefill(
                cfg, params, batch["frames"], batch["inputs"], attn_cfg, cache_size
            )
            logits = unembed(params["decoder"]["embed"], h_last, cfg.tie_embeddings)
            lens = jnp.full((logits.shape[0],), tlen, jnp.int32)
        else:
            # batch['lens'] (B,) marks true token counts for bucket-padded
            # prompts (ServingEngine admission); lm.prefill then selects the
            # hidden at each row's last real position.
            h_last, caches, lens = lm.prefill(
                cfg, params, batch["inputs"], attn_cfg, cache_size,
                patches=batch.get("patches"), lens=batch.get("lens"),
            )
            logits = lm.logits_from_hidden(cfg, params, h_last)
        next_token = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, caches, lens

    return prefill_step


def build_serve_step(cfg: ModelConfig, attn_cfg: AttentionConfig):
    def serve_step(params, token, caches, cache_len):
        if cfg.family == "encdec":
            logits, new_caches = whisper.decode_step(
                cfg, params, token, caches, cache_len, attn_cfg
            )
        else:
            logits, new_caches = lm.decode_step(
                cfg, params, token, caches, cache_len, attn_cfg
            )
        next_token = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return serve_step


def build_paged_serve_step(cfg: ModelConfig, attn_cfg: AttentionConfig):
    """Decode step over the paged cache. All shapes are functions of
    (max_batch, pages_per_seq_max, page_size) only -- never of which
    requests are resident -- so the jitted step compiles exactly once and
    requests join/leave with zero recompiles (pinned by
    tests/test_paged.py)."""

    def paged_serve_step(params, token, caches, block_table, cache_len):
        logits, new_caches = lm.decode_step(
            cfg, params, token, caches, cache_len, attn_cfg,
            block_table=block_table,
        )
        next_token = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return paged_serve_step


def build_paged_admit_step(cfg: ModelConfig, attn_cfg: AttentionConfig,
                           page_size: int):
    """Batched admission: one lens-masked bucketed prefill for a whole
    same-bucket group, its contiguous caches scattered straight into the
    pool's page planes at the ``dest`` physical pages.

    ``batch['inputs']`` (W, pad_to) right-padded prompts, ``batch['lens']``
    (W,) true lengths, ``dest`` (W, pad_to_pages) int32 physical page per
    logical prefill page (0 = the null page for rows/pages that must not
    land anywhere -- width-padding rows and overflow). Shapes depend only
    on (pad_to, W), so jit compiles once per (bucket, admission width)."""

    def scatter(paged, contig, dest):
        # contig (..., W, S, Hk, hd) -> pages (..., Hk, W, NP, ps, hd)
        *lead, W, S, Hk, hd = contig.shape
        NP = S // page_size
        v = contig.reshape(*lead, W, NP, page_size, Hk, hd)
        v = jnp.moveaxis(v, -2, -5)  # head plane first, like the pool
        return paged.at[..., dest, :, :].set(v.astype(paged.dtype))

    def admit_step(params, batch, caches, dest):
        tokens = batch["inputs"]
        cache_size = -(-tokens.shape[1] // page_size) * page_size
        h_last, prefill_caches, lens_total = lm.prefill(
            cfg, params, tokens, attn_cfg, cache_size, lens=batch.get("lens"),
        )
        logits = lm.logits_from_hidden(cfg, params, h_last)
        next_token = jnp.argmax(
            logits[..., : cfg.vocab_size], axis=-1
        ).astype(jnp.int32)
        new_caches = jax.tree.map(
            functools.partial(scatter, dest=dest), caches, prefill_caches
        )
        return next_token, lens_total, new_caches

    return admit_step
