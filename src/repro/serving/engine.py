"""Batched serving engine: slot-based continuous batching over a shared
fixed-capacity KV cache.

Design (vLLM-style, sized down to JAX/XLA static shapes):
  * ``max_batch`` slots share batched per-layer caches allocated once at
    engine start (shape-stable -> serve_step compiles once).
  * Admission: a free slot triggers a (B=1) prefill whose cache slices are
    written into the slot (pure-functional tree update).
  * Every tick runs one jitted serve_step for ALL slots; finished/empty
    slots decode garbage into scratch space that is simply ignored --
    the standard padding trade for static shapes.
  * Retirement on EOS or max_new_tokens frees the slot for the queue.

Split-KV flash decode (C2) makes the shared decode step efficient even when
resident sequences have wildly different lengths: per-slot ``cache_len``
masks exactly the valid cache prefix.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.launch.steps import build_prefill_step, build_serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


_CACHE_BASE_NDIM = {"k": 4, "v": 4, "h": 3, "conv": 3}  # (B, ...) leaf ranks


def _batch_axis(path, leaf) -> int:
    """Batch axis of a cache leaf: scan-stacked leaves carry leading group
    dims, so batch sits at ndim - base_rank (k/v: (B,S,H,D); h/conv: (B,..))."""
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    base = _CACHE_BASE_NDIM.get(name, leaf.ndim)
    return leaf.ndim - base


def _tree_slot_write(batched, single, slot: int):
    """Write a (batch=1, ...) cache tree into batch position ``slot``."""

    def one(path, buf, new):
        ax = _batch_axis(path, buf)
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), slot, axis=ax
        )

    return jax.tree_util.tree_map_with_path(one, batched, single)


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        attn_cfg: AttentionConfig,
        *,
        max_batch: int = 4,
        cache_size: int = 512,
        prompt_pad: int = 64,
    ):
        assert cfg.family != "encdec", "engine serves decoder-only families"
        self.cfg = cfg
        self.params = params
        self.attn = attn_cfg
        self.B = max_batch
        self.cache_size = cache_size
        self.prompt_pad = prompt_pad
        # Prompt-length bucketing needs the lens-masked prefill, which is
        # attention-only (an SSM's recurrent state would consume padding).
        self._bucket = prompt_pad > 1 and cfg.ssm is None
        self._prefill = jax.jit(build_prefill_step(cfg, attn_cfg, cache_size))
        self._step = jax.jit(build_serve_step(cfg, attn_cfg))
        from repro.configs.registry import cache_specs

        spec = cache_specs(cfg, max_batch, cache_size)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.next_token = jnp.zeros((max_batch, 1), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.ticks = 0

    # ------------------------------------------------------------- admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        """Bucketed (B=1) prefill into ``slot``.

        Prompts are right-padded to the next multiple of ``prompt_pad`` so
        the jitted prefill compiles once per *bucket*, not once per prompt
        length; ``lens`` tells the prefill where the real tokens end (the
        hidden is read at the last real position, causality keeps padding
        out of every real row's attention, and the padded cache tail sits
        beyond ``cache_len`` so decode never sees it — the first generated
        token simply overwrites it).
        """
        L = len(req.prompt)
        pad_to = -(-L // self.prompt_pad) * self.prompt_pad if self._bucket else L
        pad_to = min(pad_to, self.cache_size - 1)
        assert L <= pad_to, f"prompt ({L}) exceeds cache capacity {self.cache_size}"
        prompt_arr = np.zeros((1, pad_to), np.int32)
        prompt_arr[0, :L] = req.prompt
        batch = {"inputs": jnp.asarray(prompt_arr)}
        if self._bucket:
            batch["lens"] = jnp.asarray([L], jnp.int32)
        tok, cache1, lens = self._prefill(self.params, batch)
        true_len = int(lens[0])
        self.caches = _tree_slot_write(self.caches, cache1, slot)
        self.cache_len = self.cache_len.at[slot].set(true_len)
        self.next_token = self.next_token.at[slot].set(int(tok[0, 0]))
        req.generated.append(int(tok[0, 0]))
        self.slots[slot] = req

    def _retire(self, slot: int):
        req = self.slots[slot]
        if req is not None:
            req.done = True
            self.finished[req.rid] = req
        self.slots[slot] = None
        self.cache_len = self.cache_len.at[slot].set(0)

    # -------------------------------------------------------------- tick
    def tick(self):
        """Admit from queue, run one decode step, retire finished."""
        for slot in range(self.B):
            if self.slots[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0))
        if not any(self.slots):
            return
        tok, self.caches = self._step(
            self.params, self.next_token, self.caches, self.cache_len
        )
        self.cache_len = self.cache_len + jnp.asarray(
            [1 if s is not None else 0 for s in self.slots], jnp.int32
        )
        self.next_token = tok
        tok_host = np.asarray(tok)
        self.ticks += 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(tok_host[slot, 0])
            req.generated.append(t)
            if (req.eos_id is not None and t == req.eos_id) or len(
                req.generated
            ) >= req.max_new_tokens + 1 or int(self.cache_len[slot]) >= self.cache_size - 1:
                self._retire(slot)

    def run(self, max_ticks: int = 1000) -> Dict[int, Request]:
        while (self.queue or any(s is not None for s in self.slots)) and self.ticks < max_ticks:
            self.tick()
        return self.finished
