"""Continuous-batching serving engines.

Two engines share the Request/tick/retire lifecycle:

  * :class:`ServingEngine` -- the fixed-slot baseline: ``max_batch``
    contiguous cache slices of ``cache_size`` tokens each, reserved for a
    request's worst case whether it uses them or not. Kept as the
    benchmark baseline (benchmarks/serving_sweep.py measures it against
    the paged engine at a matched HBM budget).
  * :class:`PagedServingEngine` -- vLLM-style paged KV: HBM is a pool of
    fixed-size pages (serving/kv_pool.py), each resident sequence holds
    exactly ``ceil((L+1)/page_size)`` of them via an int32 block table,
    and the decode kernel reads pages through the table
    (kernels/flash_decode.flash_decode_paged_kernel). Throughput becomes
    a function of tokens *resident*, not slots *reserved*.

Both engines decode every tick with ONE jitted step whose shapes are
engine-geometry-static, so requests join/leave with zero recompiles
(pinned by compile-count tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.launch.steps import (
    build_paged_admit_step,
    build_paged_serve_step,
    build_prefill_step,
    build_serve_step,
)
from repro.obs.metrics import MetricsRegistry
from repro.obs.mfu import DecodeEfficiency
from repro.obs.trace import TraceRecorder
from repro.serving.kv_pool import KVPagePool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def feed(self) -> List[int]:
        """Tokens whose KV must be (re)built at admission: the prompt plus
        anything already generated -- nonempty ``generated`` means the
        request was preempted mid-flight and is resuming (greedy decoding
        makes the continuation deterministic, so resume == never-paused;
        tests/test_paged.py pins it)."""
        return self.prompt + self.generated


_CACHE_BASE_NDIM = {"k": 4, "v": 4, "h": 3, "conv": 3}  # (B, ...) leaf ranks

# Fixed buckets for the admission-size histogram (prompt pad buckets are
# prompt_pad multiples clamped to capacity; pow2 bounds cover both engines)
ADMIT_BUCKETS = (16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0)
QUEUE_WAIT_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


class _EngineTelemetry:
    """Shared observability surface of both serving engines.

    Everything is host-side (obs/metrics, obs/trace, obs/mfu): it runs
    *around* the jitted steps and never enters a trace, so enabling
    telemetry adds zero compiles and leaves the step shapes untouched
    (tests/test_obs.py pins ``decode_compiles == 1`` with it on).

    Registry schema (``snapshot()``; the common interface that replaced
    the paged-only ``stats()``):

      counters   serving/{tokens, admissions, retirements, ticks}
                 decode/{ticks, tokens, model_flops, compute_seconds}
      gauges     decode/{mfu, tokens_per_s}  (cumulative; obs/mfu)
      gauge_fns  serving/{active_slots, slot_utilization, queue_depth,
                 kv_cells_active, kv_cells_capacity, token_occupancy}
                 (+ kv_pool/* and serving/{preemptions,page_oom} paged)
      histograms serving/admit_bucket (admitted pad bucket, tokens),
                 serving/queue_wait_ticks (submit -> admission, ticks)
    """

    def _obs_init(self, registry: Optional[MetricsRegistry],
                  tracer: Optional[TraceRecorder]):
        self.obs = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self._c_tokens = self.obs.counter("serving/tokens")
        self._c_admissions = self.obs.counter("serving/admissions")
        self._c_retirements = self.obs.counter("serving/retirements")
        self._c_ticks = self.obs.counter("serving/ticks")
        self._h_bucket = self.obs.histogram("serving/admit_bucket", ADMIT_BUCKETS)
        self._h_wait = self.obs.histogram(
            "serving/queue_wait_ticks", QUEUE_WAIT_BUCKETS
        )
        self._eff = DecodeEfficiency(self.cfg, self.obs)
        self.obs.gauge_fn(
            "serving/active_slots",
            lambda: sum(s is not None for s in self.slots),
        )
        self.obs.gauge_fn(
            "serving/slot_utilization",
            lambda: sum(s is not None for s in self.slots) / self.B,
        )
        self.obs.gauge_fn("serving/queue_depth", lambda: len(self.queue))
        self.obs.gauge_fn("serving/kv_cells_active", self.active_kv_cells)
        self.obs.gauge_fn("serving/kv_cells_capacity", self.kv_capacity)
        self.obs.gauge_fn(
            "serving/token_occupancy",
            lambda: self.resident_tokens() / max(1, self.kv_capacity()),
        )
        self._submit_tick: Dict[int, int] = {}  # rid -> tick at (re)submit
        self._submit_ts: Dict[int, float] = {}  # rid -> trace us at submit
        self._decode_t0: Dict[int, float] = {}  # rid -> decode-span start us
        self._preempted_rids: set = set()  # resumes owe a 'resume' instant

    def snapshot(self) -> Dict[str, float]:
        """Flat metrics snapshot (obs/metrics schema); both engines."""
        return self.obs.snapshot()

    @property
    def decode_compiles(self) -> int:
        return self._step._cache_size()

    # --------------------------------------------------- lifecycle hooks
    def _note_submit(self, req: Request, *, resumed: bool = False):
        self._submit_tick[req.rid] = self.ticks
        if self.tracer:
            self.tracer.name_thread(req.rid, f"req {req.rid}")
            self._submit_ts[req.rid] = self.tracer.now_us()
            if not resumed:
                self.tracer.instant(
                    "submit", tid=req.rid,
                    args={"rid": req.rid, "prompt_len": len(req.prompt)},
                )

    def _note_admission(self, req: Request, bucket: int,
                        t_pref0: float, t_pref1: float):
        """One request admitted: counters + the rid track's queue_wait /
        prefill spans ([submit, admit) and [admit, prefill-done))."""
        self._c_admissions.inc()
        self._h_bucket.observe(bucket)
        self._h_wait.observe(self.ticks - self._submit_tick.pop(req.rid, self.ticks))
        if self.tracer:
            sub = self._submit_ts.pop(req.rid, t_pref0)
            self.tracer.complete("queue_wait", req.rid, sub, t_pref0 - sub)
            self.tracer.complete(
                "prefill", req.rid, t_pref0, t_pref1 - t_pref0,
                args={"bucket": bucket, "feed_len": len(req.feed)},
            )
            if req.rid in self._preempted_rids:
                self._preempted_rids.discard(req.rid)
                self.tracer.instant("resume", tid=req.rid, args={"rid": req.rid})
            self._decode_t0[req.rid] = t_pref1

    def _note_leave(self, req: Request, *, preempted: bool):
        """Request left its slot (retire or preempt): close its decode
        span; a preempt emits the matching instant (resume pairs with it
        at re-admission -- tests assert both carry the same rid)."""
        if not preempted:
            self._c_retirements.inc()
        if self.tracer:
            now = self.tracer.now_us()
            t0 = self._decode_t0.pop(req.rid, now)
            self.tracer.complete(
                "decode", req.rid, t0, now - t0,
                args={"generated": len(req.generated), "preempted": preempted},
            )
            self.tracer.instant(
                "preempt" if preempted else "retire", tid=req.rid,
                args={"rid": req.rid},
            )

    def _note_decode_tick(self, cache_lens, t0_us: float, dt_s: float):
        self._c_ticks.inc()
        live = self._eff.tick(cache_lens, dt_s)
        self._c_tokens.inc(live)
        if self.tracer:
            self.tracer.complete(
                "decode_tick", 0, t0_us, dt_s * 1e6, args={"live": live}
            )
            self.tracer.counter(
                "resident", {"slots": live, "tokens": self.resident_tokens()}
            )

    def _now_us(self) -> float:
        return self.tracer.now_us() if self.tracer else 0.0


def _batch_axis(path, leaf) -> int:
    """Batch axis of a cache leaf: scan-stacked leaves carry leading group
    dims, so batch sits at ndim - base_rank (k/v: (B,S,H,D); h/conv: (B,..))."""
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    base = _CACHE_BASE_NDIM.get(name, leaf.ndim)
    return leaf.ndim - base


def _tree_slot_write(batched, single, slot: int):
    """Write a (batch=1, ...) cache tree into batch position ``slot``."""

    def one(path, buf, new):
        ax = _batch_axis(path, buf)
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), slot, axis=ax
        )

    return jax.tree_util.tree_map_with_path(one, batched, single)


class ServingEngine(_EngineTelemetry):
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        attn_cfg: AttentionConfig,
        *,
        max_batch: int = 4,
        cache_size: int = 512,
        prompt_pad: int = 64,
        registry: Optional[MetricsRegistry] = None,
        tracer: Optional[TraceRecorder] = None,
    ):
        assert cfg.family != "encdec", "engine serves decoder-only families"
        self.cfg = cfg
        self.params = params
        self.attn = attn_cfg
        self.B = max_batch
        self.cache_size = cache_size
        self.prompt_pad = prompt_pad
        # Prompt-length bucketing needs the lens-masked prefill, which is
        # attention-only (an SSM's recurrent state would consume padding).
        self._bucket = prompt_pad > 1 and cfg.ssm is None
        self._prefill = jax.jit(build_prefill_step(cfg, attn_cfg, cache_size))
        self._step = jax.jit(build_serve_step(cfg, attn_cfg))
        from repro.configs.registry import cache_specs

        spec = cache_specs(cfg, max_batch, cache_size)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self.cache_len = jnp.zeros((max_batch,), jnp.int32)
        self.next_token = jnp.zeros((max_batch, 1), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.ticks = 0
        self._obs_init(registry, tracer)

    # ----------------------------------------------------------- metrics
    def resident_tokens(self) -> int:
        return int(np.asarray(self.cache_len).sum())

    def active_kv_cells(self) -> int:
        """KV cells the decode step touches: every slot's full slice,
        live or not (the cost the paged engine's page skip removes)."""
        return self.B * self.cache_size

    def kv_capacity(self) -> int:
        return self.B * self.cache_size

    # ------------------------------------------------------------- admin
    def submit(self, req: Request):
        self._note_submit(req)
        self.queue.append(req)

    def _admit(self, slot: int, req: Request):
        """Bucketed (B=1) prefill into ``slot``.

        Prompts are right-padded to the next multiple of ``prompt_pad`` so
        the jitted prefill compiles once per *bucket*, not once per prompt
        length; ``lens`` tells the prefill where the real tokens end (the
        hidden is read at the last real position, causality keeps padding
        out of every real row's attention, and the padded cache tail sits
        beyond ``cache_len`` so decode never sees it — the first generated
        token simply overwrites it).
        """
        L = len(req.prompt)
        pad_to = -(-L // self.prompt_pad) * self.prompt_pad if self._bucket else L
        pad_to = min(pad_to, self.cache_size - 1)
        assert L <= pad_to, f"prompt ({L}) exceeds cache capacity {self.cache_size}"
        prompt_arr = np.zeros((1, pad_to), np.int32)
        prompt_arr[0, :L] = req.prompt
        batch = {"inputs": jnp.asarray(prompt_arr)}
        if self._bucket:
            batch["lens"] = jnp.asarray([L], jnp.int32)
        t_pref0 = self._now_us()
        tok, cache1, lens = self._prefill(self.params, batch)
        true_len = int(lens[0])
        self._note_admission(req, pad_to, t_pref0, self._now_us())
        self.caches = _tree_slot_write(self.caches, cache1, slot)
        self.cache_len = self.cache_len.at[slot].set(true_len)
        self.next_token = self.next_token.at[slot].set(int(tok[0, 0]))
        req.generated.append(int(tok[0, 0]))
        self.slots[slot] = req

    def _retire(self, slot: int):
        req = self.slots[slot]
        if req is not None:
            req.done = True
            self.finished[req.rid] = req
            self._note_leave(req, preempted=False)
        self.slots[slot] = None
        self.cache_len = self.cache_len.at[slot].set(0)

    # -------------------------------------------------------------- tick
    def tick(self):
        """Admit from queue, run one decode step, retire finished."""
        for slot in range(self.B):
            if self.slots[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0))
        if not any(self.slots):
            return
        lens_before = np.asarray(self.cache_len)
        t0_us, t0 = self._now_us(), time.perf_counter()
        tok, self.caches = self._step(
            self.params, self.next_token, self.caches, self.cache_len
        )
        self.cache_len = self.cache_len + jnp.asarray(
            [1 if s is not None else 0 for s in self.slots], jnp.int32
        )
        self.next_token = tok
        tok_host = np.asarray(tok)
        self.ticks += 1
        self._note_decode_tick(
            [int(l) for l, s in zip(lens_before, self.slots) if s is not None],
            t0_us, time.perf_counter() - t0,
        )
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            t = int(tok_host[slot, 0])
            req.generated.append(t)
            if (req.eos_id is not None and t == req.eos_id) or len(
                req.generated
            ) >= req.max_new_tokens + 1 or int(self.cache_len[slot]) >= self.cache_size - 1:
                self._retire(slot)

    def run(self, max_ticks: int = 1000) -> Dict[int, Request]:
        while (self.queue or any(s is not None for s in self.slots)) and self.ticks < max_ticks:
            self.tick()
        return self.finished


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class PagedServingEngine(_EngineTelemetry):
    """Continuous batching over a paged KV pool.

    HBM holds ``num_pages`` physical pages of ``page_size`` tokens per
    layer (``registry.paged_cache_specs``); a resident request owns
    ``len // page_size + 1`` of them (one page of write headroom) through
    its row of the int32 block table. Admission allocates, growth extends
    one page at a time, retirement frees -- so a request's HBM footprint
    tracks its *actual* length, and the engine admits by free *pages*, not
    free worst-case slots.

    Static shapes / compiles:
      * decode: ONE jitted step, shapes fixed by
        (max_batch, pages_per_seq_max, page_size). Zero recompiles on
        join/leave/preempt (``decode_compiles`` stays 1; pinned by test).
      * admission: one jitted batched prefill per (prompt bucket,
        pow2 admission width) pair -- all same-bucket queued prompts
        admitted in a single call, scattered into their pages on device.

    OOM policy (DESIGN.md): admission is strict FIFO and reserves one
    growth page per already-resident request; if decode-time growth still
    finds the pool empty, the *youngest* resident request is preempted --
    its pages freed, the request requeued at the queue FRONT with its
    generated tokens kept, so re-admission re-prefills prompt+generated
    and greedy decoding resumes exactly where it left off.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        attn_cfg: AttentionConfig,
        *,
        max_batch: int = 4,
        num_pages: int = 64,
        page_size: int = 16,
        pages_per_seq_max: int = 16,
        prompt_pad: int = 64,
        registry: Optional[MetricsRegistry] = None,
        tracer: Optional[TraceRecorder] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.attn = attn_cfg
        self.B = max_batch
        self.ps = page_size
        self.n_max = pages_per_seq_max
        self.prompt_pad = prompt_pad
        self.pool = KVPagePool(num_pages, page_size)
        from repro.configs.registry import paged_cache_specs

        spec = paged_cache_specs(cfg, num_pages, page_size)  # asserts attn-only
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self._step = jax.jit(build_paged_serve_step(cfg, attn_cfg))
        self._admit = jax.jit(build_paged_admit_step(cfg, attn_cfg, page_size))
        # Host-side scheduler state, pushed to device every tick.
        self.table = np.zeros((max_batch, pages_per_seq_max), np.int32)
        self.cache_len = np.zeros((max_batch,), np.int32)
        self.next_token = np.zeros((max_batch, 1), np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self.ticks = 0
        self.preemptions = 0
        self._seq = 0  # admission order, for preempt-youngest
        self._slot_seq = np.zeros((max_batch,), np.int64)
        self._obs_init(registry, tracer)
        self.pool.register_metrics(self.obs)
        self._c_page_oom = self.obs.counter("serving/page_oom")
        self.obs.gauge_fn("serving/preemptions", lambda: float(self.preemptions))
        # fraction of *allocated* page cells holding real KV
        self.obs.gauge_fn(
            "serving/page_fill",
            lambda: self.resident_tokens()
            / max(1, self.pool.used_pages * self.ps),
        )

    # ----------------------------------------------------------- metrics
    @property
    def admit_compiles(self) -> int:
        return self._admit._cache_size()

    def resident_tokens(self) -> int:
        return int(self.cache_len.sum())

    def active_kv_cells(self) -> int:
        """KV cells the decode step touches: live rows' allocated pages
        only -- the page-level ``pl.when`` skip reads nothing else."""
        return int(sum(-(-int(l) // self.ps) * self.ps
                       for l in self.cache_len if int(l) > 0))

    def kv_capacity(self) -> int:
        return self.pool.usable_pages * self.ps

    # ------------------------------------------------------------- admin
    def _need_pages(self, tokens: int) -> int:
        # +1: headroom so the next decode write always has a page.
        return tokens // self.ps + 1

    def submit(self, req: Request):
        worst = len(req.prompt) + req.max_new_tokens
        assert worst <= self.n_max * self.ps - 1, (
            f"request {req.rid}: prompt+max_new ({worst}) exceeds per-seq "
            f"capacity {self.n_max * self.ps - 1}"
        )
        assert self._need_pages(len(req.prompt)) <= self.pool.usable_pages, (
            f"request {req.rid}: prompt alone overflows the pool"
        )
        self._note_submit(req)
        self.queue.append(req)

    def _bucket(self, L: int) -> int:
        pad = -(-L // self.prompt_pad) * self.prompt_pad
        return min(max(pad, self.prompt_pad), self.n_max * self.ps)

    def _admit_tick(self):
        """Strict-FIFO admission, then ONE batched prefill per bucket.

        A request is admitted only if, after taking its pages, the pool
        still holds one reserve page per resident request (including
        requests picked earlier this tick) -- decode growth must not be
        starved by admission. The first request that does not fit blocks
        the rest (FIFO fairness: no small-prompt overtaking).
        """
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        reserve = sum(s is not None for s in self.slots)
        picks: List[Tuple[int, Request, List[int]]] = []
        while self.queue and free_slots:
            req = self.queue[0]
            need = self._need_pages(len(req.feed))
            if len(req.feed) > self._bucket(len(req.feed)):
                # resumed request grew past the largest bucket: it cannot
                # re-prefill; drop to finished as-is
                self.queue.pop(0)
                req.done = True
                self.finished[req.rid] = req
                continue
            if self.pool.free_pages - need < reserve:
                break
            pages = self.pool.alloc(req.rid, need)
            if pages is None:
                break
            self.queue.pop(0)
            picks.append((free_slots.pop(0), req, pages))
            reserve += 1
        if not picks:
            return
        # Group by bucket; one batched admit call per bucket.
        by_bucket: Dict[int, List[Tuple[int, Request, List[int]]]] = {}
        for pick in picks:
            by_bucket.setdefault(self._bucket(len(pick[1].feed)), []).append(pick)
        for pad_to, group in sorted(by_bucket.items()):
            W = min(_next_pow2(len(group)), self.B)
            npb = -(-pad_to // self.ps)
            inputs = np.zeros((W, pad_to), np.int32)
            lens = np.ones((W,), np.int32)  # dummy rows: 1 token, null dest
            dest = np.zeros((W, npb), np.int32)
            for i, (slot, req, pages) in enumerate(group):
                feed = req.feed
                inputs[i, : len(feed)] = feed
                lens[i] = len(feed)
                n_dest = min(-(-len(feed) // self.ps), npb)
                dest[i, :n_dest] = pages[:n_dest]
            t_pref0 = self._now_us()
            tok, lens_total, self.caches = self._admit(
                self.params,
                {"inputs": jnp.asarray(inputs), "lens": jnp.asarray(lens)},
                self.caches,
                jnp.asarray(dest),
            )
            tok_host = np.asarray(tok)
            t_pref1 = self._now_us()
            for i, (slot, req, pages) in enumerate(group):
                self._note_admission(req, pad_to, t_pref0, t_pref1)
                self.table[slot] = 0
                self.table[slot, : len(pages)] = pages
                self.cache_len[slot] = int(lens_total[i])
                t = int(tok_host[i, 0])
                req.generated.append(t)
                self.next_token[slot, 0] = t
                self.slots[slot] = req
                self._slot_seq[slot] = self._seq
                self._seq += 1

    def _clear_slot(self, slot: int):
        self.slots[slot] = None
        self.table[slot] = 0
        self.cache_len[slot] = 0
        self.next_token[slot, 0] = 0

    def _retire(self, slot: int):
        req = self.slots[slot]
        assert req is not None
        self.pool.free(req.rid)
        req.done = True
        self.finished[req.rid] = req
        self._note_leave(req, preempted=False)
        self._clear_slot(slot)

    def _preempt_youngest(self) -> bool:
        """Free the most recently admitted request's pages and requeue it
        at the queue FRONT (it keeps FIFO priority and its generated
        tokens; Request.feed makes re-admission a deterministic resume)."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if len(active) <= 1:
            return False  # never preempt the last runner: no progress
        victim = max(active, key=lambda i: self._slot_seq[i])
        req = self.slots[victim]
        self.pool.free(req.rid)
        self._note_leave(req, preempted=True)
        self._preempted_rids.add(req.rid)
        self._note_submit(req, resumed=True)
        self.queue.insert(0, req)
        self._clear_slot(victim)
        self.preemptions += 1
        return True

    def _grow(self):
        """Ensure every resident request owns a page for its next write;
        extend from the pool, preempting the youngest on exhaustion.
        Oldest-first so preemption cost lands on the least-progressed."""
        order = sorted(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: self._slot_seq[i],
        )
        for slot in order:
            req = self.slots[slot]
            if req is None:  # preempted by an earlier iteration
                continue
            while self._need_pages(int(self.cache_len[slot])) > len(
                self.pool.pages_of(req.rid)
            ):
                page = self.pool.extend(req.rid)
                if page is None:
                    self._c_page_oom.inc()
                    if self.tracer:
                        self.tracer.instant(
                            "page_oom", tid=0, args={"rid": req.rid}
                        )
                    if not self._preempt_youngest():
                        raise RuntimeError(
                            "page pool exhausted with a single resident "
                            "request; pool too small for this workload"
                        )
                    if self.slots[slot] is None:
                        break  # we preempted ourselves
                    continue
                self.table[slot, len(self.pool.pages_of(req.rid)) - 1] = page

    # -------------------------------------------------------------- tick
    def tick(self):
        self._admit_tick()
        if not any(s is not None for s in self.slots):
            return
        lens_before = self.cache_len.copy()
        t0_us, t0 = self._now_us(), time.perf_counter()
        tok, self.caches = self._step(
            self.params,
            jnp.asarray(self.next_token),
            self.caches,
            jnp.asarray(self.table),
            jnp.asarray(self.cache_len),
        )
        tok_host = np.asarray(tok)
        self.ticks += 1
        self._note_decode_tick(
            lens_before, t0_us, time.perf_counter() - t0
        )
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.cache_len[slot] += 1
            t = int(tok_host[slot, 0])
            req.generated.append(t)
            self.next_token[slot, 0] = t
            if (
                (req.eos_id is not None and t == req.eos_id)
                or len(req.generated) >= req.max_new_tokens + 1
                or int(self.cache_len[slot]) >= self.n_max * self.ps - 1
            ):
                self._retire(slot)
        self._grow()

    def run(self, max_ticks: int = 10000) -> Dict[int, Request]:
        while (self.queue or any(s is not None for s in self.slots)) and self.ticks < max_ticks:
            self.tick()
        return self.finished
