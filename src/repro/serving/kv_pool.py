"""Fixed-size KV page pool: the allocator behind paged continuous batching.

The pool owns ``num_pages`` physical pages of ``page_size`` token slots
each, shared by every layer (one block table per sequence; layer caches are
parallel planes indexed by the same physical page ids -- vLLM's design).
Page 0 is reserved as the *null page*: block-table entries of inactive
slots and the not-yet-written tail all point at it, so the paged decode
kernel's index map always names a real page while its compute skips the
masked ones (kernels/flash_decode._paged_decode_kernel).

Allocation is host-side and O(1) per page (a free-list stack); the device
never sees the pool -- only the int32 block table the engine pushes each
tick. ``alloc`` is all-or-nothing (admission either fully fits or waits),
``extend`` grows a live sequence by one page (alloc-on-append), ``free``
retires a request's pages back to the stack (free-on-retire).
"""

from __future__ import annotations

from typing import Dict, List, Optional

NULL_PAGE = 0


class KVPagePool:
    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "need at least the null page + one real page"
        assert page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free-list: hot pages are reused first (better locality in the
        # physical planes). Page 0 (null) is never in the list.
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}  # rid -> physical page ids

    # ------------------------------------------------------------ queries
    @property
    def usable_pages(self) -> int:
        """Allocatable pages (the null page is bookkeeping, not capacity)."""
        return self.num_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def pages_of(self, rid: int) -> List[int]:
        """Physical pages owned by ``rid``, in logical order."""
        return list(self._owned.get(rid, ()))

    def pages_for_tokens(self, tokens: int) -> int:
        """Pages needed to hold positions [0, tokens): covers the *next*
        decode write too when tokens % page_size == 0 is false -- callers
        wanting write headroom for position L ask for L + 1 tokens."""
        return -(-tokens // self.page_size)

    def page_utilization(self) -> float:
        return self.used_pages / self.usable_pages if self.usable_pages else 0.0

    # -------------------------------------------------------- allocation
    def alloc(self, rid: int, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages for a new request; None (and no change) if
        the pool cannot fully satisfy it -- admission is all-or-nothing."""
        assert rid not in self._owned, f"rid {rid} already holds pages"
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned[rid] = pages
        return list(pages)

    def extend(self, rid: int) -> Optional[int]:
        """Alloc-on-append: one more page for a live request; None on OOM
        (the engine then preempts -- see PagedServingEngine)."""
        if not self._free:
            return None
        page = self._free.pop()
        self._owned.setdefault(rid, []).append(page)
        return page

    def free(self, rid: int) -> int:
        """Free-on-retire: return all of ``rid``'s pages; returns count."""
        pages = self._owned.pop(rid, [])
        self._free.extend(reversed(pages))
        return len(pages)

    # ------------------------------------------------------ observability
    def register_metrics(self, registry, prefix: str = "kv_pool") -> None:
        """Register lazily sampled pool gauges into an obs registry
        (repro.obs.metrics.MetricsRegistry): ``<prefix>/{num_pages,
        used_pages, free_pages, page_utilization, resident_seqs}``. All
        read live allocator state at snapshot time -- no write traffic on
        the alloc/extend/free hot path."""
        registry.gauge_fn(f"{prefix}/num_pages", lambda: float(self.usable_pages))
        registry.gauge_fn(f"{prefix}/used_pages", lambda: float(self.used_pages))
        registry.gauge_fn(f"{prefix}/free_pages", lambda: float(self.free_pages))
        registry.gauge_fn(f"{prefix}/page_utilization", self.page_utilization)
        registry.gauge_fn(f"{prefix}/resident_seqs", lambda: float(len(self._owned)))
