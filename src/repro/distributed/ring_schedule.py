"""Ring-attention layout + per-step schedules (DESIGN.md Section 3).

Ring flash attention keeps Q *and* KV sharded over the sequence: each device
holds one Q shard forever and the KV shards rotate around the ring
(``jax.lax.ppermute``), one shard per step. This module owns everything
*static* about that:

  * ``RingLayout`` — how the global sequence maps onto device-local shards.
    Causal (and windowed) runs use **zigzag** sharding: the sequence is cut
    into ``2P`` chunks and device ``d`` owns chunks ``(d, 2P-1-d)``, so every
    device sees the same visible-tile count under a causal mask (the early
    chunk's small triangle pairs with the late chunk's big one). Trivial
    masks use plain contiguous sharding (1 chunk per device, no reorder).
  * ``step_pairs`` — the static schedule for device ``d`` at ring step ``t``:
    which (q_chunk, kv_chunk) rectangles are visible, and the per-rectangle
    ``MaskSpec`` whose ``q_offset`` shifts local coordinates back to global
    ones. A rectangle that ``tile_visibility`` classifies as empty is
    *dropped here*, before tracing — a fully-masked ring step launches no
    kernel at all. Inside a visible rectangle the PR-2 compact schedule
    machinery (``kernels/schedule.build_tile_schedule``, keyed by the
    rectangle's spec) skips the masked tiles: the mesh-level skip and the
    grid-level skip are the same oracle at two granularities.
  * accounting — per-device visible-tile counts (the zigzag balance
    invariant, asserted by tests/test_ring.py) and comms/memory byte counts
    for the ring-vs-all-gather tradeoff table (benchmarks/ring_accounting).

Everything here is host-side python/numpy over *static* shapes; nothing is
traced. ``distributed/ring_attention.py`` consumes it.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Tuple

import numpy as np

from repro.core.masks import MaskSpec, tile_visibility


class RingLayout(NamedTuple):
    """Static sequence-to-shard layout for a P-device ring (hashable)."""

    num_devices: int       # P
    chunk: int             # C, tokens per chunk
    chunks_per_device: int # 1 = contiguous, 2 = zigzag

    @property
    def seq_len(self) -> int:
        return self.num_devices * self.shard_len

    @property
    def shard_len(self) -> int:
        return self.chunks_per_device * self.chunk

    @property
    def num_chunks(self) -> int:
        return self.num_devices * self.chunks_per_device

    def device_chunks(self, d: int) -> Tuple[int, ...]:
        """Global chunk ids owned by device ``d``, in local slot order."""
        if self.chunks_per_device == 1:
            return (d,)
        return (d, self.num_chunks - 1 - d)

    def permutation(self) -> np.ndarray:
        """Global chunk order after layout reordering: entry ``s`` is the
        global chunk id stored at chunk-slot ``s`` (device s // cpd,
        slot s % cpd)."""
        order = []
        for d in range(self.num_devices):
            order.extend(self.device_chunks(d))
        return np.asarray(order, np.int32)


def make_layout(seq_len: int, num_devices: int, spec: MaskSpec) -> RingLayout:
    """Layout for a sequence of ``seq_len`` on a ``num_devices`` ring.

    Zigzag (2 chunks/device) whenever the mask is non-trivial — that is what
    equalizes per-device visible tiles under causal/window masks; a trivial
    mask is uniform anyway, so contiguous sharding avoids the reorder.
    """
    cpd = 1 if spec.is_trivial else 2
    div = num_devices * cpd
    if seq_len % div != 0:
        raise ValueError(
            f"ring attention needs seq_len % (devices * {cpd}) == 0, got "
            f"{seq_len} % {div} (pad the sequence or change the mesh)"
        )
    return RingLayout(num_devices=num_devices, chunk=seq_len // div,
                      chunks_per_device=cpd)


class StepPair(NamedTuple):
    """One visible (q_chunk, kv_chunk) rectangle of a ring step."""

    q_slot: int      # local slot of the q chunk on this device
    kv_slot: int     # local slot of the kv chunk within the visiting shard
    q_chunk: int     # global chunk id (q)
    kv_chunk: int    # global chunk id (kv)
    spec: MaskSpec   # rectangle-local mask spec (q_offset shifted)


def kv_origin(layout: RingLayout, d: int, t: int) -> int:
    """Device whose KV shard device ``d`` holds at ring step ``t``.

    The rotation sends each shard to the next device every step
    (``ppermute`` perm ``i -> (i+1) % P``), so after ``t`` steps device
    ``d`` holds the shard that started on ``(d - t) % P``.
    """
    return (d - t) % layout.num_devices


def _pair_spec(spec: MaskSpec, q_chunk: int, kv_chunk: int, C: int) -> MaskSpec:
    """The MaskSpec for one rectangle, in rectangle-local coordinates.

    The kernels see q rows 0..C and kv cols 0..C; shifting ``q_offset`` by
    the chunk distance reproduces the global relative positions (causal and
    window masks depend only on those). ``sink`` is the one absolute-position
    feature: the global sink prefix intersected with this kv chunk.
    """
    q_off = spec.q_offset + (q_chunk - kv_chunk) * C
    sink = max(0, min(spec.sink - kv_chunk * C, C)) if spec.sink else 0
    return dataclasses.replace(spec, q_offset=q_off, sink=sink)


def step_pairs(layout: RingLayout, spec: MaskSpec, d: int, t: int) -> List[StepPair]:
    """Static schedule for device ``d`` at ring step ``t``: the visible
    (q_chunk, kv_chunk) rectangles against the shard from
    ``kv_origin(layout, d, t)``. Empty rectangles are dropped — a step whose
    list is empty launches no kernels."""
    C = layout.chunk
    e = kv_origin(layout, d, t)
    pairs: List[StepPair] = []
    for a, cq in enumerate(layout.device_chunks(d)):
        q_lo = spec.q_offset + cq * C
        for b, ck in enumerate(layout.device_chunks(e)):
            vis = tile_visibility(spec, q_lo, q_lo + C, ck * C, (ck + 1) * C)
            if vis == "empty":
                continue
            pairs.append(StepPair(a, b, cq, ck, _pair_spec(spec, cq, ck, C)))
    return pairs


def uniform_steps(layout: RingLayout, spec: MaskSpec) -> bool:
    """True when every device runs the identical static schedule at every
    step (trivial mask, contiguous layout) — the per-device ``lax.switch``
    dispatch in ring_attention collapses to a single branch."""
    return spec.is_trivial and layout.chunks_per_device == 1


# ---------------------------------------------------------------------------
# Accounting (zigzag balance invariant + ring-vs-gather tradeoff table)
# ---------------------------------------------------------------------------


def visible_tile_counts(
    layout: RingLayout, spec: MaskSpec, bq: int, bk: int
) -> np.ndarray:
    """Per-device visible (bq x bk) tile count summed over all ring steps.

    This is the mesh-level work-partitioning ledger: under a causal mask the
    zigzag layout makes these equal across devices to within one block
    (tests/test_ring.py asserts max - min <= 1). Uses the same
    ``_visible_pairs`` oracle the kernel schedules are checked against.
    """
    from repro.core.flash import _visible_pairs

    C = layout.chunk
    t_q = -(-C // bq)
    t_kv = -(-C // bk)
    counts = np.zeros(layout.num_devices, np.int64)
    for d in range(layout.num_devices):
        for t in range(layout.num_devices):
            for pair in step_pairs(layout, spec, d, t):
                counts[d] += len(_visible_pairs(pair.spec, t_q, t_kv, bq, bk)[0])
    return counts


def kernel_launch_counts(layout: RingLayout, spec: MaskSpec) -> np.ndarray:
    """Per-device count of shard-rectangle kernel launches over a full ring
    pass (a fully-masked step contributes zero — the 'skip without
    launching' claim in numbers)."""
    P = layout.num_devices
    return np.asarray(
        [sum(len(step_pairs(layout, spec, d, t)) for t in range(P)) for d in range(P)],
        np.int64,
    )


def comm_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int,
    *, backward: bool = False,
) -> int:
    """Bytes each device *sends* for one attention call's KV movement.

    Forward ring: P-1 rotations of the local (K, V) shard. Backward ring:
    P-1 (K, V) rotations plus P hops of the traveling f32 (dK, dV)
    accumulators (the extra hop brings them home). The all-gather baseline
    moves the same P-1 shards per device in one collective — the ring's
    win is peak memory (2 shards resident instead of P) and compute/comms
    overlap, not total bytes; see ``gather_bytes_per_device``.
    """
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes  # K + V
    P = layout.num_devices
    if not backward:
        return (P - 1) * shard
    dkv = 2 * layout.shard_len * kv_heads * head_dim * 4  # f32 accumulators
    return (P - 1) * shard + P * dkv


def gather_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int
) -> int:
    """Bytes each device sends for the 'sequence' mode KV all-gather."""
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes
    return (layout.num_devices - 1) * shard


def peak_kv_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int,
    *, mode: str,
) -> int:
    """Resident KV bytes per device: ring keeps the current + in-flight
    shard (2/P of the sequence); gather materializes all P shards."""
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes
    if mode == "ring":
        return 2 * shard
    if mode == "gather":
        return layout.num_devices * shard
    raise ValueError(f"unknown mode: {mode!r}")
