"""Ring-attention layout + per-step schedules (DESIGN.md Section 3).

Ring flash attention keeps Q *and* KV sharded over the sequence: each device
holds one Q shard forever and the KV shards rotate around the ring
(``jax.lax.ppermute``), one shard per step. This module owns everything
*static* about that:

  * ``RingLayout`` — how the global sequence maps onto device-local shards.
    Causal (and windowed) runs use **zigzag** sharding: the sequence is cut
    into ``2P`` chunks and device ``d`` owns chunks ``(d, 2P-1-d)``, so every
    device sees the same visible-tile count under a causal mask (the early
    chunk's small triangle pairs with the late chunk's big one). Trivial
    masks use plain contiguous sharding (1 chunk per device, no reorder).
  * ``visit_order`` — the per-device shard itinerary: ``visit[d][t]`` is the
    KV shard device ``d`` computes against at step ``t``. Dense masks (full,
    causal — every (device, shard) pair has work) use the plain rotation
    ``(d - t) % P``. Sparse masks (window/sink leave whole pairs empty) get
    a *rebalanced* itinerary: a Latin-square-style greedy matching packs the
    heavy pairs into the same early steps, so no step is serialized on one
    straggler device, and steps past the last one with any work anywhere are
    TRUNCATED — fewer hops, fewer synchronization points, less comm.
  * ``step_pairs`` — the static schedule for device ``d`` at ring step ``t``:
    which (q_chunk, kv_chunk) rectangles are visible, and the per-rectangle
    ``MaskSpec`` whose ``q_offset`` shifts local coordinates back to global
    ones. A rectangle that ``tile_visibility`` classifies as empty is
    *dropped here*, before tracing — a fully-masked ring step launches no
    kernel at all. Inside a visible rectangle the PR-2 compact schedule
    machinery (``kernels/schedule.build_tile_schedule``, keyed by the
    rectangle's spec) skips the masked tiles: the mesh-level skip and the
    grid-level skip are the same oracle at two granularities.
  * accounting — per-device visible-tile counts (the zigzag balance
    invariant, asserted by tests/test_ring.py), the per-*step* counts the
    tail-rebalance is judged by (``per_step_tile_counts``: the max over
    devices at each step is what a synchronized ring actually waits on),
    and comms/memory byte counts for the ring-vs-all-gather tradeoff table
    (benchmarks/ring_accounting).

Everything here is host-side python/numpy over *static* shapes; nothing is
traced. ``distributed/ring_attention.py`` consumes it.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, NamedTuple, Tuple

import numpy as np

from repro.core.masks import MaskSpec, tile_visibility


class RingLayout(NamedTuple):
    """Static sequence-to-shard layout for a P-device ring (hashable)."""

    num_devices: int       # P
    chunk: int             # C, tokens per chunk
    chunks_per_device: int # 1 = contiguous, 2 = zigzag

    @property
    def seq_len(self) -> int:
        return self.num_devices * self.shard_len

    @property
    def shard_len(self) -> int:
        return self.chunks_per_device * self.chunk

    @property
    def num_chunks(self) -> int:
        return self.num_devices * self.chunks_per_device

    def device_chunks(self, d: int) -> Tuple[int, ...]:
        """Global chunk ids owned by device ``d``, in local slot order."""
        if self.chunks_per_device == 1:
            return (d,)
        return (d, self.num_chunks - 1 - d)

    def permutation(self) -> np.ndarray:
        """Global chunk order after layout reordering: entry ``s`` is the
        global chunk id stored at chunk-slot ``s`` (device s // cpd,
        slot s % cpd)."""
        order = []
        for d in range(self.num_devices):
            order.extend(self.device_chunks(d))
        return np.asarray(order, np.int32)


def make_layout(seq_len: int, num_devices: int, spec: MaskSpec) -> RingLayout:
    """Layout for a sequence of ``seq_len`` on a ``num_devices`` ring.

    Zigzag (2 chunks/device) whenever the mask is non-trivial — that is what
    equalizes per-device visible tiles under causal/window masks; a trivial
    mask is uniform anyway, so contiguous sharding avoids the reorder.
    """
    cpd = 1 if spec.is_trivial else 2
    div = num_devices * cpd
    if seq_len % div != 0:
        raise ValueError(
            f"ring attention needs seq_len % (devices * {cpd}) == 0, got "
            f"{seq_len} % {div} (pad the sequence or change the mesh)"
        )
    return RingLayout(num_devices=num_devices, chunk=seq_len // div,
                      chunks_per_device=cpd)


class StepPair(NamedTuple):
    """One visible (q_chunk, kv_chunk) rectangle of a ring step."""

    q_slot: int      # local slot of the q chunk on this device
    kv_slot: int     # local slot of the kv chunk within the visiting shard
    q_chunk: int     # global chunk id (q)
    kv_chunk: int    # global chunk id (kv)
    spec: MaskSpec   # rectangle-local mask spec (q_offset shifted)


def kv_origin(layout: RingLayout, d: int, t: int) -> int:
    """Shard id device ``d`` would hold at step ``t`` under the *plain
    rotation* (``ppermute`` perm ``i -> (i+1) % P``: after ``t`` steps
    device ``d`` holds the shard that started on ``(d - t) % P``). The
    actual itinerary is :func:`visit_order`, which equals this rotation for
    dense masks and a rebalanced Latin square for sparse ones.
    """
    return (d - t) % layout.num_devices


def _pair_spec(spec: MaskSpec, q_chunk: int, kv_chunk: int, C: int) -> MaskSpec:
    """The MaskSpec for one rectangle, in rectangle-local coordinates.

    The kernels see q rows 0..C and kv cols 0..C; shifting ``q_offset`` by
    the chunk distance reproduces the global relative positions (causal and
    window masks depend only on those). ``sink`` is the one absolute-position
    feature: the global sink prefix intersected with this kv chunk.
    """
    q_off = spec.q_offset + (q_chunk - kv_chunk) * C
    sink = max(0, min(spec.sink - kv_chunk * C, C)) if spec.sink else 0
    return dataclasses.replace(spec, q_offset=q_off, sink=sink)


def pair_rects(layout: RingLayout, spec: MaskSpec, d: int, e: int) -> List[StepPair]:
    """Visible rectangles of device ``d``'s Q chunks against shard ``e``'s
    KV chunks (step-independent: a (device, shard) pair has the same work
    whichever step the itinerary schedules it at)."""
    C = layout.chunk
    pairs: List[StepPair] = []
    for a, cq in enumerate(layout.device_chunks(d)):
        q_lo = spec.q_offset + cq * C
        for b, ck in enumerate(layout.device_chunks(e)):
            vis = tile_visibility(spec, q_lo, q_lo + C, ck * C, (ck + 1) * C)
            if vis == "empty":
                continue
            pairs.append(StepPair(a, b, cq, ck, _pair_spec(spec, cq, ck, C)))
    return pairs


def pair_tiles(layout: RingLayout, spec: MaskSpec, d: int, e: int,
               bq: int = 128, bk: int = 128) -> int:
    """Visible (bq x bk) tile count of the (device d, shard e) pair — the
    work weight the tail-rebalance packs by."""
    from repro.core.flash import _visible_pairs

    C = layout.chunk
    bq, bk = min(bq, C), min(bk, C)
    t_q = -(-C // bq)
    t_kv = -(-C // bk)
    return sum(
        len(_visible_pairs(p.spec, t_q, t_kv, bq, bk)[0])
        for p in pair_rects(layout, spec, d, e)
    )


def _assignment(cost: List[List[int]]) -> List[int]:
    """Min-cost perfect assignment (Hungarian, O(P^3)): returns the shard
    assigned to each device. P is a ring size (tens), so cubic is free."""
    n = len(cost)
    INF = float("inf")
    u = [0.0] * (n + 1)
    v = [0.0] * (n + 1)
    p = [0] * (n + 1)   # p[j]: row matched to column j (1-based; 0 = none)
    way = [0] * (n + 1)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = [INF] * (n + 1)
        used = [False] * (n + 1)
        while True:
            used[j0] = True
            i0, delta, j1 = p[j0], INF, 0
            for j in range(1, n + 1):
                if used[j]:
                    continue
                cur = cost[i0 - 1][j - 1] - u[i0] - v[j]
                if cur < minv[j]:
                    minv[j], way[j] = cur, j0
                if minv[j] < delta:
                    delta, j1 = minv[j], j
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    out = [0] * n
    for j in range(1, n + 1):
        out[p[j] - 1] = j - 1
    return out


@functools.lru_cache(maxsize=None)
def visit_order(layout: RingLayout, spec: MaskSpec) -> Tuple[Tuple[int, ...], ...]:
    """Per-device shard itinerary: ``visit[d][t]`` = shard at device ``d``
    on step ``t``. Row 0..P-1, T columns (T <= P); every column is a
    permutation of the shards (realizable by ppermutes), every row visits a
    shard at most once, and every (device, shard) pair with visible work
    appears in its device's row.

    Dense masks return the plain rotation (T = P): it is already per-step
    balanced under causal zigzag (work(d, e) depends only on chunk
    geometry, and each rotation step pairs one heavy diagonal with P-1
    equal off-diagonals). Sparse masks (window/sink) leave whole pairs
    empty; there the greedy heaviest-first matching packs heavy pairs into
    the same step (the per-step max over devices is what the synchronized
    ring waits on) and drops all-empty trailing steps entirely.
    """
    P = layout.num_devices
    rotation = tuple(tuple((d - t) % P for t in range(P)) for d in range(P))
    if P == 1:
        return rotation
    weight = [[pair_tiles(layout, spec, d, e) for e in range(P)] for d in range(P)]
    if all(weight[d][e] > 0 for d in range(P) for e in range(P)):
        return rotation
    # Step 0 is always the home shard (its diagonal rectangle is visible
    # under every supported mask family, and it is resident — no hop).
    cols = [list(range(P))]
    visited = [{d} for d in range(P)]
    needed = [{e for e in range(P) if weight[d][e] > 0 and e != d}
              for d in range(P)]
    # Each column is the max-weight perfect matching over not-yet-visited
    # pairs, with needed pairs weighted NEED + tiles and padding pairs 0:
    # a step packs as many nonempty pairs as possible (NEED dominates) and
    # groups the heaviest together (the per-step max over devices is the
    # step's latency). Feasibility: after t perfect-matching columns the
    # unvisited graph is (P - t)-regular bipartite, every edge of which
    # lies in some perfect matching — forbidden pairs are never forced and
    # each column covers at least one needed pair while any remain.
    NEED, FORBID = 10 ** 12, 10 ** 18
    while any(needed) and len(cols) < P:
        cost = [
            [FORBID if e in visited[d]
             else -(NEED + weight[d][e]) if e in needed[d] else 0
             for e in range(P)]
            for d in range(P)
        ]
        col = _assignment(cost)
        cols.append(col)
        for d in range(P):
            visited[d].add(col[d])
            needed[d].discard(col[d])
    return tuple(tuple(cols[t][d] for t in range(len(cols))) for d in range(P))


def num_steps(layout: RingLayout, spec: MaskSpec) -> int:
    """Ring steps actually run (T <= P; < P when the rebalanced itinerary
    truncates all-empty tail steps of a sparse mask)."""
    return len(visit_order(layout, spec)[0])


def step_perms(
    layout: RingLayout, spec: MaskSpec
) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
    """The T-1 ``ppermute`` permutations realizing :func:`visit_order`:
    ``step_perms[t]`` moves each shard from its step-``t`` host to its
    step-``t+1`` host (for the rotation itinerary every entry is the plain
    ``i -> (i+1) % P`` ring hop)."""
    visit = visit_order(layout, spec)
    P = layout.num_devices
    T = len(visit[0])
    at = [{visit[d][t]: d for d in range(P)} for t in range(T)]  # shard->host
    return tuple(
        tuple(sorted((at[t][e], at[t + 1][e]) for e in range(P)))
        for t in range(T - 1)
    )


def home_perm(layout: RingLayout, spec: MaskSpec) -> Tuple[Tuple[int, int], ...]:
    """The final ``ppermute`` sending each traveling (dK, dV) accumulator
    from its last-step host back to the device that owns its KV shard."""
    visit = visit_order(layout, spec)
    P = layout.num_devices
    return tuple(sorted((d, visit[d][-1]) for d in range(P)))


def step_pairs(layout: RingLayout, spec: MaskSpec, d: int, t: int) -> List[StepPair]:
    """Static schedule for device ``d`` at ring step ``t``: the visible
    (q_chunk, kv_chunk) rectangles against the shard ``visit_order`` routes
    there. Empty rectangles are dropped — a step whose list is empty
    launches no kernels."""
    return pair_rects(layout, spec, d, visit_order(layout, spec)[d][t])


def uniform_steps(layout: RingLayout, spec: MaskSpec) -> bool:
    """True when every device runs the identical static schedule at every
    step (trivial mask, contiguous layout) — the per-device ``lax.switch``
    dispatch in ring_attention collapses to a single branch."""
    return spec.is_trivial and layout.chunks_per_device == 1


# ---------------------------------------------------------------------------
# Accounting (zigzag balance invariant + ring-vs-gather tradeoff table)
# ---------------------------------------------------------------------------


def per_step_tile_counts(
    layout: RingLayout, spec: MaskSpec, bq: int, bk: int
) -> np.ndarray:
    """(T, P) visible-tile counts: entry [t, d] is device ``d``'s work at
    step ``t``. The ring synchronizes at each hop, so step ``t``'s latency
    is ``max(counts[t])`` — the per-*step* balance the tail-rebalance
    optimizes, strictly stronger than the per-device row sums of
    :func:`visible_tile_counts`."""
    from repro.core.flash import _visible_pairs

    C = layout.chunk
    t_q = -(-C // bq)
    t_kv = -(-C // bk)
    T = num_steps(layout, spec)
    counts = np.zeros((T, layout.num_devices), np.int64)
    for d in range(layout.num_devices):
        for t in range(T):
            for pair in step_pairs(layout, spec, d, t):
                counts[t, d] += len(_visible_pairs(pair.spec, t_q, t_kv, bq, bk)[0])
    return counts


def visible_tile_counts(
    layout: RingLayout, spec: MaskSpec, bq: int, bk: int
) -> np.ndarray:
    """Per-device visible (bq x bk) tile count summed over all ring steps.

    This is the mesh-level work-partitioning ledger: under a causal mask the
    zigzag layout makes these equal across devices to within one block
    (tests/test_ring.py asserts max - min <= 1). Uses the same
    ``_visible_pairs`` oracle the kernel schedules are checked against.
    """
    return per_step_tile_counts(layout, spec, bq, bk).sum(axis=0)


def kernel_launch_counts(layout: RingLayout, spec: MaskSpec) -> np.ndarray:
    """Per-device count of shard-rectangle kernel launches over a full ring
    pass (a fully-masked step contributes zero — the 'skip without
    launching' claim in numbers)."""
    P = layout.num_devices
    T = num_steps(layout, spec)
    return np.asarray(
        [sum(len(step_pairs(layout, spec, d, t)) for t in range(T)) for d in range(P)],
        np.int64,
    )


def empty_slot_count(layout: RingLayout, spec: MaskSpec) -> int:
    """(device, step) slots of the *full rotation* grid that launch no
    kernels under the rebalanced itinerary: per-step empty slots within the
    T run steps plus the P per-device slots of each truncated step. The
    ``ring/empty_steps_skipped`` obs counter reports this."""
    P = layout.num_devices
    T = num_steps(layout, spec)
    empty = sum(
        1 for d in range(P) for t in range(T)
        if not step_pairs(layout, spec, d, t)
    )
    return empty + P * (P - T)


def comm_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int,
    *, backward: bool = False, spec: MaskSpec = None,
) -> int:
    """Bytes each device *sends* for one attention call's KV movement.

    Forward ring: T-1 rotations of the local (K, V) shard (T = P for dense
    masks; a truncated sparse itinerary hops less). Backward ring: T-1
    (K, V) rotations plus T hops of the traveling f32 (dK, dV) accumulators
    (the extra hop brings them home). The all-gather baseline moves the
    same P-1 shards per device in one collective — the ring's win is peak
    memory (2 shards resident instead of P) and compute/comms overlap, not
    total bytes; see ``gather_bytes_per_device``.
    """
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes  # K + V
    T = layout.num_devices if spec is None else num_steps(layout, spec)
    if not backward:
        return (T - 1) * shard
    dkv = 2 * layout.shard_len * kv_heads * head_dim * 4  # f32 accumulators
    return (T - 1) * shard + T * dkv


def gather_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int
) -> int:
    """Bytes each device sends for the 'sequence' mode KV all-gather."""
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes
    return (layout.num_devices - 1) * shard


def peak_kv_bytes_per_device(
    layout: RingLayout, kv_heads: int, head_dim: int, dtype_bytes: int,
    *, mode: str,
) -> int:
    """Resident KV bytes per device: ring keeps the current + in-flight
    shard (2/P of the sequence); gather materializes all P shards."""
    shard = 2 * layout.shard_len * kv_heads * head_dim * dtype_bytes
    if mode == "ring":
        return 2 * shard
    if mode == "gather":
        return layout.num_devices * shard
    raise ValueError(f"unknown mode: {mode!r}")
