"""Ring flash attention: context parallelism with KV sharded, not gathered.

The 'sequence' strategy (context_parallel.gather_kv) shards Q over the mesh
but replicates the full KV on every chip once per layer — per-device KV
memory is O(S), which caps context length. This module is the scalable
form (DISTFLASHATTN / Sequence Parallelism lineage, DESIGN.md Section 3):

  * Q *and* KV stay sharded over the 'model' axis. Each ring step, every
    device runs the existing flash kernel on its local Q shard against the
    KV shard currently visiting, then the shards rotate one hop
    (``jax.lax.ppermute``). After P steps every Q row has seen every key.
  * The per-step partial outputs carry the lane-major lse the kernels
    already emit; steps are folded with the associative finalized merge
    (``online_softmax.merge_partials``) — the same primitive as split-KV
    decode, one level up.
  * Causal masks get **zigzag** sharding (ring_schedule.make_layout) so all
    devices do equal work each step; fully-masked (device, step)
    rectangles are dropped from the static schedule before tracing — no
    kernel launch, no DMA. Sparse masks (window/sink) additionally get a
    *rebalanced* itinerary (ring_schedule.visit_order): heavy pairs are
    packed into the same steps and all-empty tail steps are truncated
    outright — fewer hops, fewer sync points. Inside a visible rectangle
    the PR-2 compact tile schedule (built from the rectangle's shifted
    MaskSpec) skips masked tiles.
  * **Double buffer, pinned**: step *t*'s kernels read buffer A while step
    *t+1*'s shard is already in flight into buffer B. Trace order alone
    does not make that true — the scheduler is free to sink the hop past
    the step's fusions (and the CPU backend does exactly that) — so
    ``_prefetch`` pins it with an ``optimization_barrier`` grouping both
    buffers: the step's compute consumes the barrier's A outputs and the
    barrier depends on the hop, forcing the collective to be issued before
    any of the step's compute. tests/test_ring.py asserts the resulting
    schedule in the compiled HLO, fwd and bwd, the same way it asserts
    no-all-gathers.
  * Backward is a second ring pass (custom_vjp): each rectangle's
    Algorithm-2 contribution is computed against the *globally merged*
    (o, lse) residuals (kernels/ops.flash_attention_pallas_shard_bwd,
    f32 out so bf16 inputs don't round-trip per rectangle); (dK, dV)
    accumulators travel with their KV shard — but on the far side of the
    compute (they depend on it), so the KV hop is prefetched into its own
    buffer exactly like the forward, and only the (dK, dV) hop trails the
    step. A final home hop returns each accumulator to its shard's owner.

Per-device geometry differs (device d owns chunks (d, 2P-1-d)), but a
shard_map body traces once — the per-device static schedules are dispatched
with ``lax.switch`` over ``axis_index``. Collectives stay OUTSIDE the
switch (all branches are pure compute), so every device reaches the same
``ppermute`` sequence. The O(P) traced branches bound this design to
single-pod ring sizes, the regime this repo targets.

``core.attention.attention`` routes here when the installed sharding rules
say ``attn_sharding='ring'``; ``ring_flash_attention`` is also directly
callable (tests, benchmarks, examples).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.masks import MaskSpec
from repro.core.online_softmax import merge_partials
from repro.distributed import ring_schedule as rs
from repro.distributed import sharding as shd


class _RingMeta(NamedTuple):
    """Static (hashable) call contract of the ring custom_vjp core."""

    spec: MaskSpec
    layout: rs.RingLayout
    mesh: Mesh
    axis: str                    # ring mesh axis ('model')
    batch_axes: object           # mesh axes of the batch dim (str|tuple|None)
    impl: str                    # 'flash_pallas' | 'flash_xla'
    block_q: Optional[int]       # None -> ops.default_block_sizes (Pallas)
    block_kv: Optional[int]
    scale: Optional[float]
    interpret: Optional[bool]
    schedule: Optional[str]      # None -> tuned cache / 'compact' per rect
    bwd: Optional[str]           # Pallas backward: 'fused' | 'split' | None
    num_q_bands: Optional[int]   # fwd occupancy partitioning of each
    kv_splits: Optional[int]     # rectangle kernel (None -> tuned/shape auto)
    use_tuned: Optional[bool] = None  # tuned-knob cache switch (rect kernels)


# ---------------------------------------------------------------------------
# Layout reorder (natural <-> zigzag chunk order)
# ---------------------------------------------------------------------------
#
# The zigzag layout is realized INSIDE the shard_map body with two
# half-shard ppermutes per tensor. Doing it outside as a global chunk
# permutation reads nicer, but GSPMD lowers that static gather along a
# sharded axis to a full-S all-gather per device — silently re-replicating
# exactly the O(S) arrays the ring exists to avoid (caught by inspecting
# the partitioned HLO; tests/test_ring.py now asserts the compiled ring
# program contains no all-gather at all). A production system would keep
# activations in zigzag order end-to-end and skip even these hops; here
# the boundary conversion keeps the public API order-agnostic.
#
# Geometry: device d's natural contiguous shard holds global chunks
# (2d, 2d+1); its zigzag shard holds (d, 2P-1-d). Every device owns exactly
# one even and one odd global chunk in either layout (d and 2P-1-d have
# opposite parity), so one ppermute routes all even chunks and a second all
# odd chunks — each a bijection. Only the receive/send slot of the even
# chunk depends on the device's own parity, handled by an elementwise
# select on ``axis_index % 2``.


def _zigzag_target(c: int, P: int) -> int:
    """Zigzag owner of global chunk c (slot 0 holds chunk d, slot 1 holds
    chunk 2P-1-d)."""
    return c if c < P else 2 * P - 1 - c


def _shard_to_zigzag(x, axis_name: str, layout: rs.RingLayout, seq_axis: int = 1):
    """Natural-order local shard -> zigzag-order local shard (collective)."""
    P = layout.num_devices
    if layout.chunks_per_device == 1 or P == 1:
        return x
    C = layout.chunk
    lo_nat, hi_nat = jnp.split(x, [C], axis=seq_axis)  # chunks 2d (even), 2d+1 (odd)
    perm_even = [(d, _zigzag_target(2 * d, P)) for d in range(P)]
    perm_odd = [(d, _zigzag_target(2 * d + 1, P)) for d in range(P)]
    recv_even = jax.lax.ppermute(lo_nat, axis_name, perm_even)
    recv_odd = jax.lax.ppermute(hi_nat, axis_name, perm_odd)
    # zigzag slot 0 holds chunk d: even iff the device index is even.
    d_even = jax.lax.axis_index(axis_name) % 2 == 0
    lo = jnp.where(d_even, recv_even, recv_odd)
    hi = jnp.where(d_even, recv_odd, recv_even)
    return jnp.concatenate([lo, hi], axis=seq_axis)


def _zigzag_to_shard(x, axis_name: str, layout: rs.RingLayout, seq_axis: int = 1):
    """Zigzag-order local shard -> natural-order local shard (inverse)."""
    P = layout.num_devices
    if layout.chunks_per_device == 1 or P == 1:
        return x
    C = layout.chunk
    lo, hi = jnp.split(x, [C], axis=seq_axis)  # chunks d, 2P-1-d
    d = jax.lax.axis_index(axis_name)
    d_even = d % 2 == 0
    send_even = jnp.where(d_even, lo, hi)  # the even chunk: d or 2P-1-d
    send_odd = jnp.where(d_even, hi, lo)
    # even chunk c goes home to device c // 2 (it is chunk 2(c//2) there);
    # the odd chunk likewise. Receivers get exactly chunks (2m, 2m+1).
    even_chunk = [d_ if d_ % 2 == 0 else 2 * P - 1 - d_ for d_ in range(P)]
    odd_chunk = [d_ if d_ % 2 == 1 else 2 * P - 1 - d_ for d_ in range(P)]
    perm_even = [(d_, even_chunk[d_] // 2) for d_ in range(P)]
    perm_odd = [(d_, odd_chunk[d_] // 2) for d_ in range(P)]
    lo_nat = jax.lax.ppermute(send_even, axis_name, perm_even)
    hi_nat = jax.lax.ppermute(send_odd, axis_name, perm_odd)
    return jnp.concatenate([lo_nat, hi_nat], axis=seq_axis)


def _to_layout(x: jnp.ndarray, layout: rs.RingLayout) -> jnp.ndarray:
    """(B, S, ...) natural order -> zigzag chunk order, as a *global* array
    op. Host-side reference semantics of the in-body conversion above
    (tests assert the two agree); not used on the sharded path."""
    if layout.chunks_per_device == 1:
        return x
    B, S = x.shape[:2]
    perm = layout.permutation()
    xc = x.reshape(B, layout.num_chunks, layout.chunk, *x.shape[2:])
    return xc[:, perm].reshape(B, S, *x.shape[2:])


def _from_layout(x: jnp.ndarray, layout: rs.RingLayout) -> jnp.ndarray:
    if layout.chunks_per_device == 1:
        return x
    import numpy as np

    B, S = x.shape[:2]
    inv = np.argsort(layout.permutation())
    xc = x.reshape(B, layout.num_chunks, layout.chunk, *x.shape[2:])
    return xc[:, inv].reshape(B, S, *x.shape[2:])


# ---------------------------------------------------------------------------
# Shard-local kernels (one rectangle = one kernel launch)
# ---------------------------------------------------------------------------


def _rect_fwd(q, k, v, spec: MaskSpec, meta: _RingMeta):
    """(o (B,Sq,H,D), lse (B,H,Sq)) for one (q_chunk, kv_chunk) rectangle."""
    if meta.impl == "flash_pallas":
        from repro.kernels.ops import flash_attention_pallas_with_lse

        return flash_attention_pallas_with_lse(
            q, k, v, spec, scale=meta.scale, block_q=meta.block_q,
            block_kv=meta.block_kv, interpret=meta.interpret,
            schedule=meta.schedule, num_q_bands=meta.num_q_bands,
            kv_splits=meta.kv_splits, use_tuned=meta.use_tuned,
        )
    from repro.core.flash import flash_attention_with_lse

    return flash_attention_with_lse(
        q, k, v, spec, scale=meta.scale, block_q=meta.block_q or 512,
        block_kv=meta.block_kv or 512,
    )


def _rect_bwd(q, k, v, o, lse, do, spec: MaskSpec, meta: _RingMeta):
    """Algorithm-2 contribution of one rectangle, given the globally merged
    (o, lse) for its q chunk. Returns (dq, dk, dv)."""
    if meta.impl == "flash_pallas":
        from repro.kernels.ops import flash_attention_pallas_shard_bwd

        return flash_attention_pallas_shard_bwd(
            q, k, v, o, lse, do, spec, scale=meta.scale, block_q=meta.block_q,
            block_kv=meta.block_kv, interpret=meta.interpret,
            schedule=meta.schedule, bwd=meta.bwd, use_tuned=meta.use_tuned,
            out_dtype=jnp.float32,
        )
    from repro.core.flash import FlashConfig, _bwd_impl

    cfg = FlashConfig(spec=spec, block_q=meta.block_q or 512,
                      block_kv=meta.block_kv or 512, scale=meta.scale)
    return _bwd_impl(q, k, v, o, lse, do, cfg)


# ---------------------------------------------------------------------------
# Per-(device, step) branches: static schedules under lax.switch
# ---------------------------------------------------------------------------


def _step_fwd_branch(meta: _RingMeta, d: int, t: int):
    """Forward compute for device ``d`` at ring step ``t`` (static
    geometry). Returns (o_partial (B,H,S_loc,D) f32, lse (B,H,S_loc) f32);
    q slots with no visible rectangle contribute lse = -inf."""
    C = meta.layout.chunk
    pairs = rs.step_pairs(meta.layout, meta.spec, d, t)

    def branch(q_loc, k_loc, v_loc):
        B, _, Hq, D = q_loc.shape
        slots = [
            (jnp.zeros((B, Hq, C, D), jnp.float32),
             jnp.full((B, Hq, C), -jnp.inf, jnp.float32))
            for _ in range(meta.layout.chunks_per_device)
        ]
        for p in pairs:
            q_a = q_loc[:, p.q_slot * C : (p.q_slot + 1) * C]
            k_b = k_loc[:, p.kv_slot * C : (p.kv_slot + 1) * C]
            v_b = v_loc[:, p.kv_slot * C : (p.kv_slot + 1) * C]
            o_p, lse_p = _rect_fwd(q_a, k_b, v_b, p.spec, meta)
            o_p = o_p.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B,H,C,D)
            slots[p.q_slot] = merge_partials(*slots[p.q_slot], o_p, lse_p)
        o = jnp.concatenate([s[0] for s in slots], axis=2)
        lse = jnp.concatenate([s[1] for s in slots], axis=2)
        return o, lse

    return branch


def _step_bwd_branch(meta: _RingMeta, d: int, t: int):
    """Backward compute for device ``d`` at step ``t``. Returns per-step
    (dq (B,S_loc,H,D), dk (B,S_loc,Hk,D), dv) in f32 (zeros where this
    step's rectangles don't touch)."""
    C = meta.layout.chunk
    cpd = meta.layout.chunks_per_device
    pairs = rs.step_pairs(meta.layout, meta.spec, d, t)

    def branch(q_loc, k_loc, v_loc, o_loc, lse_loc, do_loc):
        B, _, Hq, D = q_loc.shape
        Hk = k_loc.shape[2]
        dq = [jnp.zeros((B, C, Hq, D), jnp.float32) for _ in range(cpd)]
        dk = [jnp.zeros((B, C, Hk, D), jnp.float32) for _ in range(cpd)]
        dv = [jnp.zeros((B, C, Hk, D), jnp.float32) for _ in range(cpd)]
        for p in pairs:
            sl_q = slice(p.q_slot * C, (p.q_slot + 1) * C)
            sl_kv = slice(p.kv_slot * C, (p.kv_slot + 1) * C)
            dq_p, dk_p, dv_p = _rect_bwd(
                q_loc[:, sl_q], k_loc[:, sl_kv], v_loc[:, sl_kv],
                o_loc[:, sl_q], lse_loc[:, :, sl_q], do_loc[:, sl_q],
                p.spec, meta,
            )
            dq[p.q_slot] = dq[p.q_slot] + dq_p.astype(jnp.float32)
            dk[p.kv_slot] = dk[p.kv_slot] + dk_p.astype(jnp.float32)
            dv[p.kv_slot] = dv[p.kv_slot] + dv_p.astype(jnp.float32)
        return (
            jnp.concatenate(dq, axis=1),
            jnp.concatenate(dk, axis=1),
            jnp.concatenate(dv, axis=1),
        )

    return branch


def _dispatch(meta: _RingMeta, branches, *operands):
    """Run the per-device branch: a single trace when the schedule is
    device-uniform, otherwise lax.switch over axis_index (branches are pure
    compute — collectives stay outside)."""
    if rs.uniform_steps(meta.layout, meta.spec):
        return branches[0](*operands)
    return jax.lax.switch(
        jax.lax.axis_index(meta.axis), branches, *operands
    )


# ---------------------------------------------------------------------------
# Shard-local ring loops (inside shard_map)
# ---------------------------------------------------------------------------


def _prefetch(kv, perm, meta: _RingMeta, scope: str):
    """Issue the next KV hop and *pin* it ahead of this step's compute.

    The explicit double buffer: ``kv`` (buffer A) feeds this step's
    kernels while the returned ``kv_next`` (buffer B) is already in
    flight. Trace order alone is a hope, not a guarantee — the backend
    scheduler may sink the collective past the step's fusions (the CPU
    backend does). The ``optimization_barrier`` groups both buffers: the
    step's kernels consume the barrier's A outputs and the barrier
    depends on the hop, so the collective must be issued before any of
    the step's compute retires. ``perm=None`` (last step) reuses A.
    """
    if perm is None:
        return kv, kv
    with jax.named_scope(scope):
        nxt = jax.lax.ppermute(kv, meta.axis, list(perm))
    k, v, nk, nv = jax.lax.optimization_barrier((kv[0], kv[1], nxt[0], nxt[1]))
    return (k, v), (nk, nv)


def _local_fwd(q_loc, k_loc, v_loc, *, meta: _RingMeta):
    """One device's forward ring pass. q_loc (B, S/P, Hq, D) in natural
    shard order; returns (o_loc (B, S/P, Hq, D), lse_loc (B, Hq, S/P) f32),
    also natural order (zigzag conversion happens at the body boundary)."""
    P = meta.layout.num_devices
    T = rs.num_steps(meta.layout, meta.spec)
    perms = rs.step_perms(meta.layout, meta.spec)
    q_loc = _shard_to_zigzag(q_loc, meta.axis, meta.layout)
    k_loc = _shard_to_zigzag(k_loc, meta.axis, meta.layout)
    v_loc = _shard_to_zigzag(v_loc, meta.axis, meta.layout)
    B, S_loc, Hq, D = q_loc.shape
    acc_o = jnp.zeros((B, Hq, S_loc, D), jnp.float32)
    acc_lse = jnp.full((B, Hq, S_loc), -jnp.inf, jnp.float32)
    kv = (k_loc, v_loc)
    for t in range(T):
        kv, kv_next = _prefetch(
            kv, perms[t] if t < T - 1 else None, meta, f"ring_fwd_hop{t + 1}"
        )
        with jax.named_scope(f"ring_fwd_step{t}"):
            branches = [_step_fwd_branch(meta, d, t) for d in range(P)]
            o_p, lse_p = _dispatch(meta, branches, q_loc, kv[0], kv[1])
            acc_o, acc_lse = merge_partials(acc_o, acc_lse, o_p, lse_p)
        kv = kv_next
    o = acc_o.transpose(0, 2, 1, 3).astype(q_loc.dtype)
    return (
        _zigzag_to_shard(o, meta.axis, meta.layout),
        _zigzag_to_shard(acc_lse, meta.axis, meta.layout, seq_axis=2),
    )


def _local_bwd(q_loc, k_loc, v_loc, o_loc, lse_loc, do_loc, *, meta: _RingMeta):
    """One device's backward ring pass (natural shard order in and out).

    The KV shard is prefetched into its second buffer exactly like the
    forward (the old combined hop rotated (KV, dKV) together *after* the
    step's kernels, putting the KV movement on the critical path). The
    (dK, dV) accumulators genuinely depend on the step's compute, so
    their hop trails the step — it overlaps the *next* step's kernels,
    which read the already-prefetched KV, not the accumulators. A final
    home hop returns each accumulator to its shard's owner. Returns
    (dq, dk, dv) for the local shards, f32."""
    P = meta.layout.num_devices
    T = rs.num_steps(meta.layout, meta.spec)
    perms = rs.step_perms(meta.layout, meta.spec)
    to_zig = functools.partial(_shard_to_zigzag, axis_name=meta.axis, layout=meta.layout)
    q_loc, k_loc, v_loc, o_loc, do_loc = (
        to_zig(x) for x in (q_loc, k_loc, v_loc, o_loc, do_loc)
    )
    lse_loc = to_zig(lse_loc, seq_axis=2)
    dq = jnp.zeros(q_loc.shape, jnp.float32)
    kv = (k_loc, v_loc)
    dkv = (jnp.zeros(k_loc.shape, jnp.float32), jnp.zeros(v_loc.shape, jnp.float32))
    for t in range(T):
        kv, kv_next = _prefetch(
            kv, perms[t] if t < T - 1 else None, meta, f"ring_bwd_hop{t + 1}"
        )
        with jax.named_scope(f"ring_bwd_step{t}"):
            branches = [_step_bwd_branch(meta, d, t) for d in range(P)]
            dq_p, dk_p, dv_p = _dispatch(
                meta, branches, q_loc, kv[0], kv[1], o_loc, lse_loc, do_loc
            )
            dq = dq + dq_p
            dkv = (dkv[0] + dk_p, dkv[1] + dv_p)
        perm_out = perms[t] if t < T - 1 else rs.home_perm(meta.layout, meta.spec)
        with jax.named_scope(f"ring_bwd_dkv_hop{t}"):
            dkv = jax.lax.ppermute(dkv, meta.axis, list(perm_out))
        kv = kv_next
    from_zig = functools.partial(_zigzag_to_shard, axis_name=meta.axis, layout=meta.layout)
    return from_zig(dq), from_zig(dkv[0]), from_zig(dkv[1])


# ---------------------------------------------------------------------------
# Telemetry (host-side, trace time — mirrors kernels/ops.count_knob: each
# jit trace counts once, cached executions don't re-resolve)
# ---------------------------------------------------------------------------

_RING_TRACE_TID = 3  # dedicated Perfetto track for ring-schedule structure


def _record_ring_pass(meta: _RingMeta, k, *, backward: bool) -> None:
    """Count one ring pass into the default registry and, when a default
    TraceRecorder is installed (launch/train.py --trace-out), emit its
    per-step span structure so an overlap/truncation regression (extra
    steps, fatter hops, lost empty-step skips) is visible in the Perfetto
    output next to the train-step spans."""
    from repro.obs.metrics import default_registry
    from repro.obs.trace import get_default_recorder

    layout, spec = meta.layout, meta.spec
    T = rs.num_steps(layout, spec)
    kv_heads, head_dim = k.shape[2], k.shape[3]
    hop_bytes = rs.comm_bytes_per_device(
        layout, kv_heads, head_dim, jnp.dtype(k.dtype).itemsize,
        backward=backward, spec=spec,
    )
    reg = default_registry()
    reg.counter("ring/steps").inc(T)
    reg.counter("ring/hop_bytes").inc(hop_bytes)
    reg.counter("ring/empty_steps_skipped").inc(rs.empty_slot_count(layout, spec))
    rec = get_default_recorder()
    if rec is None:
        return
    name = "ring_bwd" if backward else "ring_fwd"
    bq, bk = meta.block_q or 128, meta.block_kv or 128
    tiles = rs.per_step_tile_counts(layout, spec, bq, bk)
    rec.name_thread(_RING_TRACE_TID, "ring schedule")
    with rec.span(name, tid=_RING_TRACE_TID,
                  args={"steps": T, "devices": layout.num_devices,
                        "hop_bytes_per_device": hop_bytes}):
        for t in range(T):
            if t < T - 1:
                rec.instant(f"{name}_hop{t + 1}", tid=_RING_TRACE_TID,
                            args={"in_flight_during_step": t})
            with rec.span(f"{name}_step{t}", tid=_RING_TRACE_TID,
                          args={"max_tiles": int(tiles[t].max()),
                                "tiles_per_device": tiles[t].tolist()}):
                pass


# ---------------------------------------------------------------------------
# custom_vjp wrapper (everything sharded: no global-order ops at all)
# ---------------------------------------------------------------------------


def _specs(meta: _RingMeta):
    from jax.sharding import PartitionSpec as P

    seq = P(meta.batch_axes, meta.axis, None, None)
    lse = P(meta.batch_axes, None, meta.axis)
    return seq, lse


def _shard_fwd(q, k, v, meta: _RingMeta):
    seq, lse = _specs(meta)
    return shd.shard_map(
        functools.partial(_local_fwd, meta=meta), meta.mesh,
        in_specs=(seq, seq, seq), out_specs=(seq, lse),
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _ring(q, k, v, meta: _RingMeta):
    return _shard_fwd(q, k, v, meta)[0]


def _ring_vjp_fwd(q, k, v, meta: _RingMeta):
    o, lse = _shard_fwd(q, k, v, meta)
    return o, (q, k, v, o, lse)


def _ring_vjp_bwd(meta: _RingMeta, res, do):
    q, k, v, o, lse = res
    _record_ring_pass(meta, k, backward=True)
    seq, lse_spec = _specs(meta)
    dq, dk, dv = shd.shard_map(
        functools.partial(_local_bwd, meta=meta), meta.mesh,
        in_specs=(seq, seq, seq, seq, lse_spec, seq),
        out_specs=(seq, seq, seq),
    )(q, k, v, o, lse, do)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_ring.defvjp(_ring_vjp_fwd, _ring_vjp_bwd)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------


def ring_flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: MaskSpec = MaskSpec(causal=True),
    *,
    mesh: Optional[Mesh] = None,
    axis: str = "model",
    batch_axes: object = None,
    impl: str = "flash_pallas",
    scale: Optional[float] = None,
    block_q: Optional[int] = None,
    block_kv: Optional[int] = None,
    interpret: Optional[bool] = None,
    schedule: Optional[str] = None,
    bwd: Optional[str] = None,
    num_q_bands: Optional[int] = None,
    kv_splits: Optional[int] = None,
    use_tuned: Optional[bool] = None,
) -> jnp.ndarray:
    """Differentiable ring flash attention over the ``axis`` mesh axis.

    q (B, S, Hq, D); k/v (B, S, Hkv, D) GQA. Self-attention only: the ring
    schedule assumes q and kv index the same sequence (Sq == Skv,
    spec.q_offset == 0). ``mesh``/``batch_axes`` default from the installed
    sharding context (distributed.sharding.use_rules); with a 1-device ring
    the layout degenerates and the single-device flash path runs directly.

    ``impl`` picks the shard-local kernel: the Pallas kernels
    (``flash_attention_pallas_with_lse`` + the shard bwd entry) or the XLA
    flash scan — both emit the lane-major lse the ring merge consumes.
    ``bwd`` (Pallas only) picks each rectangle's backward: the fused
    one-pass kernel (default) or the 3-launch split baseline.
    """
    if q.shape[1] != k.shape[1] or spec.q_offset != 0:
        raise ValueError(
            "ring attention is self-attention over one sequence layout "
            f"(Sq == Skv, q_offset == 0); got Sq={q.shape[1]}, "
            f"Skv={k.shape[1]}, q_offset={spec.q_offset}"
        )
    if mesh is None:
        state = shd.current()
        if state is None:
            raise ValueError("ring_flash_attention needs a mesh (argument or "
                             "sharding.use_rules context)")
        mesh, rules = state
        batch_axes = rules.table.get("batch")
    num = mesh.shape[axis] if axis in mesh.shape else 1
    if num == 1:
        # Degenerate ring: run the plain single-device flash path.
        if impl == "flash_pallas":
            from repro.kernels.ops import flash_attention_pallas

            return flash_attention_pallas(
                q, k, v, spec, scale=scale, block_q=block_q, block_kv=block_kv,
                interpret=interpret, schedule=schedule, bwd=bwd,
                num_q_bands=num_q_bands, kv_splits=kv_splits,
                use_tuned=use_tuned,
            )
        from repro.core.flash import flash_attention

        return flash_attention(
            q, k, v, spec, scale=scale, block_q=block_q or 512,
            block_kv=block_kv or 512,
        )
    layout = rs.make_layout(q.shape[1], num, spec)
    if isinstance(batch_axes, list):
        batch_axes = tuple(batch_axes)
    meta = _RingMeta(
        spec=spec, layout=layout, mesh=mesh, axis=axis, batch_axes=batch_axes,
        impl=impl, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule, bwd=bwd,
        num_q_bands=num_q_bands, kv_splits=kv_splits, use_tuned=use_tuned,
    )
    _record_ring_pass(meta, k, backward=False)
    return _ring(q, k, v, meta)
