"""Logical-axis sharding: rules, contexts, and constraint helpers.

Model code never names mesh axes. It annotates activations/params with
*logical* axes ('batch', 'seq', 'heads', 'embed', 'ff', 'vocab', 'experts',
'kv_seq', 'inner', ...); a ``ShardingRules`` table maps logical axes to mesh
axes. ``use_rules(mesh, rules)`` installs a context; outside a context every
constraint is a no-op, so models run unmodified on CPU tests.

Three attention strategies (DESIGN.md Section 3):
  'heads'    : 'heads' -> 'model'; 'seq' unsharded.
  'sequence' : context parallelism -- 'seq' -> 'model' (FA2's C2 lifted to
               the mesh); 'heads' unsharded; KV all-gathered per layer.
  'ring'     : same activation sharding as 'sequence', but KV *stays*
               sharded and rotates around the 'model' axis
               (distributed/ring_attention.py) -- per-device KV memory is
               O(S / P) instead of O(S).
FSDP: parameter 'embed'/'ff' input dims additionally sharded over 'data'
(all-gathered per scan step by XLA SPMD).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


class ShardingRules:
    """logical axis name -> mesh axis (str | tuple | None).

    ``attn_sharding`` records which attention strategy built the table so
    runtime dispatch (context_parallel.attn_context_mode) can tell the
    all-gather and ring context-parallel modes apart — they share the same
    activation sharding.
    """

    def __init__(self, table: Dict[str, object], attn_sharding: str = "heads"):
        self.table = dict(table)
        self.attn_sharding = attn_sharding

    def spec(self, *names: Optional[str]) -> P:
        return P(*[self.table.get(n) if n else None for n in names])


def lm_rules(
    cfg=None,
    *,
    attn_sharding: str = "heads",
    fsdp: bool = True,
    pods: bool = False,
    model_axis: int = 16,
    data_axis: int = 16,
    decode: bool = False,
    batch_size: int = 0,
) -> ShardingRules:
    """Build the rule table for one arch on the (pod?, data, model) mesh.

    The mesh is 2D (``data`` x ``model``): the ring / sequence context
    parallelism runs over ``model`` *inside each* data-parallel group, and
    the table carries both axes (batch over ``data``, seq over ``model``)
    so the trainer composes them freely (train.py --data-axis
    --model-axis). Divisibility-aware: kv heads / experts that don't
    divide the model axis fall back to replication (kv) or per-expert-FFN
    sharding (MoE); archs whose q heads don't divide use
    attn_sharding='sequence' (context parallelism) or 'ring' (the same
    activation layout with rotating KV shards). batch=1 decode (long_500k)
    leaves `data` to the KV-seq split instead of the batch.
    """
    if cfg is not None:
        attn_sharding = cfg.attn_sharding
        kv_ok = cfg.num_kv_heads % model_axis == 0
        heads_ok = cfg.num_heads % model_axis == 0
        experts_ok = bool(cfg.moe) and cfg.moe.num_experts % model_axis == 0
        has_ssm = cfg.ssm is not None
        # FSDP over data*model on the embed dim needs d_model divisible by
        # the full product (gemma3: 1152 % 256 != 0 -> fall back to data).
        embed_2d_ok = cfg.d_model % (model_axis * data_axis) == 0
    else:
        kv_ok = heads_ok = True
        experts_ok = True
        has_ssm = False
        embed_2d_ok = True
    if attn_sharding not in ("heads", "sequence", "ring"):
        raise ValueError(f"unknown attn_sharding: {attn_sharding!r}")
    seqsh = attn_sharding in ("sequence", "ring")
    heads_ax = None if seqsh or not heads_ok else "model"
    kv_ax = None if seqsh or not kv_ok else "model"
    batch = (("pod", "data") if pods else ("data",))
    batch_ok = batch_size == 0 or batch_size % (
        2 * data_axis if pods else data_axis
    ) == 0
    if not batch_ok:  # batch=1 long-context decode
        batch = ("pod",) if pods and batch_size % 2 == 0 else None
    # decode caches are always sequence-split (split-KV / context-parallel
    # decode -- C2); with an unshardable batch we split over data too.
    cache_ax = ("data", "model") if not batch_ok else "model"
    t = {
        # activations
        "batch": batch,
        "seq": "model" if seqsh else None,
        "kv_seq": "model" if seqsh else None,
        "heads": heads_ax,
        "kv_heads": kv_ax,
        "embed": None,
        "ff_act": None if seqsh else "model",
        "vocab": "model",
        "experts": "model" if experts_ok else None,
        "moe_ff": None if experts_ok else "model",
        "inner": "model",
        "ssm_seq": None,
        "cache_seq": cache_ax if decode else ("model" if seqsh else None),
        # params
        "p_embed": (
            ("data", "model") if (fsdp and seqsh and not has_ssm and embed_2d_ok)
            else ("data" if fsdp else None)
        ),
        "p_embed_tbl": "data" if fsdp else None,
        "p_ff": None if seqsh else "model",
        "p_heads": heads_ax,
        "p_kv_heads": kv_ax,
        "p_vocab": "model",
        "p_experts": "model" if experts_ok else None,
        "p_moe_ff": None if experts_ok else "model",
        "p_inner": "model",
        "layers": None,
    }
    return ShardingRules(t, attn_sharding=attn_sharding)


# --- trace-cache staleness guard -------------------------------------------
#
# attn_context_mode() is read at TRACE time, but jax's jit cache keys on
# function identity + avals, not on this thread-local context: jitting the
# *same* closure under a different rule context would silently replay the
# first context's trace (wrong collectives, or none). The guard records
# which effective mode each trace consulted and flushes jax's caches at
# every use_rules boundary where the effective mode changes, forcing a
# retrace under the new rules. Process-wide (jax caches are process-wide).

_traced_modes: set = set()


def _mode_of(state) -> Optional[str]:
    """Effective context-parallel mode of a (mesh, rules) state (or None).

    Mirrors context_parallel.attn_context_mode, which cannot be imported
    here (it imports this module)."""
    if state is None:
        return None
    mesh, rules = state
    mode = getattr(rules, "attn_sharding", "heads")
    if mode == "ring":
        return "ring" if mesh.shape.get("model", 1) > 1 else None
    if mode == "sequence":
        return "gather"
    return None


def record_traced_mode(mode: Optional[str]) -> None:
    """Note that attn_context_mode was consulted while tracing (mode baked
    into some cached trace). Called by context_parallel, not user code."""
    _traced_modes.add(mode)


def _flush_stale_traces(state) -> None:
    mode = _mode_of(state)
    if _traced_modes and any(m != mode for m in _traced_modes):
        jax.clear_caches()
        _traced_modes.clear()
        from repro.obs.metrics import default_registry

        default_registry().counter("sharding/trace_cache_flushes").inc()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: ShardingRules):
    prev = getattr(_ctx, "state", None)
    state = (mesh, rules)
    _flush_stale_traces(state)
    _ctx.state = state
    try:
        yield
    finally:
        _ctx.state = prev
        _flush_stale_traces(prev)


def current() -> Optional[Tuple[Mesh, ShardingRules]]:
    return getattr(_ctx, "state", None)


def constrain(x, *names: Optional[str]):
    """with_sharding_constraint on logical axes; no-op outside a context."""
    state = current()
    if state is None:
        return x
    mesh, rules = state
    spec = rules.spec(*names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    state = current()
    if state is None:
        return None
    mesh, rules = state
    return NamedSharding(mesh, rules.spec(*names))


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (replication checks off).

    ``jax.shard_map(check_vma=...)`` only exists on newer jax; older
    releases ship ``jax.experimental.shard_map.shard_map(check_rep=...)``.
    The manual-collective bodies here (MoE expert parallelism, ring
    attention) always want the replication checker off — ppermute/psum
    patterns it cannot verify.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
