"""Context-parallel attention: FA2's sequence-dimension parallelism (C2)
lifted from thread blocks to the device mesh.

Strategy (DESIGN.md Section 3, 'sequence' attn_sharding): Q stays sharded
over the sequence axis ('seq' -> 'model'); K/V are all-gathered over the
model axis ONCE per layer and the flash scan runs each chip's Q rows
against the full KV. Under GQA the gathered KV is small
(kv_heads * head_dim << q rows), which is what makes this profitable for
archs whose head counts cannot shard 16-way (whisper 8H, gemma3 4H,
hymba 25H, deepseek 56H).

The gather is expressed as a sharding *constraint* (seq axis -> None), so
XLA SPMD inserts exactly one all-gather per layer and keeps everything else
sharded. The flash implementation must then never dynamic-index a
seq-sharded axis: dense mode keeps Q whole in the forward, and the dense
backward (core.flash._bwd_dense_unblocked) scans KV blocks with dQ carried
whole -- measured in EXPERIMENTS.md Section Perf (deepseek train_4k), the
blocked alternative forced a 470 MB fp32 all-gather of q_blocks per tile
step.
"""

from __future__ import annotations

from repro.distributed.sharding import constrain


def gather_kv(k, v):
    """Constrain K/V (B, S, Hkv, D) to be replicated along the sequence axis.

    Inside a sharding-rules context with 'kv_seq' -> 'model' this makes XLA
    insert one all-gather; outside any context it is a no-op.
    """
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return k, v
