"""Context-parallel attention: FA2's sequence-dimension parallelism (C2)
lifted from thread blocks to the device mesh.

Two strategies share the same activation sharding ('seq' -> 'model'; see
DESIGN.md Section 3):

  'sequence' (all-gather): K/V are all-gathered over the model axis ONCE
  per layer and the flash scan runs each chip's Q rows against the full KV.
  Under GQA the gathered KV is small (kv_heads * head_dim << q rows), which
  is what makes this profitable for archs whose head counts cannot shard
  16-way (whisper 8H, gemma3 4H, hymba 25H, deepseek 56H). The gather is
  expressed as a sharding *constraint* (kv seq axis -> None), so XLA SPMD
  inserts exactly one all-gather per layer and keeps everything else
  sharded. The flash implementation must then never dynamic-index a
  seq-sharded axis: dense mode keeps Q whole in the forward, and the dense
  backward (core.flash._bwd_dense_unblocked) scans KV blocks with dQ
  carried whole -- measured in EXPERIMENTS.md Section Perf (deepseek
  train_4k), the blocked alternative forced a 470 MB fp32 all-gather of
  q_blocks per tile step. Per-device KV memory is O(S): fine at training
  lengths, the hard cap for long context.

  'ring' (distributed/ring_attention.py): K/V *stay* sharded and rotate
  around the model axis; per-device KV memory is O(S / P) and the rotation
  overlaps compute. This is the long-context mode. KV must NOT be gathered
  -- :func:`gather_kv` is a no-op under ring rules, and
  ``core.attention.attention`` routes to the ring implementation.

:func:`attn_context_mode` is the single dispatch point both rely on.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.distributed import sharding as shd
from repro.distributed.sharding import constrain


def attn_context_mode() -> Optional[str]:
    """The active context-parallel strategy: 'ring' | 'gather' | None.

    'ring' requires ring rules AND a model axis actually > 1 (a 1-wide ring
    is just the local kernel); 'gather' is the all-gather 'sequence' mode.
    Outside any sharding context both constraints and routing are no-ops.

    The mode is read at TRACE time, and jax's tracing cache keys on
    function identity + avals, not on this thread-local context — so a
    closure traced under one mode would silently replay under another.
    That reuse is guarded: every trace-time read is recorded
    (sharding.record_traced_mode) and ``use_rules`` flushes jax's caches
    whenever the effective mode changes across a context boundary, forcing
    a retrace (counted as 'sharding/trace_cache_flushes'). Distinct
    closures per mode (train()'s per-run step_fn) stay the cheap path —
    they never trigger a flush.
    """
    state = shd.current()
    if state is None:
        mode = None
    else:
        mesh, rules = state
        attn = getattr(rules, "attn_sharding", "heads")
        if attn == "ring":
            mode = "ring" if mesh.shape.get("model", 1) > 1 else None
        elif attn == "sequence":
            mode = "gather"
        else:
            mode = None
    if not jax.core.trace_state_clean():
        shd.record_traced_mode(mode)
    return mode


def gather_kv(k, v, *, cross: bool = False):
    """Constrain K/V (B, S, Hkv, D) to be replicated along the sequence axis.

    Inside a sharding-rules context with 'kv_seq' -> 'model' this makes XLA
    insert one all-gather; outside any context it is a no-op. Under *ring*
    rules self-attention KV must NOT be gathered (the whole point of the
    ring is that KV stays sequence-sharded; ring_attention rotates it), but
    *cross*-attention (``cross=True``) keeps the deliberate one-gather-per-
    layer constraint even then -- the ring only handles Sq == Skv
    self-attention, and leaving encoder KV unconstrained would hand its
    collective placement to GSPMD guesswork.
    """
    if attn_context_mode() == "ring" and not cross:
        return k, v
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return k, v
