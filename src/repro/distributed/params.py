"""Parameter / cache PartitionSpec assignment by param-tree path.

Walks a params (or cache) pytree and assigns a PartitionSpec per leaf by
matching the leaf's path suffix against the table below, then left-pads the
spec with None for stacked (scan-over-layers) leading dims. Used for jit
in_shardings in dryrun/train/serve and by the checkpoint elastic restore.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules

# leaf-name -> logical axes of the *unstacked* param
_TABLE = {
    # embeddings (FSDP'd over data only: p_vocab already uses `model`)
    "tokens": ("p_vocab", "p_embed_tbl"),
    "unembed": ("p_embed_tbl", "p_vocab"),
    "positions": (None, None),
    "meta": (None, None),
    # norms
    "scale": (None,),
    "bias": (None,),
    "q_norm": (None,),
    "k_norm": (None,),
    "attn_out_norm": (None,),
    "ssm_out_norm": (None,),
    # attention
    "wq": ("p_embed", "p_heads"),
    "wk": ("p_embed", "p_kv_heads"),
    "wv": ("p_embed", "p_kv_heads"),
    "wo": ("p_heads", "p_embed"),
    "bq": ("p_heads",),
    "bk": ("p_kv_heads",),
    "bv": ("p_kv_heads",),
    "bo": (None,),
    # dense mlp
    "w_gate": ("p_embed", "p_ff"),
    "w_up": ("p_embed", "p_ff"),
    "w_down": ("p_ff", "p_embed"),
    "w_in": ("p_embed", "p_ff"),
    "b_in": ("p_ff",),
    "w_out": ("p_ff", "p_embed"),
    "b_out": (None,),
    # moe (3D expert-stacked; distinguished by ndim below)
    "router": (None, None),
    # mamba
    "in_proj": ("p_embed", "p_inner"),
    "conv_w": (None, "p_inner"),
    "conv_b": ("p_inner",),
    "x_proj": ("p_inner", None),
    "dt_w": (None, "p_inner"),
    "dt_bias": ("p_inner",),
    "A_log": ("p_inner", None),
    "D": ("p_inner",),
    "out_proj": ("p_inner", "p_embed"),
    # decode caches
    "k": ("batch", "cache_seq", "kv_heads", None),
    "v": ("batch", "cache_seq", "kv_heads", None),
    "h": ("batch", "inner", None),
    "conv": ("batch", None, "inner"),
}

_MOE_TABLE = {
    "we_gate": ("p_experts", "p_embed", "p_moe_ff"),
    "we_up": ("p_experts", "p_embed", "p_moe_ff"),
    "we_down": ("p_experts", "p_moe_ff", "p_embed"),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def spec_for_leaf(name: str, ndim: int, rules: ShardingRules) -> P:
    logical: Optional[tuple] = None
    if name in _MOE_TABLE:
        logical = _MOE_TABLE[name]
    elif name in _TABLE:
        logical = _TABLE[name]
    if logical is None:
        return P()  # unknown leaf: replicate
    spec = rules.spec(*logical)
    pad = ndim - len(logical)
    if pad < 0:
        return P()
    return P(*([None] * pad + list(spec)))


def tree_specs(tree, rules: ShardingRules):
    """PartitionSpec pytree matching ``tree``."""

    def one(path, leaf):
        nd = np.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
        return spec_for_leaf(_leaf_name(path), nd, rules)

    return jax.tree_util.tree_map_with_path(one, tree)


def tree_shardings(tree, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree_specs(tree, rules)
    )


def batch_specs(batch_tree, rules: ShardingRules):
    """Shardings for a train/prefill batch: token arrays on ('batch',),
    frame/patch embeddings on ('batch','seq',None)."""

    def one(path, leaf):
        name = _leaf_name(path)
        nd = leaf.ndim
        if name in ("inputs", "targets"):
            return rules.spec("batch", None)
        if name in ("frames", "patches"):
            return rules.spec("batch", "seq", None)
        if name == "token":
            return rules.spec("batch", None)
        if name == "cache_len":
            return rules.spec("batch")
        if name in _TABLE and nd == len(_TABLE[name]):
            return rules.spec(*_TABLE[name])
        # stacked cache leaves (leading layer dims)
        if name in _TABLE:
            base = _TABLE[name]
            return P(*([None] * (nd - len(base)) + list(rules.spec(*base))))
        return P()

    return jax.tree_util.tree_map_with_path(one, batch_tree)
