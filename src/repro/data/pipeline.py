"""Deterministic, resumable data pipeline.

Sources:
  * SyntheticLM -- seeded synthetic token streams (markov-ish so loss can
    actually decrease); used by tests, benchmarks, and the dry-run.
  * PackedFileSource -- memory-mapped uint16/uint32 token files packed into
    fixed-length sequences (the production path for real corpora).

Determinism/restart contract (fault tolerance): the iterator state is
exactly ``(seed, step)`` -- ``state()``/``restore()`` round-trips through the
checkpoint manifest, and batch(step) is a pure function, so a restarted job
re-reads the same stream with no skew, on any number of hosts (each host
slices its data-parallel shard by process index).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch_size: int  # global batch (sequences per step)
    seq_len: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"  # 'synthetic' | 'file' | 'packed'
    path: Optional[str] = None
    # 'packed' (varlen) source: ragged document lengths, uniform in
    # [min_doc_len, max_doc_len] (max defaults to seq_len).
    min_doc_len: int = 16
    max_doc_len: Optional[int] = None


def pack_documents(docs, seq_len: int, pad_id: int = 0):
    """Greedy first-fit packing of ragged token docs into fixed-width rows.

    Each doc contributes its (input, target) next-token pairs: a doc of
    ``L`` tokens occupies ``L - 1`` packed positions. Segment ids are
    1-based per row; 0 marks padding. The loss mask excludes padding (and
    thereby every cross-segment boundary -- targets never leak between
    docs because each doc's targets come from that doc alone).

    Returns (inputs, targets, segment_ids, loss_mask) as (N, seq_len)
    arrays (loss_mask float32, others int32); N = however many rows the
    docs need.
    """
    rows = []  # list of lists of (inp, tgt) doc slices
    space = []  # remaining capacity per row
    for doc in docs:
        doc = np.asarray(doc)
        assert doc.ndim == 1 and len(doc) >= 2, "docs need >= 2 tokens"
        n = len(doc) - 1
        assert n <= seq_len, f"doc of {n} pairs exceeds seq_len {seq_len}"
        for r in range(len(rows)):  # first fit
            if space[r] >= n:
                rows[r].append(doc)
                space[r] -= n
                break
        else:
            rows.append([doc])
            space.append(seq_len - n)
    N = len(rows)
    inputs = np.full((N, seq_len), pad_id, np.int32)
    targets = np.full((N, seq_len), pad_id, np.int32)
    segment_ids = np.zeros((N, seq_len), np.int32)
    for r, row_docs in enumerate(rows):
        ofs = 0
        for s, doc in enumerate(row_docs, start=1):
            n = len(doc) - 1
            inputs[r, ofs : ofs + n] = doc[:-1]
            targets[r, ofs : ofs + n] = doc[1:]
            segment_ids[r, ofs : ofs + n] = s
            ofs += n
    loss_mask = (segment_ids != 0).astype(np.float32)
    return inputs, targets, segment_ids, loss_mask


class SyntheticLM:
    """Order-2 bigram-ish synthetic stream: next = f(prev, noise).

    Learnable structure (a fixed random permutation map) means train loss
    dropping below the uniform entropy is a real signal end-to-end tests can
    assert on.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed ^ 0xC0FFEE)
        self.perm = rng.permutation(cfg.vocab_size).astype(np.int64)
        self.step_ = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
        noise = rng.random((B, S)) < 0.1
        jumps = rng.integers(0, cfg.vocab_size, size=(B, S))
        for t in range(1, S + 1):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t - 1], jumps[:, t - 1], nxt)
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            out = self.batch(self.step_)
            self.step_ += 1
            yield out

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step_}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step_ = int(state["step"])


class SyntheticVarlenLM(SyntheticLM):
    """Packed (varlen) synthetic stream: ragged docs, no padding waste.

    Same learnable permutation process (and (seed, step) determinism /
    state / restore contract) as :class:`SyntheticLM`, but each batch row
    packs several back-to-back documents of random length. ``batch(step)``
    returns a dict with inputs / targets / segment_ids / loss_mask, the
    contract of the ``packed=True`` train path: attention must not cross
    segment boundaries and padding is excluded from the loss. Doc
    generation loops per token on the host like SyntheticLM; fine for a
    test/bench source (the production packed path is pack_documents over a
    real corpus).
    """

    def _doc(self, rng, length: int) -> np.ndarray:
        toks = np.empty(length + 1, np.int64)
        toks[0] = rng.integers(0, self.cfg.vocab_size)
        noise = rng.random(length) < 0.1
        jumps = rng.integers(0, self.cfg.vocab_size, size=length)
        for t in range(1, length + 1):
            nxt = self.perm[toks[t - 1]]
            toks[t] = jumps[t - 1] if noise[t - 1] else nxt
        return toks

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        lo = cfg.min_doc_len
        hi = min(cfg.max_doc_len or S, S)
        inputs = np.zeros((B, S), np.int32)
        targets = np.zeros((B, S), np.int32)
        segment_ids = np.zeros((B, S), np.int32)
        for b in range(B):
            ofs, seg = 0, 1
            while S - ofs >= lo:
                n = int(rng.integers(lo, min(hi, S - ofs) + 1))
                doc = self._doc(rng, n)  # n+1 tokens -> n pairs
                inputs[b, ofs : ofs + n] = doc[:-1]
                targets[b, ofs : ofs + n] = doc[1:]
                segment_ids[b, ofs : ofs + n] = seg
                ofs += n
                seg += 1
        return {
            "inputs": inputs,
            "targets": targets,
            "segment_ids": segment_ids,
            "loss_mask": (segment_ids != 0).astype(np.float32),
        }


class PackedFileSource:
    """Pack a flat token file into (B, S+1) windows; deterministic in step."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "file source needs cfg.path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len
        self.step_ = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        idx = rng.integers(0, self.n_windows, size=cfg.batch_size)
        starts = idx * cfg.seq_len
        rows = np.stack([self.tokens[s : s + cfg.seq_len + 1] for s in starts])
        rows = rows.astype(np.int32) % cfg.vocab_size
        return rows[:, :-1], rows[:, 1:]

    def __iter__(self):
        while True:
            out = self.batch(self.step_)
            self.step_ += 1
            yield out

    state = SyntheticLM.state
    restore = SyntheticLM.restore


def make_source(cfg: DataConfig):
    if cfg.source == "file":
        return PackedFileSource(cfg)
    if cfg.source == "packed":
        return SyntheticVarlenLM(cfg)
    return SyntheticLM(cfg)
