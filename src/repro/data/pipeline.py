"""Deterministic, resumable data pipeline.

Sources:
  * SyntheticLM -- seeded synthetic token streams (markov-ish so loss can
    actually decrease); used by tests, benchmarks, and the dry-run.
  * PackedFileSource -- memory-mapped uint16/uint32 token files packed into
    fixed-length sequences (the production path for real corpora).

Determinism/restart contract (fault tolerance): the iterator state is
exactly ``(seed, step)`` -- ``state()``/``restore()`` round-trips through the
checkpoint manifest, and batch(step) is a pure function, so a restarted job
re-reads the same stream with no skew, on any number of hosts (each host
slices its data-parallel shard by process index).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch_size: int  # global batch (sequences per step)
    seq_len: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"  # 'synthetic' | 'file'
    path: Optional[str] = None


class SyntheticLM:
    """Order-2 bigram-ish synthetic stream: next = f(prev, noise).

    Learnable structure (a fixed random permutation map) means train loss
    dropping below the uniform entropy is a real signal end-to-end tests can
    assert on.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed ^ 0xC0FFEE)
        self.perm = rng.permutation(cfg.vocab_size).astype(np.int64)
        self.step_ = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=B)
        noise = rng.random((B, S)) < 0.1
        jumps = rng.integers(0, cfg.vocab_size, size=(B, S))
        for t in range(1, S + 1):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t - 1], jumps[:, t - 1], nxt)
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            out = self.batch(self.step_)
            self.step_ += 1
            yield out

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step_}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "data seed mismatch on restore"
        self.step_ = int(state["step"])


class PackedFileSource:
    """Pack a flat token file into (B, S+1) windows; deterministic in step."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "file source needs cfg.path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len
        self.step_ = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        idx = rng.integers(0, self.n_windows, size=cfg.batch_size)
        starts = idx * cfg.seq_len
        rows = np.stack([self.tokens[s : s + cfg.seq_len + 1] for s in starts])
        rows = rows.astype(np.int32) % cfg.vocab_size
        return rows[:, :-1], rows[:, 1:]

    def __iter__(self):
        while True:
            out = self.batch(self.step_)
            self.step_ += 1
            yield out

    state = SyntheticLM.state
    restore = SyntheticLM.restore


def make_source(cfg: DataConfig):
    return PackedFileSource(cfg) if cfg.source == "file" else SyntheticLM(cfg)
