"""FlashAttention-2 forward Pallas TPU kernel.

TPU mapping of the paper's scheme (DESIGN.md Section 2):

  * Grid ``(B*Hq, Tq, Tkv)`` -- (batch x heads) plus the paper's C2
    sequence-dimension axis ``Tq``; both are `parallel`. The KV axis ``Tkv``
    is `arbitrary` (sequential on TPU), which makes the VMEM scratch carry
    the online-softmax state across KV steps.
  * "Split-Q" warp partitioning (C3) becomes q-block-stationary scheduling:
    the Q tile is fetched once per (bh, i) and stays in VMEM while K/V
    stream past; the accumulator never leaves VMEM scratch. There is no
    cross-"worker" communication, exactly as in the paper's Figure 3 right.
  * C1: the accumulator is un-rescaled until the final KV step, where we
    apply ``diag(l)^-1`` once and emit the logsumexp.
  * Causal/window block skipping: fully-masked tiles skip the MXU work via
    ``pl.when`` (the TPU grid still visits the step -- the cost is a scalar
    branch, the matmuls are skipped).

Layout contract (set up by ops.py): q (BH, Sq, D), k/v (BHk, Skv, D) with
BH = B * Hq, BHk = B * Hkv, q head ``h`` reading kv head ``h // G``.
All sequence lengths pre-padded to the block size; KV padding masked here.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels.compat import CompilerParams

LANES = 128


def _visibility(
    spec: MaskSpec, i, j, bq: int, bk: int, kv_valid: int,
    q_seg=None, kv_seg=None,
):
    """In-kernel scalar visibility: returns (is_empty, needs_mask) bools.

    i/j are (traced) program ids; spec fields and block sizes are static, so
    every branch below is a static Python branch over *which* scalar ops to
    emit -- the emitted ops themselves are traced scalar arithmetic.

    q_seg/kv_seg: optional loaded (bq,)/(bk,) int32 segment-id tiles (packed
    varlen). Their min/max ranges drive *data-dependent* block skipping: a
    tile whose id ranges are disjoint cannot contain an equal pair, so it is
    empty -- sound for any id layout, and exact for contiguous packing. A
    tile is mask-free only if both sides are uniform and equal.
    """
    q_lo = i * bq + spec.q_offset
    q_hi = q_lo + bq - 1
    kv_lo = j * bk
    kv_hi = kv_lo + bk - 1
    empty = jnp.bool_(False)
    full = jnp.bool_(True)
    if spec.causal:
        empty = q_hi < kv_lo
        full = q_lo >= kv_hi
        if spec.window is not None:
            win_empty = (q_lo - kv_hi) >= spec.window
            if spec.sink:
                win_empty = win_empty & ~(kv_lo < spec.sink)
            empty = empty | win_empty
            in_win = (q_hi - kv_lo) < spec.window
            if spec.sink:
                in_win = in_win | (kv_hi < spec.sink)
            full = full & in_win
    elif spec.window is not None:
        win_empty = ((q_lo - kv_hi) >= spec.window) | ((kv_lo - q_hi) >= spec.window)
        if spec.sink:
            win_empty = win_empty & ~(kv_lo < spec.sink)
        empty = win_empty
        full = (abs_diff(q_lo, kv_hi) < spec.window) & (abs_diff(q_hi, kv_lo) < spec.window)
        if spec.sink:
            full = full | (kv_hi < spec.sink)
    if kv_valid % bk != 0:
        # last block contains padding -> not full there
        pad_block = kv_valid // bk
        empty = empty | (kv_lo >= kv_valid)
        full = full & (j != pad_block)
    if q_seg is not None:
        qs_lo, qs_hi = jnp.min(q_seg), jnp.max(q_seg)
        ks_lo, ks_hi = jnp.min(kv_seg), jnp.max(kv_seg)
        empty = empty | (qs_hi < ks_lo) | (qs_lo > ks_hi)
        full = full & (qs_lo == qs_hi) & (ks_lo == ks_hi) & (qs_lo == ks_lo)
    return jnp.bool_(empty), ~jnp.bool_(full)


def abs_diff(a, b):
    d = a - b
    return jnp.where(d < 0, -d, d)


def _tile_mask(
    spec: MaskSpec, i, j, bq: int, bk: int, kv_valid: int,
    q_seg=None, kv_seg=None,
):
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + i * bq + spec.q_offset
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + j * bk
    mask = cols < kv_valid
    if q_seg is not None:
        mask = mask & (q_seg[:, None] == kv_seg[None, :])
    if spec.causal:
        mask = mask & (rows >= cols)
        if spec.window is not None:
            in_win = rows - cols < spec.window
            if spec.sink:
                in_win = in_win | (cols < spec.sink)
            mask = mask & in_win
    elif spec.window is not None:
        in_win = abs_diff(rows, cols) < spec.window
        if spec.sink:
            in_win = in_win | (cols < spec.sink)
        mask = mask & in_win
    return mask


def _fwd_kernel(
    *refs,  # inputs [+ optional segment-id refs], outputs, VMEM scratch
    spec: MaskSpec,
    bq: int,
    bk: int,
    t_kv: int,
    kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, qs_ref, ks_ref,
         o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]  # (bq,), (bk,) int32
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
        q_seg = kv_seg = None
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        q = q_ref[0]  # (bq, d) -- pre-scaled by 1/sqrt(d) in ops.py
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
        s = jnp.where(jnp.logical_or(~needs_mask, mask), s, DEFAULT_MASK_VALUE)

        m_prev = m_scr[:, :1]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_new))
        p = jnp.exp(s - m_new)
        l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # C1a: accumulate UN-rescaled; only the running-max correction.
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == t_kv - 1)
    def _finalize():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        m = m_scr[:, :1]
        lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
        lse_ref[0] = jnp.broadcast_to(lse, lse_ref.shape[1:])


def flash_fwd(
    q: jnp.ndarray,  # (BH, Sq, D), pre-scaled
    k: jnp.ndarray,  # (BHk, Skp, D)
    v: jnp.ndarray,
    spec: MaskSpec,
    *,
    group: int,  # G = Hq // Hkv
    block_q: int,
    block_kv: int,
    kv_valid: int,  # unpadded KV length
    q_seg: Optional[jnp.ndarray] = None,  # (BH, Sq) int32 segment ids
    kv_seg: Optional[jnp.ndarray] = None,  # (BHk, Skp) int32
    interpret: bool = True,
):
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    assert Sq % block_q == 0 and Skp % block_kv == 0
    t_q, t_kv = Sq // block_q, Skp // block_kv
    grid = (BH, t_q, t_kv)
    has_segments = q_seg is not None

    kernel = functools.partial(
        _fwd_kernel, spec=spec, bq=block_q, bk=block_kv, t_kv=t_kv,
        kv_valid=kv_valid, has_segments=has_segments,
    )
    # Roofline-honest cost: count only visible tiles (block skipping).
    # (Segment skipping is data-dependent, so the static spec-only count is
    # an upper bound there.)
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    flops_per_tile = 2 * block_q * block_kv * D * 2  # QK^T + PV
    kv_tile_bytes = 2 * block_kv * D * k.dtype.itemsize  # K + V tiles streamed
    cost = pl.CostEstimate(
        flops=BH * n_vis * flops_per_tile,
        bytes_accessed=2 * q.size * q.dtype.itemsize + BH * n_vis * kv_tile_bytes,
        transcendentals=BH * n_vis * block_q * block_kv,
    )

    in_specs = [
        pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0)),
        pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0)),
        pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0)),
    ]
    inputs = [q, k, v]
    if has_segments:
        in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((1, block_kv), lambda bh, i, j, g=group: (bh // g, j)),
        ]
        inputs += [q_seg, kv_seg]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_q, LANES), lambda bh, i, j: (bh, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq, LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_fwd_varlen" if has_segments else "fa2_fwd",
    )(*inputs)
