"""FlashAttention-2 forward Pallas TPU kernel.

TPU mapping of the paper's scheme (DESIGN.md Section 2):

  * Grid: (batch x heads) is `parallel`; the KV dimension is the sequential
    (`arbitrary`) axis, which makes the VMEM scratch carry the online-
    softmax state across KV steps.
  * ``schedule="compact"`` (default): the sequential axis enumerates ONLY
    the visible (i, j) tile pairs -- flattened q-row-major into a scalar-
    prefetched schedule table (kernels/schedule.py), grid ``(BH, n_steps)``.
    Spec-masked tiles are never *visited*: the paper's Section 3.1 work
    partitioning moved from an in-kernel branch into the grid itself, so
    causal drops ~2x of the grid steps and K/V tile DMAs, sliding-window
    O(S/W)x. Packed-varlen visibility is data-dependent and cannot shrink
    the (static) grid; cross-segment tiles still occupy a step but skip
    their *compute* via a prefetched per-(batch, step) bit table -- no
    in-kernel segment-id min/max probing.
  * Occupancy-aware forward partitioning (paper Section 3.2, Figure 2):
    the compact schedule optionally splits each head's work over a second
    *parallel* grid axis -- ``num_q_bands`` q-row bands (balanced by
    visible tile count; bitwise-equal to unbanded) and/or ``kv_splits``
    contiguous KV ranges emitting (o, lse) partials merged outside the
    kernel. Grid ``(BH, bands * splits, n_steps_part)``, so small-BH /
    long-S shapes still fill the chip. See
    ``schedule.build_partitioned_schedule`` and ``ops.
    default_forward_partitions`` (the shape-aware auto policy).
  * ``schedule="dense"``: the legacy ``(BH, Tq, Tkv)`` grid that visits
    every tile and skips empty ones with ``pl.when`` (kept as the
    measurable baseline; the matmuls are skipped but the grid step and its
    tile DMA still happen).
  * "Split-Q" warp partitioning (C3) becomes q-block-stationary scheduling:
    the Q tile is fetched once per row run and stays in VMEM while K/V
    stream past; the accumulator never leaves VMEM scratch. There is no
    cross-"worker" communication, exactly as in the paper's Figure 3 right.
  * C1: the accumulator is un-rescaled until the final KV step, where we
    apply ``diag(l)^-1`` once and emit the logsumexp.
  * The logsumexp is emitted LANE-MAJOR: ``(BH, Sq)`` f32 with the sequence
    on the 128-lane axis, BlockSpec ``(1, block_q)`` -- 128x fewer softmax-
    stat bytes than the historical ``(BH, Sq, LANES)`` broadcast. The
    backward consumes the same layout; decode's split merge reuses it.

Layout contract (set up by ops.py): q (BH, Sq, D), k/v (BHk, Skv, D) with
BH = B * Hq, BHk = B * Hkv, q head ``h`` reading kv head ``h // G``.
All sequence lengths pre-padded to the block size; KV padding masked here.
Segment ids (packed varlen) arrive UNREPLICATED as (B, Sqp)/(B, Skp); the
index maps divide the head-row id by the head count.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels.compat import CompilerParams, resolve_interpret
from repro.kernels.schedule import (
    build_partitioned_schedule,
    build_tile_schedule,
    decode_step_bits,
    segment_step_tables,
)

LANES = 128


def _visibility(
    spec: MaskSpec, i, j, bq: int, bk: int, kv_valid: int,
    q_seg=None, kv_seg=None,
):
    """In-kernel scalar visibility: returns (is_empty, needs_mask) bools.

    Used by the DENSE schedule only -- the compact schedule precomputes the
    same classification host-side (kernels/schedule.py) and prefetches it.

    i/j are (traced) program ids; spec fields and block sizes are static, so
    every branch below is a static Python branch over *which* scalar ops to
    emit -- the emitted ops themselves are traced scalar arithmetic.

    q_seg/kv_seg: optional loaded (bq,)/(bk,) int32 segment-id tiles (packed
    varlen). Their min/max ranges drive *data-dependent* block skipping: a
    tile whose id ranges are disjoint cannot contain an equal pair, so it is
    empty -- sound for any id layout, and exact for contiguous packing. A
    tile is mask-free only if both sides are uniform and equal.
    """
    q_lo = i * bq + spec.q_offset
    q_hi = q_lo + bq - 1
    kv_lo = j * bk
    kv_hi = kv_lo + bk - 1
    empty = jnp.bool_(False)
    full = jnp.bool_(True)
    if spec.causal:
        empty = q_hi < kv_lo
        full = q_lo >= kv_hi
        if spec.window is not None:
            win_empty = (q_lo - kv_hi) >= spec.window
            if spec.sink:
                win_empty = win_empty & ~(kv_lo < spec.sink)
            empty = empty | win_empty
            in_win = (q_hi - kv_lo) < spec.window
            if spec.sink:
                in_win = in_win | (kv_hi < spec.sink)
            full = full & in_win
    elif spec.window is not None:
        win_empty = ((q_lo - kv_hi) >= spec.window) | ((kv_lo - q_hi) >= spec.window)
        if spec.sink:
            win_empty = win_empty & ~(kv_lo < spec.sink)
        empty = win_empty
        full = (abs_diff(q_lo, kv_hi) < spec.window) & (abs_diff(q_hi, kv_lo) < spec.window)
        if spec.sink:
            full = full | (kv_hi < spec.sink)
    if kv_valid % bk != 0:
        # last block contains padding -> not full there
        pad_block = kv_valid // bk
        empty = empty | (kv_lo >= kv_valid)
        full = full & (j != pad_block)
    if q_seg is not None:
        qs_lo, qs_hi = jnp.min(q_seg), jnp.max(q_seg)
        ks_lo, ks_hi = jnp.min(kv_seg), jnp.max(kv_seg)
        empty = empty | (qs_hi < ks_lo) | (qs_lo > ks_hi)
        full = full & (qs_lo == qs_hi) & (ks_lo == ks_hi) & (qs_lo == ks_lo)
    return jnp.bool_(empty), ~jnp.bool_(full)


def abs_diff(a, b):
    d = a - b
    return jnp.where(d < 0, -d, d)


def _tile_mask(
    spec: MaskSpec, i, j, bq: int, bk: int, kv_valid: int,
    q_seg=None, kv_seg=None,
):
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + i * bq + spec.q_offset
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1) + j * bk
    mask = cols < kv_valid
    if q_seg is not None:
        mask = mask & (q_seg[:, None] == kv_seg[None, :])
    if spec.causal:
        mask = mask & (rows >= cols)
        if spec.window is not None:
            in_win = rows - cols < spec.window
            if spec.sink:
                in_win = in_win | (cols < spec.sink)
            mask = mask & in_win
    elif spec.window is not None:
        in_win = abs_diff(rows, cols) < spec.window
        if spec.sink:
            in_win = in_win | (cols < spec.sink)
        mask = mask & in_win
    return mask


# ---------------------------------------------------------------------------
# Shared tile-step bodies (used by both schedules)
# ---------------------------------------------------------------------------


def _init_state(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _online_softmax_step(q, k, v, mask, needs_mask, m_scr, l_scr, acc_scr):
    """One KV-tile update (FA2 Algorithm 1 lines 8-10, C1a un-rescaled)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)
    s = jnp.where(jnp.logical_or(~needs_mask, mask), s, DEFAULT_MASK_VALUE)

    m_prev = m_scr[:, :1]  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_new))
    p = jnp.exp(s - m_new)
    l_new = l_scr[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    # C1a: accumulate UN-rescaled; only the running-max correction.
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)


def _finalize_state(o_ref, lse_ref, m_scr, l_scr, acc_scr):
    """C1a final rescale + the lane-major logsumexp emit."""
    l = l_scr[:, :1]
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
    m = m_scr[:, :1]
    lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
    lse_ref[0] = lse[:, 0]  # (bq,) on the lane axis


# ---------------------------------------------------------------------------
# Dense schedule (legacy baseline): visit every tile, branch-skip empties
# ---------------------------------------------------------------------------


def _fwd_kernel_dense(
    *refs,  # inputs [+ optional segment-id refs], outputs, VMEM scratch
    spec: MaskSpec,
    bq: int,
    bk: int,
    t_kv: int,
    kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, qs_ref, ks_ref,
         o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]  # (bq,), (bk,) int32
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
        q_seg = kv_seg = None
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_state(m_scr, l_scr, acc_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
        _online_softmax_step(
            q_ref[0], k_ref[0], v_ref[0], mask, needs_mask, m_scr, l_scr, acc_scr
        )

    @pl.when(j == t_kv - 1)
    def _finalize():
        _finalize_state(o_ref, lse_ref, m_scr, l_scr, acc_scr)


# ---------------------------------------------------------------------------
# Compact schedule: the grid IS the visible-tile list
# ---------------------------------------------------------------------------


def _fwd_kernel_compact(
    *refs,  # scalar-prefetch refs, inputs [+ seg tiles], outputs, scratch
    spec: MaskSpec,
    bq: int,
    bk: int,
    kv_valid: int,
    heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, qs_ref, ks_ref,
         o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    i = outer_ref[s]
    j = inner_ref[s]
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[s], seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(first)
    def _init():
        _init_state(m_scr, l_scr, acc_scr)

    @pl.when(active)
    def _compute():
        mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
        _online_softmax_step(
            q_ref[0], k_ref[0], v_ref[0], mask, needs_mask, m_scr, l_scr, acc_scr
        )

    @pl.when(last)
    def _finalize():
        _finalize_state(o_ref, lse_ref, m_scr, l_scr, acc_scr)


def _fwd_kernel_partitioned(
    *refs,  # scalar-prefetch refs, inputs [+ seg tiles], outputs, scratch
    spec: MaskSpec,
    bq: int,
    bk: int,
    kv_valid: int,
    heads: int,
    has_segments: bool = False,
):
    """Compact step body on the partitioned grid (BH, P, n_steps_part).

    Identical tile math to ``_fwd_kernel_compact``; the partition id ``p``
    (a *parallel* axis -- the paper's Figure 2 forward split) picks the row
    of the 2-D schedule tables. Each partition runs its own q-row runs with
    its own scratch; there is no cross-partition communication. Padding
    placeholder steps (flags == 0) run no compute and revisit the last
    emitted blocks, so they cost neither exps nor DMAs.
    """
    if has_segments:
        (outer_ref, inner_ref, flags_ref, pkv_ref, seg_ref,
         q_ref, k_ref, v_ref, qs_ref, ks_ref,
         o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref, pkv_ref,
         q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr) = refs
        q_seg = kv_seg = None
    del pkv_ref  # output index maps read it; the body does not
    bh = pl.program_id(0)
    p = pl.program_id(1)
    s = pl.program_id(2)
    i = outer_ref[p, s]
    j = inner_ref[p, s]
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[p, s], seg_ref[bh // heads, p, s] if has_segments else None
    )

    @pl.when(first)
    def _init():
        _init_state(m_scr, l_scr, acc_scr)

    @pl.when(active)
    def _compute():
        mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
        _online_softmax_step(
            q_ref[0], k_ref[0], v_ref[0], mask, needs_mask, m_scr, l_scr, acc_scr
        )

    @pl.when(last)
    def _finalize():
        _finalize_state(o_ref, lse_ref, m_scr, l_scr, acc_scr)


def _fwd_cost(BH, n_vis, block_q, block_kv, D, q, k):
    """Roofline-honest cost: count only visible tiles (block skipping)."""
    flops_per_tile = 2 * block_q * block_kv * D * 2  # QK^T + PV
    kv_tile_bytes = 2 * block_kv * D * k.dtype.itemsize  # K + V tiles streamed
    return pl.CostEstimate(
        flops=BH * n_vis * flops_per_tile,
        bytes_accessed=2 * q.size * q.dtype.itemsize + BH * n_vis * kv_tile_bytes,
        transcendentals=BH * n_vis * block_q * block_kv,
    )


def flash_fwd(
    q: jnp.ndarray,  # (BH, Sq, D), pre-scaled
    k: jnp.ndarray,  # (BHk, Skp, D)
    v: jnp.ndarray,
    spec: MaskSpec,
    *,
    group: int,  # G = Hq // Hkv
    block_q: int,
    block_kv: int,
    kv_valid: int,  # unpadded KV length
    q_seg: Optional[jnp.ndarray] = None,  # (B, Sqp) int32 segment ids
    kv_seg: Optional[jnp.ndarray] = None,  # (B, Skp) int32
    interpret: Optional[bool] = None,
    schedule: str = "compact",
    num_q_bands: int = 1,
    kv_splits: int = 1,
):
    """FA2 forward on prepped (head-major, padded) tensors.

    ``num_q_bands`` / ``kv_splits`` (compact schedule only) apply the
    paper's Section 3.2 forward partitioning: the grid grows a *parallel*
    partition axis over q-row bands x contiguous kv ranges (see
    ``schedule.build_partitioned_schedule``). With ``kv_splits == 1`` the
    return contract is unchanged -- ``(o (BH, Sq, D), lse (BH, Sq))``,
    bitwise-equal to the unbanded schedule. With ``kv_splits > 1`` the
    kernel returns *partials* ``(o_parts (BH, kv_splits, Sq, D) f32,
    lse_parts (BH, kv_splits, Sq) f32)`` for the caller to fold with
    ``online_softmax.merge_partials`` (ops.py does).
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    assert Sq % block_q == 0 and Skp % block_kv == 0
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    num_q_bands = max(1, min(num_q_bands, t_q))
    kv_splits = max(1, min(kv_splits, t_kv))
    if schedule == "dense" and (num_q_bands > 1 or kv_splits > 1):
        raise ValueError(
            "num_q_bands/kv_splits partition the compact schedule; the dense "
            "grid already keeps its q-tile axis parallel"
        )

    # (Segment skipping is data-dependent, so the static spec-only count is
    # an upper bound there.)
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = _fwd_cost(BH, n_vis, block_q, block_kv, D, q, k)
    out_shape = [
        jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        jax.ShapeDtypeStruct((BH, Sq), jnp.float32),  # lane-major lse
    ]
    scratch_shapes = [
        pltpu.VMEM((block_q, LANES), jnp.float32),
        pltpu.VMEM((block_q, LANES), jnp.float32),
        pltpu.VMEM((block_q, D), jnp.float32),
    ]

    if schedule == "dense":
        kernel = functools.partial(
            _fwd_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_kv=t_kv,
            kv_valid=kv_valid, has_segments=has_segments,
        )
        in_specs = [
            pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0)),
        ]
        inputs = [q, k, v]
        if has_segments:
            heads = BH // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, i, j, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, i, j, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BH, t_q, t_kv),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0)),
                pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
            ],
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_fwd_varlen" if has_segments else "fa2_fwd",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    heads = BH // q_seg.shape[0] if has_segments else 1
    if num_q_bands > 1 or kv_splits > 1:
        return _flash_fwd_partitioned(
            q, k, v, spec, group=group, block_q=block_q, block_kv=block_kv,
            kv_valid=kv_valid, q_seg=q_seg, kv_seg=kv_seg, heads=heads,
            interpret=interpret, num_q_bands=num_q_bands, kv_splits=kv_splits,
            cost=cost, t_q=t_q, t_kv=t_kv,
        )
    sched = build_tile_schedule(spec, t_q, t_kv, block_q, block_kv, kv_valid)
    kernel = functools.partial(
        _fwd_kernel_compact, spec=spec, bq=block_q, bk=block_kv,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    # index maps receive the scalar-prefetch refs after the grid ids
    in_specs = [
        pl.BlockSpec((1, block_q, D), lambda bh, s, o_, i_, f_, *_: (bh, o_[s], 0)),
        pl.BlockSpec(
            (1, block_kv, D), lambda bh, s, o_, i_, f_, *_, g=group: (bh // g, i_[s], 0)
        ),
        pl.BlockSpec(
            (1, block_kv, D), lambda bh, s, o_, i_, f_, *_, g=group: (bh // g, i_[s], 0)
        ),
    ]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv)
        )
        in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, o_[s])),
            pl.BlockSpec((1, block_kv), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, i_[s])),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BH, sched.n_steps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, s, o_, i_, f_, *_: (bh, o_[s], 0)),
            pl.BlockSpec((1, block_q), lambda bh, s, o_, i_, f_, *_: (bh, o_[s])),
        ],
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_fwd_compact_varlen" if has_segments else "fa2_fwd_compact",
    )(*scalar_args, *inputs)


def _flash_fwd_partitioned(
    q, k, v, spec: MaskSpec, *, group, block_q, block_kv, kv_valid,
    q_seg, kv_seg, heads, interpret, num_q_bands, kv_splits, cost, t_q, t_kv,
):
    """Compact forward on the partitioned grid ``(BH, P, n_steps_part)``.

    The partition axis is ``parallel`` (dimension semantics); with
    ``kv_splits > 1`` the outputs are per-split partials (see flash_fwd's
    docstring for the return contract).
    """
    BH, Sq, D = q.shape
    has_segments = q_seg is not None
    sched = build_partitioned_schedule(
        spec, t_q, t_kv, block_q, block_kv, kv_valid, num_q_bands, kv_splits
    )
    P, ks = sched.num_parts, sched.kv_splits
    kernel = functools.partial(
        _fwd_kernel_partitioned, spec=spec, bq=block_q, bk=block_kv,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    # index maps receive the scalar-prefetch refs after the 3 grid ids
    in_specs = [
        pl.BlockSpec(
            (1, block_q, D), lambda bh, p, s, o_, i_, f_, k_, *_: (bh, o_[p, s], 0)
        ),
        pl.BlockSpec(
            (1, block_kv, D),
            lambda bh, p, s, o_, i_, f_, k_, *_, g=group: (bh // g, i_[p, s], 0),
        ),
        pl.BlockSpec(
            (1, block_kv, D),
            lambda bh, p, s, o_, i_, f_, k_, *_, g=group: (bh // g, i_[p, s], 0),
        ),
    ]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner),
        jnp.asarray(sched.flags), jnp.asarray(sched.part_kv),
    ]
    inputs = [q, k, v]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q),
                lambda bh, p, s, o_, i_, f_, k_, t_, h=heads: (bh // h, o_[p, s]),
            ),
            pl.BlockSpec(
                (1, block_kv),
                lambda bh, p, s, o_, i_, f_, k_, t_, h=heads: (bh // h, i_[p, s]),
            ),
        ]
        inputs += [q_seg, kv_seg]
    if ks == 1:
        # bands only: same outputs as the unbanded schedule, bitwise-equal
        # (each q row runs its unchanged kv visit sequence, just on a
        # different parallel grid cell).
        out_shape = [
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ]
        out_specs = [
            pl.BlockSpec(
                (1, block_q, D), lambda bh, p, s, o_, i_, f_, k_, *_: (bh, o_[p, s], 0)
            ),
            pl.BlockSpec(
                (1, block_q), lambda bh, p, s, o_, i_, f_, k_, *_: (bh, o_[p, s])
            ),
        ]
    else:
        # split-KV partials: each split emits a locally-normalized (o, lse)
        # plane, folded by merge_partials in ops.py. f32 so the fold does
        # not round through the storage dtype. Split planes are flattened
        # into the leading axis (row bh*ks + split) to keep the kernel's
        # output refs rank-identical to the unsplit path.
        out_shape = [
            jax.ShapeDtypeStruct((BH * ks, Sq, D), jnp.float32),
            jax.ShapeDtypeStruct((BH * ks, Sq), jnp.float32),
        ]
        out_specs = [
            pl.BlockSpec(
                (1, block_q, D),
                lambda bh, p, s, o_, i_, f_, k_, *_, n=ks: (bh * n + k_[p], o_[p, s], 0),
            ),
            pl.BlockSpec(
                (1, block_q),
                lambda bh, p, s, o_, i_, f_, k_, *_, n=ks: (bh * n + k_[p], o_[p, s]),
            ),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BH, P, sched.n_steps),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
    )
    name = "fa2_fwd_splitkv" if ks > 1 else "fa2_fwd_banded"
    o, lse = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name=name + "_varlen" if has_segments else name,
    )(*scalar_args, *inputs)
    if ks == 1:
        return o, lse
    return o.reshape(BH, ks, Sq, D), lse.reshape(BH, ks, Sq)
