"""Split-KV flash decode Pallas TPU kernel (C2 applied to inference).

One query token per sequence: the (batch x kv-heads) grid alone cannot fill
a TPU pod, so -- exactly as the paper parallelizes the forward over the
sequence axis -- we add a ``num_splits`` grid axis over the KV cache. Each
grid step computes a locally-normalized partial (o_c, lse_c) for its chunk;
the (cheap, O(splits)) merge runs in XLA via the associative online-softmax
combine. All G queries of a GQA group are processed against their shared KV
head in one step (the paper's MQA/GQA indexing note).

Layouts (ops.py): q (B*Hkv, G, D) pre-scaled; kv (B*Hkv, S, D);
lengths (B*Hkv,) int32 in SMEM. Outputs o_parts (B*Hkv, ns, G, D) fp32 and
lse_parts (B*Hkv, ns, G) fp32 -- lane-major, the same softmax-stat layout
contract as flash_fwd.py (DESIGN.md Section 2), merged in XLA by
``online_softmax.combine_lse_outputs``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE
from repro.kernels.compat import CompilerParams, resolve_interpret

LANES = 128


def _decode_kernel(
    *refs,  # SMEM lens [+ q segment], q/k/v [+ kv segment ids], outputs
    chunk: int, window: Optional[int], sink: int, has_segments: bool = False,
):
    if has_segments:
        len_ref, qseg_ref, q_ref, k_ref, v_ref, kseg_ref, o_ref, lse_ref = refs
    else:
        len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref = refs
    bh = pl.program_id(0)
    c = pl.program_id(1)
    L = len_ref[bh]

    q = q_ref[0]  # (G, D)
    k = k_ref[0]  # (chunk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + c * chunk
    valid = cols < L
    if has_segments:
        # Packed cache: never read across a segment boundary (the query
        # belongs to exactly one segment of its cache row).
        valid = valid & (kseg_ref[0][None, :] == qseg_ref[bh])
    if window is not None:
        in_win = cols >= L - window
        if sink:
            in_win = in_win | (cols < sink)
        valid = valid & in_win
    s = jnp.where(valid, s, DEFAULT_MASK_VALUE)

    m = jnp.max(s, axis=-1, keepdims=True)  # (G, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    any_valid = jnp.any(valid, axis=-1, keepdims=True)
    l = jnp.where(any_valid, l, 0.0)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) / l_safe
    lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
    o_ref[0, 0] = jnp.where(any_valid, o, 0.0)
    lse_ref[0, 0] = lse[:, 0]  # (G,) lane-major


def flash_decode_kernel(
    q: jnp.ndarray,  # (BHk, G, D) pre-scaled
    k: jnp.ndarray,  # (BHk, S, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (BHk,) int32
    *,
    num_splits: int = 8,
    window: Optional[int] = None,
    sink: int = 0,
    kv_seg: Optional[jnp.ndarray] = None,  # (BHk, S) int32 packed-cache ids
    q_seg: Optional[jnp.ndarray] = None,  # (BHk,) int32 query's segment
    interpret: Optional[bool] = None,
):
    interpret = resolve_interpret(interpret)
    BHk, G, D = q.shape
    _, S, _ = k.shape
    # Ceil-div split resolution. The historical `while S % ns: ns -= 1`
    # silently degraded to ns=1 for prime/odd cache lengths -- the C2
    # parallelism gone exactly when the cache is ragged. Instead: 8-aligned
    # (sublane) ceil-div chunks, the cache padded up to ns*chunk, and the
    # tail masked by the existing `cols < L` guard (pad cols sit at logical
    # positions >= S >= L), so the partial merge stays exact.
    ns = max(1, min(num_splits, -(-S // 8)))
    chunk = -(-(-(-S // ns)) // 8) * 8  # ceil(ceil(S/ns) / 8) * 8
    ns = -(-S // chunk)
    pad = ns * chunk - S
    if pad:
        # jnp.pad copies the whole cache; serving allocates chunk-aligned
        # caches (prompt_pad buckets) so this triggers only for genuinely
        # ragged capacities -- allocate aligned if decode is hot there.
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        if kv_seg is not None:
            # any id never equal to a real q segment: pad cols are masked by
            # cols < L already; -1 keeps them inert even if L were wrong
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-1)
    has_segments = kv_seg is not None
    kernel = functools.partial(
        _decode_kernel, chunk=chunk, window=window, sink=sink,
        has_segments=has_segments,
    )
    cost = pl.CostEstimate(
        flops=2 * BHk * G * S * D * 2,
        bytes_accessed=2 * k.size * k.dtype.itemsize + 2 * q.size * q.dtype.itemsize,
        transcendentals=BHk * G * S,
    )
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, G, D), lambda bh, c: (bh, 0, 0)),
        pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
        pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
    ]
    inputs = [lengths, q, k, v]
    if has_segments:
        in_specs.insert(1, pl.BlockSpec(memory_space=pltpu.SMEM))
        inputs.insert(1, q_seg)
        in_specs.append(pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)))
        inputs.append(kv_seg)
    return pl.pallas_call(
        kernel,
        grid=(BHk, ns),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda bh, c: (bh, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHk, ns, G, D), jnp.float32),
            jax.ShapeDtypeStruct((BHk, ns, G), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_decode_varlen" if has_segments else "fa2_decode",
    )(*inputs)


# ---------------------------------------------------------------------------
# Paged (block-table) split-KV decode
# ---------------------------------------------------------------------------


def _paged_decode_kernel(
    tbl_ref,  # scalar prefetch: (B, n_pages) int32 block table (read by maps)
    len_ref,  # scalar prefetch: (BHk,) int32 logical lengths
    q_ref,    # (1, G, D)
    k_ref,    # (1, 1, ps, D) -- the page the index map named
    v_ref,
    o_ref,    # (1, 1, G, D)
    lse_ref,  # (1, 1, G)
    m_scr,    # VMEM (G, LANES) f32
    l_scr,    # VMEM (G, LANES) f32
    acc_scr,  # VMEM (G, D) f32
    *, ps: int, pp: int, window: Optional[int], sink: int,
):
    """One (split, page) step of the page-indirect decode.

    The sequential ``p`` axis walks the split's pages with flash_fwd-style
    online-softmax scratch. A page is *skipped entirely* (``pl.when``) when
    the scalar arithmetic on (L, base, window, sink) proves every column
    masked -- so a free/finished slot (L == 0, all-null table row) issues
    zero compute, and the per-page update for an *active* page is
    op-for-op the contiguous kernel's chunk math (bitwise-equal partials
    whenever one split == one page -- tests/test_paged.py pins it).
    """
    del tbl_ref  # index maps read it; the body only needs lengths
    bh = pl.program_id(0)
    c = pl.program_id(1)
    p = pl.program_id(2)
    L = len_ref[bh]
    base = (c * pp + p) * ps  # logical position of this page's column 0

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Page-level visibility, purely from scalars: an active page always has
    # >= 1 valid column (proof in DESIGN.md Section 5.1), so the in-page
    # masking below never needs the contiguous kernel's any_valid guard --
    # fully-masked pages (which would corrupt l with exp(0) garbage) are
    # exactly the skipped ones.
    active = base < L
    if window is not None:
        in_win = base + ps > L - window
        if sink:
            in_win = in_win | (base < sink)
        active = active & in_win

    @pl.when(active)
    def _step():
        q = q_ref[0]      # (G, D)
        k = k_ref[0, 0]   # (ps, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + base
        valid = cols < L
        if window is not None:
            in_win = cols >= L - window
            if sink:
                in_win = in_win | (cols < sink)
            valid = valid & in_win
        s = jnp.where(valid, s, DEFAULT_MASK_VALUE)
        m_prev = m_scr[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # First touched page: m_prev = -inf -> alpha = 0, and 0 * prev + x
        # leaves x bitwise intact -- the single-page path IS the contiguous
        # kernel's math.
        alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_new))
        pexp = jnp.exp(s - m_new)
        l_new = l_scr[:, :1] * alpha + jnp.sum(pexp, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(p == pp - 1)
    def _finalize():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = acc_scr[...] / l_safe
        lse = jnp.where(l == 0.0, -jnp.inf, m_scr[:, :1] + jnp.log(l_safe))
        lse_ref[0, 0] = lse[:, 0]  # (G,) lane-major


def flash_decode_paged_kernel(
    q: jnp.ndarray,  # (BHk, G, D) pre-scaled
    k_pages: jnp.ndarray,  # (Hk, P, ps, D) physical page planes
    v_pages: jnp.ndarray,
    lengths: jnp.ndarray,  # (BHk,) int32 logical lengths
    block_table: jnp.ndarray,  # (B, n_pages) int32 logical -> physical page
    *,
    num_splits: int = 8,
    window: Optional[int] = None,
    sink: int = 0,
    interpret: Optional[bool] = None,
):
    """Split-KV decode that never sees a contiguous cache.

    Each KV split covers ``pp = ceil(n_pages / num_splits)`` *logical*
    pages; the k/v index maps dereference the prefetched block table
    (``PrefetchScalarGridSpec`` -- the same scalar-prefetch contract as
    kernels/schedule.py) so the DMA engine fetches physical page
    ``tbl[b, c*pp + p]`` directly from the pool plane. Physical page order
    is irrelevant to the math (shuffle-invariance is tested bitwise).
    Table entries past a sequence's live pages must point at the null page
    (0): their DMA is a cheap repeat and their compute is skipped.

    Returns per-split partials ``(o_parts (BHk, ns, G, D) f32,
    lse_parts (BHk, ns, G) f32)`` for ``combine_lse_outputs``.
    """
    interpret = resolve_interpret(interpret)
    BHk, G, D = q.shape
    Hk, _, ps, _ = k_pages.shape
    B, n_pages = block_table.shape
    assert BHk == B * Hk, (BHk, B, Hk)
    ns = max(1, min(num_splits, n_pages))
    pp = -(-n_pages // ns)  # logical pages per split
    ns = -(-n_pages // pp)
    pad = ns * pp - n_pages
    tbl = block_table.astype(jnp.int32)
    if pad:
        # Padded table columns are logical positions >= n_pages*ps >= L:
        # never active; the null page keeps their DMA well-defined.
        tbl = jnp.pad(tbl, ((0, 0), (0, pad)))
    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, pp=pp, window=window, sink=sink,
    )
    cost = pl.CostEstimate(
        flops=2 * BHk * G * n_pages * ps * D * 2,
        bytes_accessed=2 * B * n_pages * ps * D * k_pages.dtype.itemsize
        + 2 * q.size * q.dtype.itemsize,
        transcendentals=BHk * G * n_pages * ps,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block table + lengths
        grid=(BHk, ns, pp),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, c, p, tbl_, len_: (bh, 0, 0)),
            pl.BlockSpec(
                (1, 1, ps, D),
                lambda bh, c, p, tbl_, len_, h=Hk, n=pp: (
                    bh % h, tbl_[bh // h, c * n + p], 0, 0
                ),
            ),
            pl.BlockSpec(
                (1, 1, ps, D),
                lambda bh, c, p, tbl_, len_, h=Hk, n=pp: (
                    bh % h, tbl_[bh // h, c * n + p], 0, 0
                ),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda bh, c, p, *_: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda bh, c, p, *_: (bh, c, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BHk, ns, G, D), jnp.float32),
            jax.ShapeDtypeStruct((BHk, ns, G), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_decode_paged",
    )(tbl, lengths.astype(jnp.int32), q, k_pages, v_pages)
