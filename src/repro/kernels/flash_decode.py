"""Split-KV flash decode Pallas TPU kernel (C2 applied to inference).

One query token per sequence: the (batch x kv-heads) grid alone cannot fill
a TPU pod, so -- exactly as the paper parallelizes the forward over the
sequence axis -- we add a ``num_splits`` grid axis over the KV cache. Each
grid step computes a locally-normalized partial (o_c, lse_c) for its chunk;
the (cheap, O(splits)) merge runs in XLA via the associative online-softmax
combine. All G queries of a GQA group are processed against their shared KV
head in one step (the paper's MQA/GQA indexing note).

Layouts (ops.py): q (B*Hkv, G, D) pre-scaled; kv (B*Hkv, S, D);
lengths (B*Hkv,) int32 in SMEM. Outputs o_parts (B*Hkv, ns, G, D) fp32 and
lse_parts (B*Hkv, ns, G) fp32 -- lane-major, the same softmax-stat layout
contract as flash_fwd.py (DESIGN.md Section 2), merged in XLA by
``online_softmax.combine_lse_outputs``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE
from repro.kernels.compat import CompilerParams, resolve_interpret

LANES = 128


def _decode_kernel(
    *refs,  # SMEM lens [+ q segment], q/k/v [+ kv segment ids], outputs
    chunk: int, window: Optional[int], sink: int, has_segments: bool = False,
):
    if has_segments:
        len_ref, qseg_ref, q_ref, k_ref, v_ref, kseg_ref, o_ref, lse_ref = refs
    else:
        len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref = refs
    bh = pl.program_id(0)
    c = pl.program_id(1)
    L = len_ref[bh]

    q = q_ref[0]  # (G, D)
    k = k_ref[0]  # (chunk, D)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + c * chunk
    valid = cols < L
    if has_segments:
        # Packed cache: never read across a segment boundary (the query
        # belongs to exactly one segment of its cache row).
        valid = valid & (kseg_ref[0][None, :] == qseg_ref[bh])
    if window is not None:
        in_win = cols >= L - window
        if sink:
            in_win = in_win | (cols < sink)
        valid = valid & in_win
    s = jnp.where(valid, s, DEFAULT_MASK_VALUE)

    m = jnp.max(s, axis=-1, keepdims=True)  # (G, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    any_valid = jnp.any(valid, axis=-1, keepdims=True)
    l = jnp.where(any_valid, l, 0.0)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) / l_safe
    lse = jnp.where(l == 0.0, -jnp.inf, m + jnp.log(l_safe))
    o_ref[0, 0] = jnp.where(any_valid, o, 0.0)
    lse_ref[0, 0] = lse[:, 0]  # (G,) lane-major


def flash_decode_kernel(
    q: jnp.ndarray,  # (BHk, G, D) pre-scaled
    k: jnp.ndarray,  # (BHk, S, D)
    v: jnp.ndarray,
    lengths: jnp.ndarray,  # (BHk,) int32
    *,
    num_splits: int = 8,
    window: Optional[int] = None,
    sink: int = 0,
    kv_seg: Optional[jnp.ndarray] = None,  # (BHk, S) int32 packed-cache ids
    q_seg: Optional[jnp.ndarray] = None,  # (BHk,) int32 query's segment
    interpret: Optional[bool] = None,
):
    interpret = resolve_interpret(interpret)
    BHk, G, D = q.shape
    _, S, _ = k.shape
    # Ceil-div split resolution. The historical `while S % ns: ns -= 1`
    # silently degraded to ns=1 for prime/odd cache lengths -- the C2
    # parallelism gone exactly when the cache is ragged. Instead: 8-aligned
    # (sublane) ceil-div chunks, the cache padded up to ns*chunk, and the
    # tail masked by the existing `cols < L` guard (pad cols sit at logical
    # positions >= S >= L), so the partial merge stays exact.
    ns = max(1, min(num_splits, -(-S // 8)))
    chunk = -(-(-(-S // ns)) // 8) * 8  # ceil(ceil(S/ns) / 8) * 8
    ns = -(-S // chunk)
    pad = ns * chunk - S
    if pad:
        # jnp.pad copies the whole cache; serving allocates chunk-aligned
        # caches (prompt_pad buckets) so this triggers only for genuinely
        # ragged capacities -- allocate aligned if decode is hot there.
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        if kv_seg is not None:
            # any id never equal to a real q segment: pad cols are masked by
            # cols < L already; -1 keeps them inert even if L were wrong
            kv_seg = jnp.pad(kv_seg, ((0, 0), (0, pad)), constant_values=-1)
    has_segments = kv_seg is not None
    kernel = functools.partial(
        _decode_kernel, chunk=chunk, window=window, sink=sink,
        has_segments=has_segments,
    )
    cost = pl.CostEstimate(
        flops=2 * BHk * G * S * D * 2,
        bytes_accessed=2 * k.size * k.dtype.itemsize + 2 * q.size * q.dtype.itemsize,
        transcendentals=BHk * G * S,
    )
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec((1, G, D), lambda bh, c: (bh, 0, 0)),
        pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
        pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0)),
    ]
    inputs = [lengths, q, k, v]
    if has_segments:
        in_specs.insert(1, pl.BlockSpec(memory_space=pltpu.SMEM))
        inputs.insert(1, q_seg)
        in_specs.append(pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)))
        inputs.append(kv_seg)
    return pl.pallas_call(
        kernel,
        grid=(BHk, ns),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda bh, c: (bh, c, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda bh, c: (bh, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BHk, ns, G, D), jnp.float32),
            jax.ShapeDtypeStruct((BHk, ns, G), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_decode_varlen" if has_segments else "fa2_decode",
    )(*inputs)
