"""Compact tile schedules for the Pallas kernels (DESIGN.md Section 2).

FlashAttention-2's Section 3.1 argument is about *work partitioning*: a
causal/window mask empties whole (q_block, kv_block) tiles, and a good
schedule never visits them. The historical kernels here visited every tile
and branch-skipped with ``pl.when`` -- the matmuls were saved but the grid
steps (and their K/V tile DMAs) were not. This module precomputes, per
kernel launch, the flattened list of *visible* tile pairs plus per-step
control flags; the kernels feed it through scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so the sequential grid axis has exactly
``n_steps`` entries and the index maps DMA only the tiles the schedule
names. Causal drops ~2x of the steps, sliding-window O(S/W)x.

Two orientations of the same schedule:

  * q-major (``kv_major=False``) -- used by ``flash_fwd`` / ``flash_bwd_dq``:
    steps are grouped by owning q tile ``i`` (the ``outer`` array), streaming
    its visible kv tiles ``j`` (``inner``).
  * kv-major (``kv_major=True``) -- used by ``flash_bwd_dkv``: grouped by
    owning kv tile ``j`` (``outer``), streaming visible q tiles ``i``.

An outer tile with *zero* visible partners still gets one placeholder step
(ACTIVE bit clear) so its init/finalize run and its output block is written
(zeros / -inf lse); that is the ``+ t_q`` slack in the step-count bound
``n_steps <= n_visible + n_outer``.

The static schedule is spec-only. Packed-varlen (segment) visibility is
data-dependent, so it rides along as a second, *dynamic* table built by
:func:`segment_step_tables` -- per (batch, step) bits computed with O(B * S)
jnp work outside the kernel and scalar-prefetched, replacing the in-kernel
per-tile segment-id min/max probing.

The step count is cross-checked against ``core.flash._visible_pairs`` -- the
shared schedule oracle -- at build time.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.masks import MaskSpec, tile_visibility

# Static per-step flag bits (TileSchedule.flags).
STEP_ACTIVE = 1  # tile contributes compute (clear on placeholder steps)
STEP_FIRST = 2   # first step of its outer-tile run -> init VMEM scratch
STEP_LAST = 4    # last step of its outer-tile run -> finalize / emit
STEP_MASKED = 8  # partial tile (or KV padding): apply the element mask
# kv-major only (the fused one-pass backward, flash_bwd.flash_bwd_fused):
# first/last visit of the *streamed q tile* anywhere in the flattened
# schedule. The fused kernel consumes QFIRST (zero-init its revisited dq
# output block + compute delta = rowsum(dO o O), so neither needs its own
# pass). QLAST is schedule metadata only today: revisit-accumulation
# writes dq on every visit, so there is no emit step -- the bit exists for
# accounting (tests assert the pair brackets each q tile's visits) and for
# an emit-style consumer (e.g. a variant that downcasts dq on last visit).
STEP_QFIRST = 16
STEP_QLAST = 32

# Dynamic per-(batch, step) segment bits (segment_step_tables).
SEG_ACTIVE = 1   # tile id ranges overlap (range-disjointness skip)
SEG_UNIFORM = 2  # both sides uniform and equal -> tile is mask-free


class TileSchedule(NamedTuple):
    """Flattened compact schedule (host-side numpy; static per launch)."""

    outer: np.ndarray  # (n_steps,) int32 -- owning tile index per step
    inner: np.ndarray  # (n_steps,) int32 -- streamed tile index per step
    flags: np.ndarray  # (n_steps,) int32 -- STEP_* bitmask
    n_active: int      # number of ACTIVE steps == visible tile count

    @property
    def n_steps(self) -> int:
        return len(self.outer)


@functools.lru_cache(maxsize=256)  # bounded: chunked prefill varies q_offset
def build_tile_schedule(
    spec: MaskSpec, t_q: int, t_kv: int, bq: int, bk: int, kv_valid: int,
    kv_major: bool = False,
) -> TileSchedule:
    """Build the compact schedule for a (t_q x t_kv) tile grid under spec.

    ``kv_valid`` is the unpadded KV length: tiles touching KV padding are
    flagged STEP_MASKED (never dropped -- the last tile always holds some
    real keys because padding is < one block).

    kv-major schedules additionally carry STEP_QFIRST / STEP_QLAST on the
    first / last step that streams each q tile (QFIRST drives the fused
    backward's dq zero-init + delta prologue; QLAST is accounting metadata,
    see the bit definitions above). A q tile no step streams
    (possible under exotic window / q_offset specs: its row attends
    nothing) gets an inactive placeholder appended at the tail so its dq
    block is still zeroed and its delta still written; the tail placeholder
    reuses the final outer tile, whose dk/dv windows were already emitted.
    """
    n_outer = t_kv if kv_major else t_q
    n_inner = t_q if kv_major else t_kv
    outer, inner, flags = [], [], []
    n_active = 0
    for a in range(n_outer):
        run = []
        for b in range(n_inner):
            i, j = (b, a) if kv_major else (a, b)
            q_lo = i * bq + spec.q_offset
            vis = tile_visibility(spec, q_lo, q_lo + bq, j * bk, j * bk + bk)
            if vis == "empty":
                continue
            run.append((b, vis == "partial" or (j + 1) * bk > kv_valid))
        if not run:
            # placeholder so the outer tile still inits + emits (zeros).
            outer.append(a)
            inner.append(0)
            flags.append(STEP_FIRST | STEP_LAST)
            continue
        for pos, (b, masked) in enumerate(run):
            f = STEP_ACTIVE
            f |= STEP_FIRST if pos == 0 else 0
            f |= STEP_LAST if pos == len(run) - 1 else 0
            f |= STEP_MASKED if masked else 0
            outer.append(a)
            inner.append(b)
            flags.append(f)
        n_active += len(run)
    if kv_major:
        # q-row visit bits for the fused backward (see docstring).
        first_seen: dict = {}
        last_seen: dict = {}
        for s, b in enumerate(inner):
            first_seen.setdefault(b, s)
            last_seen[b] = s
        tail = outer[-1] if outer else 0
        for b in range(n_inner):
            if b not in first_seen:
                outer.append(tail)
                inner.append(b)
                flags.append(0)
                first_seen[b] = last_seen[b] = len(inner) - 1
        for s in first_seen.values():
            flags[s] |= STEP_QFIRST
        for s in last_seen.values():
            flags[s] |= STEP_QLAST
    sched = TileSchedule(
        outer=np.asarray(outer, np.int32),
        inner=np.asarray(inner, np.int32),
        flags=np.asarray(flags, np.int32),
        n_active=n_active,
    )
    # Accounting invariant: the schedule's active steps are exactly the
    # oracle's visible tiles (core.flash._visible_pairs, row-major).
    from repro.core.flash import _visible_pairs

    assert sched.n_active == len(_visible_pairs(spec, t_q, t_kv, bq, bk)[0]), (
        "compact schedule disagrees with the _visible_pairs oracle"
    )
    return sched


def decode_step_bits(flags, seg_bits=None):
    """Shared in-kernel step decode: (active, first, last, needs_mask).

    ``flags`` is the loaded STEP_* bitmask for the current step;
    ``seg_bits`` the loaded (batch, step) segment bits or None. Used by all
    three compact kernels so a schedule-format change lands in one place.
    """
    active = (flags & STEP_ACTIVE) != 0
    needs_mask = (flags & STEP_MASKED) != 0
    if seg_bits is not None:
        active = jnp.logical_and(active, (seg_bits & SEG_ACTIVE) != 0)
        needs_mask = jnp.logical_or(needs_mask, (seg_bits & SEG_UNIFORM) == 0)
    return active, (flags & STEP_FIRST) != 0, (flags & STEP_LAST) != 0, needs_mask


def segment_step_tables(
    q_seg: jnp.ndarray,  # (B, Sqp) int32, padded with the masks.py sentinels
    kv_seg: jnp.ndarray,  # (B, Skp) int32
    sched: TileSchedule,
    bq: int,
    bk: int,
    kv_major: bool = False,
) -> jnp.ndarray:
    """Dynamic per-(batch, step) visibility bits for a packed batch.

    Returns (B, n_steps) int32 with SEG_ACTIVE / SEG_UNIFORM bits. ACTIVE
    uses per-tile id-range disjointness (sound for any id layout, exact for
    contiguous packing); UNIFORM means both tiles are constant and equal, so
    the element mask can be skipped. Computed as O(B * S) jnp reductions at
    trace time and scalar-prefetched -- no in-kernel min/max probing.
    """
    B = q_seg.shape[0]
    qt = q_seg.reshape(B, -1, bq)
    kt = kv_seg.reshape(B, -1, bk)
    q_lo, q_hi = qt.min(axis=-1), qt.max(axis=-1)  # (B, t_q)
    k_lo, k_hi = kt.min(axis=-1), kt.max(axis=-1)  # (B, t_kv)
    ii = jnp.asarray(sched.inner if kv_major else sched.outer)
    jj = jnp.asarray(sched.outer if kv_major else sched.inner)
    qlo, qhi = q_lo[:, ii], q_hi[:, ii]  # (B, n_steps)
    klo, khi = k_lo[:, jj], k_hi[:, jj]
    overlap = ~((qhi < klo) | (qlo > khi))
    uniform = (qlo == qhi) & (klo == khi) & (qlo == klo)
    return overlap.astype(jnp.int32) | (uniform.astype(jnp.int32) << 1)
