"""Compact tile schedules for the Pallas kernels (DESIGN.md Section 2).

FlashAttention-2's Section 3.1 argument is about *work partitioning*: a
causal/window mask empties whole (q_block, kv_block) tiles, and a good
schedule never visits them. The historical kernels here visited every tile
and branch-skipped with ``pl.when`` -- the matmuls were saved but the grid
steps (and their K/V tile DMAs) were not. This module precomputes, per
kernel launch, the flattened list of *visible* tile pairs plus per-step
control flags; the kernels feed it through scalar prefetch
(``pltpu.PrefetchScalarGridSpec``) so the sequential grid axis has exactly
``n_steps`` entries and the index maps DMA only the tiles the schedule
names. Causal drops ~2x of the steps, sliding-window O(S/W)x.

Two orientations of the same schedule:

  * q-major (``kv_major=False``) -- used by ``flash_fwd`` / ``flash_bwd_dq``:
    steps are grouped by owning q tile ``i`` (the ``outer`` array), streaming
    its visible kv tiles ``j`` (``inner``).
  * kv-major (``kv_major=True``) -- used by ``flash_bwd_dkv``: grouped by
    owning kv tile ``j`` (``outer``), streaming visible q tiles ``i``.

An outer tile with *zero* visible partners still gets one placeholder step
(ACTIVE bit clear) so its init/finalize run and its output block is written
(zeros / -inf lse); that is the ``+ t_q`` slack in the step-count bound
``n_steps <= n_visible + n_outer``.

The static schedule is spec-only. Packed-varlen (segment) visibility is
data-dependent, so it rides along as a second, *dynamic* table built by
:func:`segment_step_tables` -- per (batch, step) bits computed with O(B * S)
jnp work outside the kernel and scalar-prefetched, replacing the in-kernel
per-tile segment-id min/max probing.

The step count is cross-checked against ``core.flash._visible_pairs`` -- the
shared schedule oracle -- at build time.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.masks import MaskSpec, tile_visibility

# Static per-step flag bits (TileSchedule.flags).
STEP_ACTIVE = 1  # tile contributes compute (clear on placeholder steps)
STEP_FIRST = 2   # first step of its outer-tile run -> init VMEM scratch
STEP_LAST = 4    # last step of its outer-tile run -> finalize / emit
STEP_MASKED = 8  # partial tile (or KV padding): apply the element mask
# kv-major only (the fused one-pass backward, flash_bwd.flash_bwd_fused):
# first/last visit of the *streamed q tile* anywhere in the flattened
# schedule. The fused kernel consumes QFIRST (zero-init its revisited dq
# output block + compute delta = rowsum(dO o O), so neither needs its own
# pass). QLAST is schedule metadata only today: revisit-accumulation
# writes dq on every visit, so there is no emit step -- the bit exists for
# accounting (tests assert the pair brackets each q tile's visits) and for
# an emit-style consumer (e.g. a variant that downcasts dq on last visit).
STEP_QFIRST = 16
STEP_QLAST = 32

# Dynamic per-(batch, step) segment bits (segment_step_tables).
SEG_ACTIVE = 1   # tile id ranges overlap (range-disjointness skip)
SEG_UNIFORM = 2  # both sides uniform and equal -> tile is mask-free


class TileSchedule(NamedTuple):
    """Flattened compact schedule (host-side numpy; static per launch)."""

    outer: np.ndarray  # (n_steps,) int32 -- owning tile index per step
    inner: np.ndarray  # (n_steps,) int32 -- streamed tile index per step
    flags: np.ndarray  # (n_steps,) int32 -- STEP_* bitmask
    n_active: int      # number of ACTIVE steps == visible tile count

    @property
    def n_steps(self) -> int:
        return len(self.outer)


class PartitionedSchedule(NamedTuple):
    """Forward compact schedule split into parallel partitions.

    The paper's Section 3.2 forward partitioning applied to the compact
    schedule: the q tiles of each head are dealt into ``num_q_bands``
    bands (balanced by *visible* tile count) and, orthogonally, the kv
    tiles into ``kv_splits`` contiguous ranges. Each partition
    ``p = split * num_q_bands + band`` runs its band's q rows against its
    split's kv range on its own grid cell along a *parallel* axis -- no
    cross-partition communication, each band keeps its own online-softmax
    scratch. Tables are padded to the longest partition with compute-free
    placeholder steps (flags == 0, repeating the partition's final
    (outer, inner) so no extra tile is DMA'd).
    """

    outer: np.ndarray        # (P, n_steps) int32 -- owning q tile per step
    inner: np.ndarray        # (P, n_steps) int32 -- streamed kv tile per step
    flags: np.ndarray        # (P, n_steps) int32 -- STEP_* bitmask
    part_kv: np.ndarray      # (P,) int32 -- kv split index of each partition
    part_active: np.ndarray  # (P,) int64 -- visible tiles per partition
    n_active: int            # total visible tiles (== sum(part_active))
    num_q_bands: int
    kv_splits: int

    @property
    def n_steps(self) -> int:
        return self.outer.shape[1]

    @property
    def num_parts(self) -> int:
        return self.outer.shape[0]


def _tile_class(spec: MaskSpec, i: int, j: int, bq: int, bk: int, kv_valid: int):
    """None if tile (i, j) is spec-empty, else whether it needs the mask.

    THE shared per-tile classifier of both schedule builders (flat and
    partitioned) -- the bitwise-equality contract between them rides on
    the empty/masked predicate living in exactly one place.
    """
    q_lo = i * bq + spec.q_offset
    vis = tile_visibility(spec, q_lo, q_lo + bq, j * bk, j * bk + bk)
    if vis == "empty":
        return None
    return vis == "partial" or (j + 1) * bk > kv_valid


@functools.lru_cache(maxsize=256)  # bounded: chunked prefill varies q_offset
def build_tile_schedule(
    spec: MaskSpec, t_q: int, t_kv: int, bq: int, bk: int, kv_valid: int,
    kv_major: bool = False,
) -> TileSchedule:
    """Build the compact schedule for a (t_q x t_kv) tile grid under spec.

    ``kv_valid`` is the unpadded KV length: tiles touching KV padding are
    flagged STEP_MASKED (never dropped -- the last tile always holds some
    real keys because padding is < one block).

    kv-major schedules additionally carry STEP_QFIRST / STEP_QLAST on the
    first / last step that streams each q tile (QFIRST drives the fused
    backward's dq zero-init + delta prologue; QLAST is accounting metadata,
    see the bit definitions above). A q tile no step streams
    (possible under exotic window / q_offset specs: its row attends
    nothing) gets an inactive placeholder appended at the tail so its dq
    block is still zeroed and its delta still written; the tail placeholder
    reuses the final outer tile, whose dk/dv windows were already emitted.
    """
    n_outer = t_kv if kv_major else t_q
    n_inner = t_q if kv_major else t_kv
    outer, inner, flags = [], [], []
    n_active = 0
    for a in range(n_outer):
        run = []
        for b in range(n_inner):
            i, j = (b, a) if kv_major else (a, b)
            masked = _tile_class(spec, i, j, bq, bk, kv_valid)
            if masked is None:
                continue
            run.append((b, masked))
        if not run:
            # placeholder so the outer tile still inits + emits (zeros).
            outer.append(a)
            inner.append(0)
            flags.append(STEP_FIRST | STEP_LAST)
            continue
        for pos, (b, masked) in enumerate(run):
            f = STEP_ACTIVE
            f |= STEP_FIRST if pos == 0 else 0
            f |= STEP_LAST if pos == len(run) - 1 else 0
            f |= STEP_MASKED if masked else 0
            outer.append(a)
            inner.append(b)
            flags.append(f)
        n_active += len(run)
    if kv_major:
        # q-row visit bits for the fused backward (see docstring).
        first_seen: dict = {}
        last_seen: dict = {}
        for s, b in enumerate(inner):
            first_seen.setdefault(b, s)
            last_seen[b] = s
        tail = outer[-1] if outer else 0
        for b in range(n_inner):
            if b not in first_seen:
                outer.append(tail)
                inner.append(b)
                flags.append(0)
                first_seen[b] = last_seen[b] = len(inner) - 1
        for s in first_seen.values():
            flags[s] |= STEP_QFIRST
        for s in last_seen.values():
            flags[s] |= STEP_QLAST
    sched = TileSchedule(
        outer=np.asarray(outer, np.int32),
        inner=np.asarray(inner, np.int32),
        flags=np.asarray(flags, np.int32),
        n_active=n_active,
    )
    # Accounting invariant: the schedule's active steps are exactly the
    # oracle's visible tiles (core.flash._visible_pairs, row-major).
    from repro.core.flash import _visible_pairs

    assert sched.n_active == len(_visible_pairs(spec, t_q, t_kv, bq, bk)[0]), (
        "compact schedule disagrees with the _visible_pairs oracle"
    )
    return sched


def band_assignment(counts, num_bands: int):
    """Deal q rows into ``num_bands`` bands balanced by visible-tile count.

    Load of a row is ``max(count, 1)`` -- a fully-masked row still costs one
    placeholder step, and charging it spreads such rows across bands (every
    band keeps >= 1 row when ``num_bands <= len(counts)``).

    Two deterministic passes:

      1. *Quota fill*: per-band targets ``floor/ceil(total / num_bands)``,
         each band greedily taking the largest unassigned row that still
         fits its remaining quota. For a causal mask the row loads are the
         consecutive integers ``1..t_q`` (the regime where this always
         lands exactly on quota): the largest row pairs with its
         complement, reproducing ``ring_schedule``'s zigzag trick -- row
         ``i`` opposite row ``t_q - 1 - i`` -- so per-band visible totals
         balance to within ONE tile (tests/test_occupancy.py asserts the
         bound).
      2. If some band cannot reach its quota (irregular window/varlen
         count distributions), fall back to longest-processing-time: rows
         by (load desc, index asc), each to the lightest band.

    Returns ``num_bands`` ascending row-index lists.
    """
    loads = {r: max(c, 1) for r, c in enumerate(counts)}
    order = sorted(loads, key=lambda r: (-loads[r], r))
    total = sum(loads.values())
    q, rem = divmod(total, num_bands)
    quotas = [q + 1] * rem + [q] * (num_bands - rem)
    bands: list = [[] for _ in range(num_bands)]
    remaining = list(order)
    ok = True
    for b, quota in enumerate(quotas):
        while quota > 0 and remaining:
            pick = next((r for r in remaining if loads[r] <= quota), None)
            if pick is None:
                ok = False
                break
            remaining.remove(pick)
            bands[b].append(pick)
            quota -= loads[pick]
        if not ok or (quota > 0 and not remaining):
            ok = False
            break
    if not ok or remaining or any(not b for b in bands):
        # LPT fallback: near-balanced for arbitrary load distributions.
        band_loads = [0] * num_bands
        bands = [[] for _ in range(num_bands)]
        for r in order:
            b = min(range(num_bands), key=lambda i: (band_loads[i], i))
            band_loads[b] += loads[r]
            bands[b].append(r)
    for rows in bands:
        rows.sort()
    return bands


def kv_split_edges(t_kv: int, kv_splits: int):
    """Ceil-div contiguous kv-tile ranges [(j0, j1), ...] covering 0..t_kv.

    The first ``t_kv % kv_splits`` splits carry one extra tile
    (``np.array_split`` semantics) -- no silent degrade for prime/odd tile
    counts, mirroring the decode split fix.
    """
    base, extra = divmod(t_kv, kv_splits)
    edges, j0 = [], 0
    for s in range(kv_splits):
        j1 = j0 + base + (1 if s < extra else 0)
        edges.append((j0, j1))
        j0 = j1
    return edges


@functools.lru_cache(maxsize=256)
def build_partitioned_schedule(
    spec: MaskSpec, t_q: int, t_kv: int, bq: int, bk: int, kv_valid: int,
    num_q_bands: int = 1, kv_splits: int = 1,
) -> PartitionedSchedule:
    """Build the q-banded / split-KV forward schedule (paper Section 3.2).

    Same per-step contract as :func:`build_tile_schedule` q-major
    schedules, but the steps of each head are spread over
    ``num_q_bands * kv_splits`` partitions that the kernel runs on a
    *parallel* grid axis:

      * every q row belongs to exactly one band (``band_assignment``;
        balanced by visible tiles), and its kv visit order within a
        partition is unchanged ascending -- so with ``kv_splits == 1`` the
        banded kernel's per-row update sequence is IDENTICAL to the
        unbanded compact schedule (bitwise-equal outputs);
      * with ``kv_splits > 1`` each partition covers one contiguous kv-tile
        range; its finalize emits a *partial* (o, lse) for its rows, folded
        outside the kernel by ``online_softmax.merge_partials``. A row with
        no visible tile in some split gets the usual placeholder step
        (FIRST|LAST, ACTIVE clear), emitting the merge identity
        (o = 0, lse = -inf).

    Partition tables are padded to the longest partition with flags == 0
    steps that repeat the partition's last real (outer, inner) pair: the
    revisited blocks cost no new DMA and the step runs no compute (the
    occupancy benchmark's exp census asserts banding adds zero exps per
    visible tile).
    """
    num_q_bands = max(1, min(num_q_bands, t_q))
    kv_splits = max(1, min(kv_splits, t_kv))
    runs, counts = [], []
    for i in range(t_q):
        run = []
        for j in range(t_kv):
            masked = _tile_class(spec, i, j, bq, bk, kv_valid)
            if masked is None:
                continue
            run.append((j, masked))
        runs.append(run)
        counts.append(len(run))
    bands = band_assignment(tuple(counts), num_q_bands)
    parts, part_kv, part_active = [], [], []
    for s_idx, (j0, j1) in enumerate(kv_split_edges(t_kv, kv_splits)):
        for rows in bands:
            steps = []
            n_act = 0
            for i in rows:
                seg = [(j, m) for (j, m) in runs[i] if j0 <= j < j1]
                if not seg:
                    # placeholder: init + emit zeros / -inf (merge identity)
                    steps.append((i, j0, STEP_FIRST | STEP_LAST))
                    continue
                for pos, (j, m) in enumerate(seg):
                    f = STEP_ACTIVE
                    f |= STEP_FIRST if pos == 0 else 0
                    f |= STEP_LAST if pos == len(seg) - 1 else 0
                    f |= STEP_MASKED if m else 0
                    steps.append((i, j, f))
                n_act += len(seg)
            parts.append(steps)
            part_kv.append(s_idx)
            part_active.append(n_act)
    n_steps = max(len(p) for p in parts)
    P = len(parts)
    outer = np.zeros((P, n_steps), np.int32)
    inner = np.zeros((P, n_steps), np.int32)
    flags = np.zeros((P, n_steps), np.int32)
    for p, steps in enumerate(parts):
        for s, (i, j, f) in enumerate(steps):
            outer[p, s], inner[p, s], flags[p, s] = i, j, f
        # padding placeholders: repeat the last real pair, flags stay 0
        outer[p, len(steps):] = steps[-1][0]
        inner[p, len(steps):] = steps[-1][1]
    sched = PartitionedSchedule(
        outer=outer, inner=inner, flags=flags,
        part_kv=np.asarray(part_kv, np.int32),
        part_active=np.asarray(part_active, np.int64),
        n_active=int(sum(part_active)),
        num_q_bands=num_q_bands, kv_splits=kv_splits,
    )
    # Accounting invariant: partitions tile the oracle's visible set.
    from repro.core.flash import _visible_pairs

    assert sched.n_active == len(_visible_pairs(spec, t_q, t_kv, bq, bk)[0]), (
        "partitioned schedule disagrees with the _visible_pairs oracle"
    )
    return sched


def decode_step_bits(flags, seg_bits=None):
    """Shared in-kernel step decode: (active, first, last, needs_mask).

    ``flags`` is the loaded STEP_* bitmask for the current step;
    ``seg_bits`` the loaded (batch, step) segment bits or None. Used by all
    three compact kernels so a schedule-format change lands in one place.
    """
    active = (flags & STEP_ACTIVE) != 0
    needs_mask = (flags & STEP_MASKED) != 0
    if seg_bits is not None:
        active = jnp.logical_and(active, (seg_bits & SEG_ACTIVE) != 0)
        needs_mask = jnp.logical_or(needs_mask, (seg_bits & SEG_UNIFORM) == 0)
    return active, (flags & STEP_FIRST) != 0, (flags & STEP_LAST) != 0, needs_mask


def segment_step_tables(
    q_seg: jnp.ndarray,  # (B, Sqp) int32, padded with the masks.py sentinels
    kv_seg: jnp.ndarray,  # (B, Skp) int32
    sched: TileSchedule,
    bq: int,
    bk: int,
    kv_major: bool = False,
) -> jnp.ndarray:
    """Dynamic per-(batch, step) visibility bits for a packed batch.

    Returns (B, n_steps) int32 with SEG_ACTIVE / SEG_UNIFORM bits (for a
    :class:`PartitionedSchedule`, whose tables are (P, n_steps), the fancy
    indexing broadcasts to (B, P, n_steps) -- same bits per step). ACTIVE
    uses per-tile id-range disjointness (sound for any id layout, exact for
    contiguous packing); UNIFORM means both tiles are constant and equal, so
    the element mask can be skipped. Computed as O(B * S) jnp reductions at
    trace time and scalar-prefetched -- no in-kernel min/max probing.
    """
    B = q_seg.shape[0]
    qt = q_seg.reshape(B, -1, bq)
    kt = kv_seg.reshape(B, -1, bk)
    q_lo, q_hi = qt.min(axis=-1), qt.max(axis=-1)  # (B, t_q)
    k_lo, k_hi = kt.min(axis=-1), kt.max(axis=-1)  # (B, t_kv)
    ii = jnp.asarray(sched.inner if kv_major else sched.outer)
    jj = jnp.asarray(sched.outer if kv_major else sched.inner)
    qlo, qhi = q_lo[:, ii], q_hi[:, ii]  # (B, n_steps)
    klo, khi = k_lo[:, jj], k_hi[:, jj]
    overlap = ~((qhi < klo) | (qlo > khi))
    uniform = (qlo == qhi) & (klo == khi) & (qlo == klo)
    return overlap.astype(jnp.int32) | (uniform.astype(jnp.int32) << 1)
