"""Jit'd public wrappers for the Pallas kernels.

Handles: layout normalization ((B,S,H,D) -> per-head rows), padding to block
multiples, q pre-scaling, the fwd<->bwd pairing via ``jax.custom_vjp``
(Algorithm 1 + Algorithm 2), and the decode split merge. The pure-jnp oracle
lives in ref.py; parity is enforced by tests/test_flash_kernels.py.

Memory contract (DESIGN.md Section 2):

  * The ``custom_vjp`` boundary sits INSIDE the layout prep: the core
    differentiable function takes *prepped* tensors (head-major, padded,
    q pre-scaled) and its residuals are exactly those tensors plus the
    kernel outputs -- the backward never re-runs ``_prep`` (no re-transpose
    / re-pad / re-scale of q, k, v). The cheap layout ops around the core
    are differentiated by XLA itself.
  * The logsumexp is lane-major ``(BH, Sqp)`` f32 end to end (kernels emit
    it, the backward consumes it, decode's split merge reuses it) -- 128x
    fewer softmax-stat bytes than the old ``(BH, Sqp, LANES)`` broadcast,
    for both lse and delta.
  * The backward is ``bwd="fused"`` by default: ONE kv-major pallas_call
    (``flash_bwd.flash_bwd_fused``) producing dK, dV, dQ *and* delta --
    (s, p) recomputed once per visible tile, delta fused into the q-row
    prologue, dQ revisit-accumulated in an f32 output. ``bwd="split"``
    keeps the 3-launch baseline (``flash_bwd_delta`` + ``flash_bwd_dkv`` +
    ``flash_bwd_dq``) for parity and comparison.
  * Tile scheduling is ``schedule="compact"`` by default (see
    kernels/schedule.py); ``"dense"`` keeps the legacy visit-every-tile
    grid for comparison.
  * Knob resolution is measurement-driven (ISSUE 6): whenever a
    ``PallasFlashConfig`` knob is ``None``, :func:`resolve_pallas_knobs`
    consults the committed tuned cache (``kernels/autotune.py`` /
    ``tuned.json``) before falling back to the hand heuristics. Precedence,
    per knob: explicit arg > tuned cache > heuristic
    (``default_block_sizes`` / ``default_forward_partitions`` /
    ``_resolve_bwd``). ``use_tuned=False`` (or env ``REPRO_TUNED_CACHE=0``)
    disables the cache and restores pure-heuristic resolution.
  * Block sizes default to a shape-aware table (``default_block_sizes``):
    clamped to the padded sequence length, ``block_kv`` shrinking as the
    head dim grows so the fused backward's f32 dK/dV scratch plus streamed
    tiles stay inside the VMEM budget. Pass explicit ``block_q``/
    ``block_kv`` to override, exactly as before -- explicit values are
    *legalized* (rounded up to the 8-sublane alignment the kernels assume,
    clamped to the padded sequence length) with a warning, instead of
    silently mis-padding the sequence.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masks import MaskSpec, pad_segments
from repro.core.online_softmax import combine_lse_outputs
from repro.kernels import autotune as _autotune
from repro.kernels import flash_bwd as _bwd
from repro.kernels import flash_decode as _dec
from repro.kernels import flash_fwd as _fwd
from repro.kernels.schedule import (  # re-export
    PartitionedSchedule,
    TileSchedule,
    build_partitioned_schedule,
    build_tile_schedule,
)

LANES = _fwd.LANES

__all__ = [
    "PallasFlashConfig",
    "PartitionedSchedule",
    "TileSchedule",
    "build_partitioned_schedule",
    "build_tile_schedule",
    "default_block_sizes",
    "default_forward_partitions",
    "resolve_pallas_knobs",
    "flash_attention_pallas",
    "flash_attention_pallas_shard_bwd",
    "flash_attention_pallas_varlen",
    "flash_attention_pallas_varlen_with_lse",
    "flash_attention_pallas_with_lse",
    "flash_decode_pallas",
]


@dataclasses.dataclass(frozen=True)
class PallasFlashConfig:
    """The five-knob kernel config. ``None`` = resolve per shape at call
    time with precedence explicit arg > tuned cache > heuristic (see
    :func:`resolve_pallas_knobs`)."""

    spec: MaskSpec
    block_q: Optional[int] = None   # None -> tuned / default_block_sizes
    block_kv: Optional[int] = None
    scale: Optional[float] = None
    interpret: Optional[bool] = None  # None -> auto (off on TPU); compat.py
    schedule: Optional[str] = None  # 'compact' | 'dense'; None -> tuned/'compact'
    bwd: Optional[str] = None  # 'fused' (one-pass) | 'split'; None -> tuned/'fused'
    # Forward partitioning (compact schedule; paper Section 3.2). None ->
    # tuned cache, then the shape-aware default_forward_partitions policy;
    # explicit ints override (1 disables). Bands are bitwise-free; kv
    # splits change the fp summation order (exact up to merge rounding).
    num_q_bands: Optional[int] = None
    kv_splits: Optional[int] = None
    # Tri-state tuned-cache switch: None -> env REPRO_TUNED_CACHE (on by
    # default); False forces pure-heuristic resolution for every knob.
    use_tuned: Optional[bool] = None

    def __post_init__(self):
        if self.schedule not in (None, "compact", "dense"):
            raise ValueError(f"unknown tile schedule: {self.schedule!r}")
        if self.bwd not in (None, "fused", "split"):
            raise ValueError(f"unknown backward mode: {self.bwd!r}")
        for name in ("num_q_bands", "kv_splits"):
            val = getattr(self, name)
            if val is not None and val < 1:
                raise ValueError(f"{name} must be >= 1 (or None for auto)")


@dataclasses.dataclass(frozen=True)
class _KernelMeta:
    """Static call contract of the custom_vjp core (hashable, nondiff)."""

    spec: MaskSpec
    block_q: int
    block_kv: int
    group: int
    kv_valid: int
    schedule: str
    bwd: str
    interpret: Optional[bool]
    num_q_bands: int = 1  # resolved (never None) forward partition counts
    kv_splits: int = 1


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# The fused backward keeps every q tile's delta = rowsum(dO o O) row in a
# (G, t_q, block_q) f32 VMEM scratch for the whole kv-major sweep -- an
# O(G * padded_seq) term no block size can shrink. Past this budget the
# fused kernel would blow the ~16 MB/core VMEM on real TPUs (interpret
# mode never notices), so bwd="fused" silently degrades to the split
# 3-launch baseline, which keeps delta in HBM.
_FUSED_DELTA_VMEM_BUDGET = 2 * 1024 * 1024  # bytes; G * Sqp * 4 must fit


def _resolve_bwd(bwd: str, group: int, seq_q_padded: int) -> str:
    """Shape-aware backward-mode resolution (see _FUSED_DELTA_VMEM_BUDGET)."""
    if bwd == "fused" and group * seq_q_padded * 4 > _FUSED_DELTA_VMEM_BUDGET:
        return "split"
    return bwd


# Target number of *parallel* grid cells for the compact forward. The
# flattened compact schedule exposes only B*Hq parallel cells; below this
# target the auto policy adds q bands (paper Section 3.2 forward
# partitioning) until BH * bands reaches it (or runs out of q tiles). A
# modest multiple of real TPU core counts so the scheduler can also
# pipeline across cells; large-BH shapes stay at 1 band (no padding cost).
_TARGET_PARALLEL_CELLS = 64


def default_forward_partitions(bh: int, t_q: int, t_kv: int):
    """Shape-aware (num_q_bands, kv_splits) for the compact forward.

    Bands: enough that ``bh * bands >= _TARGET_PARALLEL_CELLS``, capped at
    the q-tile count; degrade to 1 when ``bh`` alone fills the target
    (large-batch training) or the sequence is a single q tile. Banding is
    bitwise-free, so it is safe to apply by default.

    KV splits: only for the prefill-like corner where q-parallelism cannot
    exist at all -- a single q tile against many kv tiles (short-q/long-kv
    cross-attention, chunked prefill) with bh under the target. Splits
    change the fp merge order (exact up to rounding), so wider shapes that
    merely *also* want splits opt in explicitly via ``kv_splits=``.
    """
    bands = 1
    if bh < _TARGET_PARALLEL_CELLS and t_q > 1:
        bands = min(t_q, -(-_TARGET_PARALLEL_CELLS // bh))
    splits = 1
    if t_q == 1 and t_kv >= 4 and bh < _TARGET_PARALLEL_CELLS:
        splits = min(t_kv, -(-_TARGET_PARALLEL_CELLS // bh))
    return bands, splits


def _resolve_partitions(cfg: PallasFlashConfig, tuned: dict, schedule: str,
                        bh: int, t_q: int, t_kv: int):
    """Knobs (explicit > tuned > auto) -> concrete (num_q_bands, kv_splits)."""
    if schedule != "compact":
        if (cfg.num_q_bands or 1) > 1 or (cfg.kv_splits or 1) > 1:
            raise ValueError(
                "num_q_bands/kv_splits require schedule='compact'"
            )
        return 1, 1
    auto_nb, auto_ks = default_forward_partitions(bh, t_q, t_kv)
    nb = cfg.num_q_bands if cfg.num_q_bands is not None else \
        tuned.get("num_q_bands", auto_nb)
    ks = cfg.kv_splits if cfg.kv_splits is not None else \
        tuned.get("kv_splits", auto_ks)
    return max(1, min(nb, t_q)), max(1, min(ks, t_kv))


def default_block_sizes(seq_q: int, seq_kv: int, head_dim: int):
    """Shape-aware default (block_q, block_kv) for the Pallas kernels.

    The table keys off the head dim: the fused backward holds two f32
    ``(block_kv, D)`` scratch tiles (dK, dV) plus the streamed q/do/o tiles
    and the revisited f32 dq block in VMEM at once, so ``block_kv`` shrinks
    as D grows to keep that working set inside the ~16 MB/core budget.
    Both blocks clamp to the (8-aligned) padded sequence length so short
    sequences never over-pad. Explicit ``block_q``/``block_kv`` arguments
    override the table everywhere, exactly as before.
    """
    if head_dim <= 128:
        bq, bk = 512, 512
    elif head_dim <= 256:
        bq, bk = 512, 256
    else:
        bq, bk = 256, 128
    return min(bq, _round_up(seq_q, 8)), min(bk, _round_up(seq_kv, 8))


def _legalize_block(name: str, val, seq: int, *, explicit: bool) -> int:
    """Legalize one block-size knob against the kernels' layout contract.

    The kernels assume 8-sublane-aligned blocks and pad the sequence to a
    block multiple; a misaligned explicit value used to flow straight into
    ``_round_up(S, block)`` and silently corrupt the padding geometry.
    Non-positive / non-integer values raise; otherwise the value is rounded
    up to a multiple of 8 and clamped to the padded sequence length, with a
    warning when an *explicit* request had to change (the heuristic and the
    tuned cache legalize silently -- clamping to a short sequence is their
    normal operating mode, not a user error).
    """
    if isinstance(val, bool) or not isinstance(val, int):
        raise ValueError(f"{name} must be an int >= 1, got {val!r}")
    if val < 1:
        raise ValueError(f"{name} must be >= 1, got {val}")
    legal = min(_round_up(val, 8), _round_up(seq, 8))
    if explicit and legal != val:
        warnings.warn(
            f"{name}={val} is not legal for seq={seq} (blocks must be "
            f"8-aligned and <= the padded sequence); using {legal}",
            stacklevel=3,
        )
    return legal


def resolve_pallas_knobs(cfg: PallasFlashConfig, q_shape, k_shape,
                         dtype=jnp.float32) -> dict:
    """Concrete knob resolution for one call -- explicit > tuned > heuristic.

    ``q_shape``/``k_shape`` are the public-layout shapes (B, S, H, D). Every
    ``None`` knob on ``cfg`` is filled from the tuned cache entry for
    (impl='flash_pallas', causal, seq, heads, head dim, dtype) when the
    cache is enabled and has a (near-enough) entry -- see
    ``kernels/autotune.lookup`` -- and from the hand heuristics otherwise.
    Returns the full dict the kernel call contract is built from:
    ``block_q``, ``block_kv``, ``schedule``, ``bwd`` (VMEM-guard resolved),
    ``num_q_bands``, ``kv_splits``, plus ``tuned`` (the raw cache knobs
    consulted; empty when disabled or missed) for introspection.
    """
    B, Sq, Hq, D = q_shape
    _, Sk, Hk, _ = k_shape
    spec = cfg.spec
    tuned = {}
    # Windowed / sink mask families were never swept; their knob landscape
    # differs from plain causal/full, so they stay on the heuristics.
    if (_autotune.cache_enabled(cfg.use_tuned) and spec.window is None
            and spec.sink == 0):
        tuned = _autotune.lookup(
            "flash_pallas", spec.causal, Sq, Hq, D, dtype
        )
    bq_def, bk_def = default_block_sizes(Sq, Sk, D)
    bq = cfg.block_q if cfg.block_q is not None else tuned.get("block_q", bq_def)
    bk = cfg.block_kv if cfg.block_kv is not None else tuned.get("block_kv", bk_def)
    bq = _legalize_block("block_q", bq, Sq, explicit=cfg.block_q is not None)
    bk = _legalize_block("block_kv", bk, Sk, explicit=cfg.block_kv is not None)
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    schedule = cfg.schedule or tuned.get("schedule") or "compact"
    bwd = cfg.bwd or tuned.get("bwd") or "fused"
    nb, ks = _resolve_partitions(
        cfg, tuned, schedule, B * Hq, Sqp // bq, Skp // bk
    )
    _count_knob_sources(cfg, tuned, schedule)
    return dict(
        block_q=bq, block_kv=bk, schedule=schedule,
        bwd=_resolve_bwd(bwd, Hq // Hk, Sqp),
        num_q_bands=nb, kv_splits=ks, tuned=dict(tuned),
    )


def _count_knob_sources(cfg: PallasFlashConfig, tuned: dict, schedule: str):
    """Telemetry: which precedence tier supplied each knob of this call.

    Increments ``knobs/flash_pallas/{explicit,tuned,heuristic}`` on the
    process-wide default registry (repro.obs.metrics) -- one hit per knob,
    so a call resolving block_q explicitly but everything else from the
    cache counts 1 explicit + N tuned. Runs at *trace* time (resolution
    happens once per jit trace); cached executions do not re-count, the
    same way they do not re-compile.
    """
    from repro.obs.metrics import count_knob

    per_source = {"explicit": 0, "tuned": 0, "heuristic": 0}

    def classify(explicit: bool, tuned_key: str):
        if explicit:
            per_source["explicit"] += 1
        elif tuned_key in tuned:
            per_source["tuned"] += 1
        else:
            per_source["heuristic"] += 1

    classify(cfg.block_q is not None, "block_q")
    classify(cfg.block_kv is not None, "block_kv")
    classify(cfg.schedule is not None, "schedule")
    classify(cfg.bwd is not None, "bwd")
    if schedule != "dense":  # dense forces 1/1: no partition knobs in play
        classify(cfg.num_q_bands is not None, "num_q_bands")
        classify(cfg.kv_splits is not None, "kv_splits")
    for source, n in per_source.items():
        if n:
            count_knob("flash_pallas", source, n)


def _heads_layout(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, H, D) -> (B*H, S, D)."""
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _unheads_layout(x: jnp.ndarray, B: int, H: int) -> jnp.ndarray:
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _prep(q, k, v, cfg: PallasFlashConfig, resolved: dict):
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hq % Hk == 0
    G = Hq // Hk
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(D)
    bq, bk = resolved["block_q"], resolved["block_kv"]
    qh = _heads_layout(q)
    kh = _heads_layout(k)
    vh = _heads_layout(v)
    pad_q = _round_up(Sq, bq) - Sq
    pad_k = _round_up(Sk, bk) - Sk
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    qh = (qh.astype(jnp.float32) * scale).astype(q.dtype)
    return qh, kh, vh, dict(
        B=B, Sq=Sq, Sk=Sk, Sqp=qh.shape[1], Skp=kh.shape[1],
        Hq=Hq, Hk=Hk, G=G, D=D, bq=bq, bk=bk, scale=scale,
    )


def _prep_call(q, k, v, cfg: PallasFlashConfig, q_seg=None, kv_seg=None):
    """Layout prep + the static kernel-call contract.

    Segment ids stay UNREPLICATED (B, Sqp)/(B, Skp) -- the kernels' index
    maps divide the head-row id down, so the ids are never materialized per
    head. Padding uses the repo-wide sentinels (masks.pad_segments): padded
    tiles become cross-segment, so padded q rows attend nothing (l = 0 ->
    o = 0, lse = -inf; trimmed by the caller).
    """
    r = resolve_pallas_knobs(cfg, q.shape, k.shape, q.dtype)
    qh, kh, vh, m = _prep(q, k, v, cfg, r)
    meta = _KernelMeta(
        spec=cfg.spec, block_q=m["bq"], block_kv=m["bk"], group=m["G"],
        kv_valid=m["Sk"], schedule=r["schedule"],
        bwd=r["bwd"], interpret=cfg.interpret,
        num_q_bands=r["num_q_bands"], kv_splits=r["kv_splits"],
    )
    qs = ks = None
    if q_seg is not None:
        qs, ks = pad_segments(
            q_seg.astype(jnp.int32), kv_seg.astype(jnp.int32), m["Sqp"], m["Skp"]
        )
    return qh, kh, vh, qs, ks, m, meta


# ---------------------------------------------------------------------------
# The differentiable core: prepped tensors in, prepped tensors out.
# ---------------------------------------------------------------------------


def _core_fwd(qh, kh, vh, qs, ks, meta: _KernelMeta):
    """flash_fwd on prepped tensors -> (o (BH, Sqp, D), lse (BH, Sqp)).

    With ``meta.kv_splits > 1`` the kernel emits per-split partials which
    are folded here by the associative ``merge_partials`` tree
    (``combine_lse_outputs``) -- the same primitive split-KV decode and the
    ring merge use. A split that saw no visible tile for a row emitted the
    merge identity (o = 0, lse = -inf), so fully-masked rows still come out
    as (0, -inf) exactly like the single-pass kernel.
    """
    out = _fwd.flash_fwd(
        qh, kh, vh, meta.spec, group=meta.group, block_q=meta.block_q,
        block_kv=meta.block_kv, kv_valid=meta.kv_valid, q_seg=qs, kv_seg=ks,
        interpret=meta.interpret, schedule=meta.schedule,
        num_q_bands=meta.num_q_bands, kv_splits=meta.kv_splits,
    )
    if meta.kv_splits > 1:
        o_parts, lse_parts = out  # (BH, ks, Sqp, D) f32, (BH, ks, Sqp) f32
        o, lse = combine_lse_outputs(
            jnp.moveaxis(o_parts, 1, 0), jnp.moveaxis(lse_parts, 1, 0)
        )
        return o.astype(qh.dtype), lse
    return out


def _core_bwd(qh, kh, vh, o, lse, do, meta: _KernelMeta, qs=None, ks=None):
    """Algorithm 2 on prepped residuals; returns (dqh, dkh, dvh).

    ``bwd="fused"``: one kv-major launch computes delta, dK, dV and dQ with
    a single (s, p) recompute per visible tile. ``bwd="split"``: the
    3-launch baseline (delta preprocess, then dkv and dq each recomputing
    (s, p) for every tile they visit).
    """
    doh = do.astype(qh.dtype)
    kw = dict(
        group=meta.group, block_q=meta.block_q, block_kv=meta.block_kv,
        kv_valid=meta.kv_valid, q_seg=qs, kv_seg=ks,
        interpret=meta.interpret, schedule=meta.schedule,
    )
    if meta.bwd == "fused":
        # Raw lse: the -inf cleanup for fully-masked rows happens in-kernel.
        dk, dv, dq = _bwd.flash_bwd_fused(
            qh, kh, vh, o, doh, lse, meta.spec, **kw
        )
        return dq.astype(qh.dtype), dk.astype(kh.dtype), dv.astype(vh.dtype)
    delta = _bwd.flash_bwd_delta(
        o, do, block_q=meta.block_q, interpret=meta.interpret
    )  # (BH, Sqp) f32: Algorithm 2 line 4
    # Fully-masked rows carry lse = -inf; zero it so exp(S - lse) stays 0
    # (S is DEFAULT_MASK_VALUE there) instead of producing inf.
    lse_s = jnp.where(jnp.isneginf(lse), 0.0, lse)
    dk, dv = _bwd.flash_bwd_dkv(qh, kh, vh, doh, lse_s, delta, meta.spec, **kw)
    dq = _bwd.flash_bwd_dq(qh, kh, vh, doh, lse_s, delta, meta.spec, **kw)
    # dq is w.r.t. the *scaled* q; the wrapper's prep transpose applies the
    # scale (and the unpad/unhead) when XLA differentiates through it.
    return dq.astype(qh.dtype), dk.astype(kh.dtype), dv.astype(vh.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_core(qh, kh, vh, meta: _KernelMeta):
    return _core_fwd(qh, kh, vh, None, None, meta)[0]


def _flash_core_fwd(qh, kh, vh, meta):
    o, lse = _core_fwd(qh, kh, vh, None, None, meta)
    return o, (qh, kh, vh, o, lse)  # prepped residuals: no _prep in the bwd


def _flash_core_bwd(meta, res, do):
    qh, kh, vh, o, lse = res
    return _core_bwd(qh, kh, vh, o, lse, do, meta)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _flash_core_varlen(qh, kh, vh, qs, ks, meta: _KernelMeta):
    return _core_fwd(qh, kh, vh, qs, ks, meta)[0]


def _flash_core_varlen_fwd(qh, kh, vh, qs, ks, meta):
    o, lse = _core_fwd(qh, kh, vh, qs, ks, meta)
    return o, (qh, kh, vh, qs, ks, o, lse)


def _flash_core_varlen_bwd(meta, res, do):
    qh, kh, vh, qs, ks, o, lse = res
    dq, dk, dv = _core_bwd(qh, kh, vh, o, lse, do, meta, qs, ks)
    return dq, dk, dv, None, None  # integer segment ids carry no gradient


_flash_core_varlen.defvjp(_flash_core_varlen_fwd, _flash_core_varlen_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def flash_attention_pallas(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None,
    block_q: Optional[int] = None, block_kv: Optional[int] = None,
    interpret: Optional[bool] = None, schedule: Optional[str] = None,
    bwd: Optional[str] = None,
    num_q_bands: Optional[int] = None, kv_splits: Optional[int] = None,
    use_tuned: Optional[bool] = None,
):
    """Differentiable FA2 via the Pallas TPU kernels. q (B,Sq,Hq,D).

    ``bwd`` picks the backward: ``"fused"`` (one-pass kernel, the resolved
    default) or ``"split"`` (delta + dkv + dq baseline). Every ``None``
    knob resolves per shape -- tuned cache first (``kernels/autotune``,
    disable with ``use_tuned=False``), then the shape-aware heuristics
    (:func:`default_block_sizes` / :func:`default_forward_partitions`).
    """
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule, bwd=bwd,
        num_q_bands=num_q_bands, kv_splits=kv_splits, use_tuned=use_tuned,
    )
    qh, kh, vh, _, _, m, meta = _prep_call(q, k, v, cfg)
    o = _flash_core(qh, kh, vh, meta)
    return _unheads_layout(o[:, : m["Sq"]], m["B"], m["Hq"]).astype(q.dtype)


def flash_attention_pallas_varlen(
    q, k, v, segment_ids, spec: MaskSpec = MaskSpec(causal=True), *,
    kv_segment_ids=None, scale: Optional[float] = None,
    block_q: Optional[int] = None, block_kv: Optional[int] = None,
    interpret: Optional[bool] = None, schedule: Optional[str] = None,
    bwd: Optional[str] = None,
    num_q_bands: Optional[int] = None, kv_splits: Optional[int] = None,
    use_tuned: Optional[bool] = None,
):
    """Differentiable segment-packed (varlen) FA2 via the Pallas kernels.

    Each batch row packs several back-to-back sequences; ``segment_ids``
    (B, Sq) int32 marks which tokens belong together (id 0 = padding by the
    data-pipeline convention -- any non-negative ids work). Query i attends
    key j iff their ids match AND the MaskSpec admits the *global* positions
    (with contiguous packing, global causality == within-segment causality).
    Cross-segment tiles are skipped in all three kernels (fwd, dkv, dq):
    under the compact schedule via a prefetched per-(batch, step) range-
    disjointness table, under the dense schedule via in-kernel per-tile
    id-range probing -- the paper's Section 3.1 block skipping generalized
    from a static causal schedule to data-dependent segments.

    kv_segment_ids defaults to segment_ids (self-attention over one packed
    layout); a ``masks.SegmentInfo`` is accepted in place of the raw array.
    Returns o (B, Sq, Hq, D).
    """
    from repro.core.masks import SegmentInfo

    if isinstance(segment_ids, SegmentInfo):
        segment_ids, kv_segment_ids = segment_ids.q, segment_ids.kv
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    assert segment_ids.shape == q.shape[:2], (segment_ids.shape, q.shape)
    assert kv_segment_ids.shape == k.shape[:2], (kv_segment_ids.shape, k.shape)
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule, bwd=bwd,
        num_q_bands=num_q_bands, kv_splits=kv_splits, use_tuned=use_tuned,
    )
    qh, kh, vh, qs, ks, m, meta = _prep_call(q, k, v, cfg, segment_ids, kv_segment_ids)
    o = _flash_core_varlen(qh, kh, vh, qs, ks, meta)
    return _unheads_layout(o[:, : m["Sq"]], m["B"], m["Hq"]).astype(q.dtype)


def _fwd_with_lse(q, k, v, cfg, q_seg=None, kv_seg=None):
    qh, kh, vh, qs, ks, m, meta = _prep_call(q, k, v, cfg, q_seg, kv_seg)
    o, lse = _core_fwd(qh, kh, vh, qs, ks, meta)
    o = _unheads_layout(o[:, : m["Sq"]], m["B"], m["Hq"]).astype(q.dtype)
    lse_rows = lse[:, : m["Sq"]].reshape(m["B"], m["Hq"], m["Sq"])
    return o, lse_rows


def flash_attention_pallas_varlen_with_lse(
    q, k, v, segment_ids, spec: MaskSpec = MaskSpec(causal=True), *,
    kv_segment_ids=None, scale: Optional[float] = None,
    block_q: Optional[int] = None, block_kv: Optional[int] = None,
    interpret: Optional[bool] = None, schedule: Optional[str] = None,
    num_q_bands: Optional[int] = None, kv_splits: Optional[int] = None,
    use_tuned: Optional[bool] = None,
):
    """Forward-only varlen (serving): returns (o, lse (B, Hq, Sq))."""
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule,
        num_q_bands=num_q_bands, kv_splits=kv_splits, use_tuned=use_tuned,
    )
    return _fwd_with_lse(
        q, k, v, cfg, segment_ids.astype(jnp.int32), kv_segment_ids.astype(jnp.int32)
    )


def flash_attention_pallas_with_lse(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None,
    block_q: Optional[int] = None, block_kv: Optional[int] = None,
    interpret: Optional[bool] = None, schedule: Optional[str] = None,
    num_q_bands: Optional[int] = None, kv_splits: Optional[int] = None,
    use_tuned: Optional[bool] = None,
):
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule,
        num_q_bands=num_q_bands, kv_splits=kv_splits, use_tuned=use_tuned,
    )
    return _fwd_with_lse(q, k, v, cfg)


def flash_attention_pallas_shard_bwd(
    q, k, v, o, lse, do, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None,
    block_q: Optional[int] = None, block_kv: Optional[int] = None,
    interpret: Optional[bool] = None, schedule: Optional[str] = None,
    bwd: Optional[str] = None, use_tuned: Optional[bool] = None,
    out_dtype=None,
):
    """Shard-local Algorithm 2 against an externally merged (o, lse).

    The ring-attention backward (distributed/ring_attention.py) replays each
    (q_shard, kv_shard) rectangle it visited in the forward and needs that
    rectangle's (dq, dk, dv) contribution computed with the *globally*
    merged softmax statistics: ``lse`` (B, Hq, Sq) f32 is the final merged
    logsumexp over ALL keys, and ``o`` (B, Sq, Hq, D) the final merged
    output (so ``delta = rowsum(dO o O)``, Algorithm 2 line 4, is the global
    row term). With those, ``P = exp(S_rect - lse)`` is exactly this
    rectangle's slice of the global probability matrix, and the three bwd
    kernels run their ordinary compact schedule restricted to the
    rectangle's spec. Summing the returned (dq, dk, dv) over rectangles (as
    the ring does) reproduces the single-device backward.

    There is no ``custom_vjp`` here on purpose — the caller IS a vjp; this
    is a direct kernel entry on one shard pair. Returns (dq, dk, dv) in the
    input dtypes, or in ``out_dtype`` when given — the ring passes f32 so
    its traveling (dK, dV) accumulators fold in each rectangle's
    contribution without a lossy round-trip through the bf16 input dtype.
    ``bwd="fused"`` runs the rectangle as ONE kernel launch (ring training
    inherits the fused win); ``"split"`` keeps the 3-launch baseline.
    """
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale,
        interpret=interpret, schedule=schedule, bwd=bwd, use_tuned=use_tuned,
    )
    qh, kh, vh, _, _, m, meta = _prep_call(q, k, v, cfg)
    oh = _heads_layout(o.astype(jnp.float32))
    doh = _heads_layout(do.astype(jnp.float32))
    lse_h = lse.astype(jnp.float32).reshape(m["B"] * m["Hq"], m["Sq"])
    pad_q = m["Sqp"] - m["Sq"]
    if pad_q:
        # Padded rows carry do = 0 and lse = -inf -> every bwd term is 0.
        oh = jnp.pad(oh, ((0, 0), (0, pad_q), (0, 0)))
        doh = jnp.pad(doh, ((0, 0), (0, pad_q), (0, 0)))
        lse_h = jnp.pad(lse_h, ((0, 0), (0, pad_q)), constant_values=-jnp.inf)
    dqh, dkh, dvh = _core_bwd(qh, kh, vh, oh, lse_h, doh, meta)
    # _core_bwd differentiates w.r.t. the pre-scaled q; fold the scale back.
    dq = _unheads_layout(dqh[:, : m["Sq"]].astype(jnp.float32) * m["scale"],
                         m["B"], m["Hq"])
    dk = _unheads_layout(dkh[:, : m["Sk"]], m["B"], m["Hk"])
    dv = _unheads_layout(dvh[:, : m["Sk"]], m["B"], m["Hk"])
    return (
        dq.astype(out_dtype or q.dtype),
        dk.astype(out_dtype or k.dtype),
        dv.astype(out_dtype or v.dtype),
    )


def flash_decode_pallas(
    q, k_cache, v_cache, cache_length, *,
    window: Optional[int] = None, sink: int = 0, scale: Optional[float] = None,
    num_splits: int = 8, kv_segment_ids=None, q_segment=None,
    interpret: Optional[bool] = None,
):
    """Split-KV decode via the Pallas kernel. q (B,1,Hq,D); returns (o, lse).

    kv_segment_ids (B, S) + q_segment (B,) restrict each query to its own
    segment of a *packed* KV cache (no reads across segment boundaries).
    """
    B, one, Hq, D = q.shape
    assert one == 1
    _, S, Hk, _ = k_cache.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qh = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qh = qh.reshape(B, Hk, G, D).reshape(B * Hk, G, D)
    kh = _heads_layout(k_cache)
    vh = _heads_layout(v_cache)
    lens = jnp.repeat(cache_length.astype(jnp.int32), Hk)
    kv_seg = q_seg = None
    if kv_segment_ids is not None:
        assert q_segment is not None, "packed decode needs q_segment (B,)"
        kv_seg = jnp.repeat(kv_segment_ids.astype(jnp.int32), Hk, axis=0)
        q_seg = jnp.repeat(q_segment.astype(jnp.int32), Hk)
    o_parts, lse_parts = _dec.flash_decode_kernel(
        qh, kh, vh, lens, num_splits=num_splits, window=window, sink=sink,
        kv_seg=kv_seg, q_seg=q_seg, interpret=interpret,
    )
    # Merge the splits (associative combine) -- (ns, BHk, G, D) / (ns, BHk, G).
    # lse_parts is already lane-major (BHk, ns, G): no broadcast axis to strip.
    o, lse = combine_lse_outputs(
        jnp.moveaxis(o_parts, 1, 0), jnp.moveaxis(lse_parts, 1, 0)
    )
    return (
        o.reshape(B, 1, Hq, D).astype(q.dtype),
        lse.reshape(B, Hq, 1),
    )


def flash_decode_paged_pallas(
    q, k_pages, v_pages, cache_length, block_table, *,
    window: Optional[int] = None, sink: int = 0, scale: Optional[float] = None,
    num_splits: int = 8, interpret: Optional[bool] = None,
):
    """Page-indirect split-KV decode. q (B,1,Hq,D); k/v_pages (Hkv,P,ps,D);
    cache_length (B,) logical lengths; block_table (B, n_pages) int32
    physical page ids (0 = the reserved null page). Returns (o, lse) with
    the same contract as :func:`flash_decode_pallas` -- the serving engine
    swaps a contiguous cache for pool planes without touching the merge."""
    B, one, Hq, D = q.shape
    assert one == 1
    Hk = k_pages.shape[0]
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qh = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qh = qh.reshape(B, Hk, G, D).reshape(B * Hk, G, D)
    lens = jnp.repeat(cache_length.astype(jnp.int32), Hk)
    o_parts, lse_parts = _dec.flash_decode_paged_kernel(
        qh, k_pages, v_pages, lens, block_table, num_splits=num_splits,
        window=window, sink=sink, interpret=interpret,
    )
    o, lse = combine_lse_outputs(
        jnp.moveaxis(o_parts, 1, 0), jnp.moveaxis(lse_parts, 1, 0)
    )
    return (
        o.reshape(B, 1, Hq, D).astype(q.dtype),
        lse.reshape(B, Hq, 1),
    )
