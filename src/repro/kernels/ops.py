"""Jit'd public wrappers for the Pallas kernels.

Handles: layout normalization ((B,S,H,D) -> per-head rows), padding to block
multiples, q pre-scaling, the fwd<->bwd pairing via ``jax.custom_vjp``
(Algorithm 1 + Algorithm 2), and the decode split merge. The pure-jnp oracle
lives in ref.py; parity is enforced by tests/test_flash_kernels.py.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masks import MaskSpec
from repro.core.online_softmax import combine_lse_outputs
from repro.kernels import flash_bwd as _bwd
from repro.kernels import flash_decode as _dec
from repro.kernels import flash_fwd as _fwd

LANES = _fwd.LANES


@dataclasses.dataclass(frozen=True)
class PallasFlashConfig:
    spec: MaskSpec
    block_q: int = 512
    block_kv: int = 512
    scale: Optional[float] = None
    interpret: bool = True


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _heads_layout(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, H, D) -> (B*H, S, D)."""
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _unheads_layout(x: jnp.ndarray, B: int, H: int) -> jnp.ndarray:
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _prep(q, k, v, cfg: PallasFlashConfig):
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hq % Hk == 0
    G = Hq // Hk
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(D)
    bq = cfg.block_q if Sq >= cfg.block_q else _round_up(Sq, 8)
    bk = cfg.block_kv if Sk >= cfg.block_kv else _round_up(Sk, 8)
    qh = _heads_layout(q)
    kh = _heads_layout(k)
    vh = _heads_layout(v)
    pad_q = _round_up(Sq, bq) - Sq
    pad_k = _round_up(Sk, bk) - Sk
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    qh = (qh.astype(jnp.float32) * scale).astype(q.dtype)
    return qh, kh, vh, dict(
        B=B, Sq=Sq, Sk=Sk, Sqp=qh.shape[1], Skp=kh.shape[1],
        Hq=Hq, Hk=Hk, G=G, D=D, bq=bq, bk=bk, scale=scale,
    )


def _prep_segments(q_seg, kv_seg, m):
    """(B, Sq)/(B, Sk) int32 segment ids -> per-head-row padded layouts.

    Ids are broadcast per head ((B,S) -> (B*H, S), batch-major like
    ``_heads_layout``) and padded to the block multiple with the repo-wide
    sentinels (masks.pad_segments): padded tiles become cross-segment, so
    padded q rows attend nothing (l = 0 -> o = 0, lse = -inf; trimmed by
    the caller)."""
    from repro.core.masks import pad_segments

    qs = jnp.repeat(q_seg.astype(jnp.int32), m["Hq"], axis=0)
    ks = jnp.repeat(kv_seg.astype(jnp.int32), m["Hk"], axis=0)
    return pad_segments(qs, ks, m["Sqp"], m["Skp"])


def _fwd_call(q, k, v, cfg: PallasFlashConfig, q_seg=None, kv_seg=None):
    qh, kh, vh, m = _prep(q, k, v, cfg)
    qs = ks = None
    if q_seg is not None:
        qs, ks = _prep_segments(q_seg, kv_seg, m)
    o, lse = _fwd.flash_fwd(
        qh, kh, vh, cfg.spec, group=m["G"], block_q=m["bq"], block_kv=m["bk"],
        kv_valid=m["Sk"], q_seg=qs, kv_seg=ks, interpret=cfg.interpret,
    )
    o = _unheads_layout(o[:, : m["Sq"]], m["B"], m["Hq"]).astype(q.dtype)
    lse_rows = lse[:, : m["Sq"], 0].reshape(m["B"], m["Hq"], m["Sq"])
    return o, lse_rows


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _pallas_flash(q, k, v, cfg: PallasFlashConfig):
    return _fwd_call(q, k, v, cfg)[0]


def _pallas_flash_fwd(q, k, v, cfg):
    o, lse = _fwd_call(q, k, v, cfg)
    return o, (q, k, v, o, lse)


def _bwd_call(q, k, v, o, lse, do, cfg: PallasFlashConfig, q_seg=None, kv_seg=None):
    qh, kh, vh, m = _prep(q, k, v, cfg)  # qh pre-scaled
    B, Sq, Hq, Hk, G, D = m["B"], m["Sq"], m["Hq"], m["Hk"], m["G"], m["D"]
    bq, bk = m["bq"], m["bk"]
    Sqp = qh.shape[1]
    qs = ks = None
    if q_seg is not None:
        qs, ks = _prep_segments(q_seg, kv_seg, m)

    doh = _heads_layout(do.astype(jnp.float32))
    oh = _heads_layout(o.astype(jnp.float32))
    delta = jnp.sum(doh * oh, axis=-1)  # (BH, Sq): Algorithm 2 line 4
    pad_q = Sqp - Sq
    if pad_q:
        doh = jnp.pad(doh, ((0, 0), (0, pad_q), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_q)))
    lse_h = lse.reshape(B * Hq, Sq)
    lse_h = jnp.where(jnp.isneginf(lse_h), 0.0, lse_h)
    if pad_q:
        lse_h = jnp.pad(lse_h, ((0, 0), (0, pad_q)))
    lse_b = jnp.broadcast_to(lse_h[..., None], (*lse_h.shape, LANES))
    delta_b = jnp.broadcast_to(delta[..., None], (*delta.shape, LANES))
    doh = doh.astype(q.dtype)

    dk, dv = _bwd.flash_bwd_dkv(
        qh, kh, vh, doh, lse_b, delta_b, cfg.spec,
        group=G, block_q=bq, block_kv=bk, kv_valid=m["Sk"],
        q_seg=qs, kv_seg=ks, interpret=cfg.interpret,
    )
    dq = _bwd.flash_bwd_dq(
        qh, kh, vh, doh, lse_b, delta_b, cfg.spec,
        group=G, block_q=bq, block_kv=bk, kv_valid=m["Sk"],
        q_seg=qs, kv_seg=ks, interpret=cfg.interpret,
    )
    dq = _unheads_layout(dq[:, :Sq], B, Hq) * m["scale"]
    dk = _unheads_layout(dk[:, : m["Sk"]], B, Hk)
    dv = _unheads_layout(dv[:, : m["Sk"]], B, Hk)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _pallas_flash_bwd(cfg: PallasFlashConfig, res, do):
    q, k, v, o, lse = res
    return _bwd_call(q, k, v, o, lse, do, cfg)


_pallas_flash.defvjp(_pallas_flash_fwd, _pallas_flash_bwd)


# ---------------------------------------------------------------------------
# Segment-packed (varlen) attention: same kernels, segment-aware tiles.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _pallas_flash_varlen(q, k, v, q_seg, kv_seg, cfg: PallasFlashConfig):
    return _fwd_call(q, k, v, cfg, q_seg, kv_seg)[0]


def _pallas_flash_varlen_fwd(q, k, v, q_seg, kv_seg, cfg):
    o, lse = _fwd_call(q, k, v, cfg, q_seg, kv_seg)
    return o, (q, k, v, q_seg, kv_seg, o, lse)


def _pallas_flash_varlen_bwd(cfg: PallasFlashConfig, res, do):
    q, k, v, q_seg, kv_seg, o, lse = res
    dq, dk, dv = _bwd_call(q, k, v, o, lse, do, cfg, q_seg, kv_seg)
    return dq, dk, dv, None, None  # integer segment ids carry no gradient


_pallas_flash_varlen.defvjp(_pallas_flash_varlen_fwd, _pallas_flash_varlen_bwd)


def flash_attention_pallas(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None, block_q: int = 512, block_kv: int = 512,
    interpret: bool = True,
):
    """Differentiable FA2 via the Pallas TPU kernels. q (B,Sq,Hq,D)."""
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _pallas_flash(q, k, v, cfg)


def flash_attention_pallas_varlen(
    q, k, v, segment_ids, spec: MaskSpec = MaskSpec(causal=True), *,
    kv_segment_ids=None, scale: Optional[float] = None,
    block_q: int = 512, block_kv: int = 512, interpret: bool = True,
):
    """Differentiable segment-packed (varlen) FA2 via the Pallas kernels.

    Each batch row packs several back-to-back sequences; ``segment_ids``
    (B, Sq) int32 marks which tokens belong together (id 0 = padding by the
    data-pipeline convention -- any non-negative ids work). Query i attends
    key j iff their ids match AND the MaskSpec admits the *global* positions
    (with contiguous packing, global causality == within-segment causality).
    Cross-segment tiles are skipped in all three kernels (fwd, dkv, dq) via
    per-tile id-range disjointness -- the paper's Section 3.1 block skipping
    generalized from a static causal schedule to data-dependent segments.

    kv_segment_ids defaults to segment_ids (self-attention over one packed
    layout); a ``masks.SegmentInfo`` is accepted in place of the raw array.
    Returns o (B, Sq, Hq, D).
    """
    from repro.core.masks import SegmentInfo

    if isinstance(segment_ids, SegmentInfo):
        segment_ids, kv_segment_ids = segment_ids.q, segment_ids.kv
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    assert segment_ids.shape == q.shape[:2], (segment_ids.shape, q.shape)
    assert kv_segment_ids.shape == k.shape[:2], (kv_segment_ids.shape, k.shape)
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _pallas_flash_varlen(
        q, k, v, segment_ids.astype(jnp.int32), kv_segment_ids.astype(jnp.int32), cfg
    )


def flash_attention_pallas_varlen_with_lse(
    q, k, v, segment_ids, spec: MaskSpec = MaskSpec(causal=True), *,
    kv_segment_ids=None, scale: Optional[float] = None,
    block_q: int = 512, block_kv: int = 512, interpret: bool = True,
):
    """Forward-only varlen (serving): returns (o, lse (B, Hq, Sq))."""
    if kv_segment_ids is None:
        kv_segment_ids = segment_ids
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _fwd_call(
        q, k, v, cfg, segment_ids.astype(jnp.int32), kv_segment_ids.astype(jnp.int32)
    )


def flash_attention_pallas_with_lse(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None, block_q: int = 512, block_kv: int = 512,
    interpret: bool = True,
):
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _fwd_call(q, k, v, cfg)


def flash_decode_pallas(
    q, k_cache, v_cache, cache_length, *,
    window: Optional[int] = None, sink: int = 0, scale: Optional[float] = None,
    num_splits: int = 8, kv_segment_ids=None, q_segment=None,
    interpret: bool = True,
):
    """Split-KV decode via the Pallas kernel. q (B,1,Hq,D); returns (o, lse).

    kv_segment_ids (B, S) + q_segment (B,) restrict each query to its own
    segment of a *packed* KV cache (no reads across segment boundaries).
    """
    B, one, Hq, D = q.shape
    assert one == 1
    _, S, Hk, _ = k_cache.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qh = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qh = qh.reshape(B, Hk, G, D).reshape(B * Hk, G, D)
    kh = _heads_layout(k_cache)
    vh = _heads_layout(v_cache)
    lens = jnp.repeat(cache_length.astype(jnp.int32), Hk)
    kv_seg = q_seg = None
    if kv_segment_ids is not None:
        assert q_segment is not None, "packed decode needs q_segment (B,)"
        kv_seg = jnp.repeat(kv_segment_ids.astype(jnp.int32), Hk, axis=0)
        q_seg = jnp.repeat(q_segment.astype(jnp.int32), Hk)
    o_parts, lse_parts = _dec.flash_decode_kernel(
        qh, kh, vh, lens, num_splits=num_splits, window=window, sink=sink,
        kv_seg=kv_seg, q_seg=q_seg, interpret=interpret,
    )
    # Merge the splits (associative combine) -- (ns, BHk, G, D) / (ns, BHk, G)
    o, lse = combine_lse_outputs(
        jnp.moveaxis(o_parts, 1, 0), jnp.moveaxis(lse_parts[..., 0], 1, 0)
    )
    return (
        o.reshape(B, 1, Hq, D).astype(q.dtype),
        lse.reshape(B, Hq, 1),
    )
