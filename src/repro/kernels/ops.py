"""Jit'd public wrappers for the Pallas kernels.

Handles: layout normalization ((B,S,H,D) -> per-head rows), padding to block
multiples, q pre-scaling, the fwd<->bwd pairing via ``jax.custom_vjp``
(Algorithm 1 + Algorithm 2), and the decode split merge. The pure-jnp oracle
lives in ref.py; parity is enforced by tests/test_flash_kernels.py.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.masks import MaskSpec
from repro.core.online_softmax import combine_lse_outputs
from repro.kernels import flash_bwd as _bwd
from repro.kernels import flash_decode as _dec
from repro.kernels import flash_fwd as _fwd

LANES = _fwd.LANES


@dataclasses.dataclass(frozen=True)
class PallasFlashConfig:
    spec: MaskSpec
    block_q: int = 512
    block_kv: int = 512
    scale: Optional[float] = None
    interpret: bool = True


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _heads_layout(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, H, D) -> (B*H, S, D)."""
    B, S, H, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, D)


def _unheads_layout(x: jnp.ndarray, B: int, H: int) -> jnp.ndarray:
    BH, S, D = x.shape
    return x.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def _prep(q, k, v, cfg: PallasFlashConfig):
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hq % Hk == 0
    G = Hq // Hk
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(D)
    bq = cfg.block_q if Sq >= cfg.block_q else _round_up(Sq, 8)
    bk = cfg.block_kv if Sk >= cfg.block_kv else _round_up(Sk, 8)
    qh = _heads_layout(q)
    kh = _heads_layout(k)
    vh = _heads_layout(v)
    pad_q = _round_up(Sq, bq) - Sq
    pad_k = _round_up(Sk, bk) - Sk
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    qh = (qh.astype(jnp.float32) * scale).astype(q.dtype)
    return qh, kh, vh, dict(B=B, Sq=Sq, Sk=Sk, Hq=Hq, Hk=Hk, G=G, D=D, bq=bq, bk=bk, scale=scale)


def _fwd_call(q, k, v, cfg: PallasFlashConfig):
    qh, kh, vh, m = _prep(q, k, v, cfg)
    o, lse = _fwd.flash_fwd(
        qh, kh, vh, cfg.spec, group=m["G"], block_q=m["bq"], block_kv=m["bk"],
        kv_valid=m["Sk"], interpret=cfg.interpret,
    )
    o = _unheads_layout(o[:, : m["Sq"]], m["B"], m["Hq"]).astype(q.dtype)
    lse_rows = lse[:, : m["Sq"], 0].reshape(m["B"], m["Hq"], m["Sq"])
    return o, lse_rows


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _pallas_flash(q, k, v, cfg: PallasFlashConfig):
    return _fwd_call(q, k, v, cfg)[0]


def _pallas_flash_fwd(q, k, v, cfg):
    o, lse = _fwd_call(q, k, v, cfg)
    return o, (q, k, v, o, lse)


def _pallas_flash_bwd(cfg: PallasFlashConfig, res, do):
    q, k, v, o, lse = res
    qh, kh, vh, m = _prep(q, k, v, cfg)  # qh pre-scaled
    B, Sq, Hq, Hk, G, D = m["B"], m["Sq"], m["Hq"], m["Hk"], m["G"], m["D"]
    bq, bk = m["bq"], m["bk"]
    Sqp = qh.shape[1]

    doh = _heads_layout(do.astype(jnp.float32))
    oh = _heads_layout(o.astype(jnp.float32))
    delta = jnp.sum(doh * oh, axis=-1)  # (BH, Sq): Algorithm 2 line 4
    pad_q = Sqp - Sq
    if pad_q:
        doh = jnp.pad(doh, ((0, 0), (0, pad_q), (0, 0)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_q)))
    lse_h = lse.reshape(B * Hq, Sq)
    lse_h = jnp.where(jnp.isneginf(lse_h), 0.0, lse_h)
    if pad_q:
        lse_h = jnp.pad(lse_h, ((0, 0), (0, pad_q)))
    lse_b = jnp.broadcast_to(lse_h[..., None], (*lse_h.shape, LANES))
    delta_b = jnp.broadcast_to(delta[..., None], (*delta.shape, LANES))
    doh = doh.astype(q.dtype)

    dk, dv = _bwd.flash_bwd_dkv(
        qh, kh, vh, doh, lse_b, delta_b, cfg.spec,
        group=G, block_q=bq, block_kv=bk, kv_valid=m["Sk"], interpret=cfg.interpret,
    )
    dq = _bwd.flash_bwd_dq(
        qh, kh, vh, doh, lse_b, delta_b, cfg.spec,
        group=G, block_q=bq, block_kv=bk, kv_valid=m["Sk"], interpret=cfg.interpret,
    )
    dq = _unheads_layout(dq[:, :Sq], B, Hq) * m["scale"]
    dk = _unheads_layout(dk[:, : m["Sk"]], B, Hk)
    dv = _unheads_layout(dv[:, : m["Sk"]], B, Hk)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_pallas_flash.defvjp(_pallas_flash_fwd, _pallas_flash_bwd)


def flash_attention_pallas(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None, block_q: int = 512, block_kv: int = 512,
    interpret: bool = True,
):
    """Differentiable FA2 via the Pallas TPU kernels. q (B,Sq,Hq,D)."""
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _pallas_flash(q, k, v, cfg)


def flash_attention_pallas_with_lse(
    q, k, v, spec: MaskSpec = MaskSpec(causal=True), *,
    scale: Optional[float] = None, block_q: int = 512, block_kv: int = 512,
    interpret: bool = True,
):
    cfg = PallasFlashConfig(
        spec=spec, block_q=block_q, block_kv=block_kv, scale=scale, interpret=interpret
    )
    return _fwd_call(q, k, v, cfg)


def flash_decode_pallas(
    q, k_cache, v_cache, cache_length, *,
    window: Optional[int] = None, sink: int = 0, scale: Optional[float] = None,
    num_splits: int = 8, interpret: bool = True,
):
    """Split-KV decode via the Pallas kernel. q (B,1,Hq,D); returns (o, lse)."""
    B, one, Hq, D = q.shape
    assert one == 1
    _, S, Hk, _ = k_cache.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qh = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qh = qh.reshape(B, Hk, G, D).reshape(B * Hk, G, D)
    kh = _heads_layout(k_cache)
    vh = _heads_layout(v_cache)
    lens = jnp.repeat(cache_length.astype(jnp.int32), Hk)
    o_parts, lse_parts = _dec.flash_decode_kernel(
        qh, kh, vh, lens, num_splits=num_splits, window=window, sink=sink,
        interpret=interpret,
    )
    # Merge the splits (associative combine) -- (ns, BHk, G, D) / (ns, BHk, G)
    o, lse = combine_lse_outputs(
        jnp.moveaxis(o_parts, 1, 0), jnp.moveaxis(lse_parts[..., 0], 1, 0)
    )
    return (
        o.reshape(B, 1, Hq, D).astype(q.dtype),
        lse.reshape(B, Hq, 1),
    )
