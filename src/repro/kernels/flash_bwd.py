"""FlashAttention-2 backward Pallas TPU kernels (the paper's Algorithm 2).

GPU->TPU adaptation (DESIGN.md Section 2): the paper parallelizes the
backward over *column* (KV) blocks, with thread blocks doing **atomic adds**
into dQ. TPUs have no HBM atomics, so we split into two kernels -- the
standard TPU flash scheme:

  * ``dkv`` kernel -- grid (B*Hkv, Tkv, G, Tq): each (bh, j) owns one KV
    block (the paper's column-block worker, Fig. 2 right); the inner
    sequential (g, i) axes stream Q/dO blocks past it, accumulating dK_j,
    dV_j in VMEM scratch (Algorithm 2 lines 12, 16) -- and summing over the
    GQA group g, the paper's "sum dK/dV across duplicated heads".
  * ``dq`` kernel -- grid (B*Hq, Tq, Tkv): each (bh, i) owns one Q block;
    the inner KV loop accumulates dQ_i in scratch (line 15). This replaces
    the atomic-add cross-worker communication with a second pass that
    recomputes S -- extra *matmul* FLOPs in exchange for zero communication,
    which is the paper's own trade (matmul FLOPs are ~16x cheaper).

Both kernels recompute P = exp(S - L) from the logsumexp only (C1b, line 11).
D = rowsum(dO o O) (line 4) is precomputed in ops.py (one fused elementwise
pass). Layouts as in flash_fwd.py; lse/delta are (BH, Sq, LANES)-broadcast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels.compat import CompilerParams
from repro.kernels.flash_fwd import LANES, _tile_mask, _visibility


def _recompute_p(q, k, lse, spec, i, j, bq, bk, kv_valid, q_seg=None, kv_seg=None):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    _, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
    mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
    s = jnp.where(jnp.logical_or(~needs_mask, mask), s, DEFAULT_MASK_VALUE)
    return jnp.exp(s - lse), s


# ---------------------------------------------------------------------------
# dK / dV kernel
# ---------------------------------------------------------------------------


def _dkv_kernel(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_q: int, group: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg = kv_seg = None
    j = pl.program_id(1)
    g = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(jnp.logical_and(g == 0, i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    empty, _ = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        q = q_ref[0]      # (bq, d), pre-scaled
        k = k_ref[0]      # (bk, d)
        v = v_ref[0]
        do = do_ref[0]    # (bq, d)
        lse = lse_ref[0][:, :1]    # (bq, 1)
        delta = delta_ref[0][:, :1]
        p, _ = _recompute_p(
            q, k, lse, spec, i, j, bq, bk, kv_valid, q_seg, kv_seg
        )  # line 11
        # dV_j += P^T dO_i                                          (line 12)
        dv_scr[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # dP = dO_i V_j^T                                           (line 13)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        # dS = P o (dP - D_i)                                       (line 14)
        ds = p * (dp - delta)
        # dK_j += dS^T Q_i  (q pre-scaled => scale already folded)  (line 16)
        dk_scr[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(jnp.logical_and(g == group - 1, i == t_q - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_dkv(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: bool = True,
):
    """Returns (dk, dv) in (BHk, Skp, D) fp32. q pre-scaled by 1/sqrt(d)."""
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    grid = (BHk, t_kv, group, t_q)
    has_segments = q_seg is not None
    kernel = functools.partial(
        _dkv_kernel, spec=spec, bq=block_q, bk=block_kv, t_q=t_q, group=group,
        kv_valid=kv_valid, has_segments=has_segments,
    )
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,  # 3 matmuls here
        bytes_accessed=2 * k.size * k.dtype.itemsize
        + BHk * t_kv * group * t_q * 2 * block_q * D * q.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D), lambda bh, j, g, i, grp=group: (bh * grp + g, i, 0)
    )
    lspec = pl.BlockSpec(
        (1, block_q, LANES), lambda bh, j, g, i, grp=group: (bh * grp + g, i, 0)
    )
    kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, j, g, i: (bh, j, 0))
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, j, g, i, grp=group: (bh * grp + g, i)),
            pl.BlockSpec((1, block_kv), lambda bh, j, g, i: (bh, j)),
        ]
        inputs += [q_seg, kv_seg]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[kvspec, kvspec],
        out_shape=[
            jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
            jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, D), jnp.float32),
            pltpu.VMEM((block_kv, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dkv_varlen" if has_segments else "fa2_bwd_dkv",
    )(*inputs)


# ---------------------------------------------------------------------------
# dQ kernel
# ---------------------------------------------------------------------------


def _dq_kernel(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_kv: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, dq_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, dq_scr) = refs
        q_seg = kv_seg = None
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    empty, _ = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        lse = lse_ref[0][:, :1]
        delta = delta_ref[0][:, :1]
        p, _ = _recompute_p(q, k, lse, spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta)
        # dQ_i += dS K_j                                            (line 15)
        dq_scr[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == t_kv - 1)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def flash_bwd_dq(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: bool = True,
):
    """Returns dq in (BH, Sq, D) fp32 (gradient w.r.t. *scaled* q)."""
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    grid = (BH, t_q, t_kv)
    has_segments = q_seg is not None
    kernel = functools.partial(
        _dq_kernel, spec=spec, bq=block_q, bk=block_kv, t_kv=t_kv,
        kv_valid=kv_valid, has_segments=has_segments,
    )
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,
        bytes_accessed=2 * q.size * q.dtype.itemsize
        + BH * n_vis * 2 * block_kv * D * k.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    qspec = pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0))
    lspec = pl.BlockSpec((1, block_q, LANES), lambda bh, i, j: (bh, i, 0))
    kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0))
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i)),
            pl.BlockSpec((1, block_kv), lambda bh, i, j, g=group: (bh // g, j)),
        ]
        inputs += [q_seg, kv_seg]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dq_varlen" if has_segments else "fa2_bwd_dq",
    )(*inputs)
