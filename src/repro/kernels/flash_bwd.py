"""FlashAttention-2 backward Pallas TPU kernels (the paper's Algorithm 2).

GPU->TPU adaptation (DESIGN.md Section 2): the paper parallelizes the
backward over *column* (KV) blocks, with thread blocks doing **atomic adds**
into dQ. TPUs have no HBM atomics; two TPU realizations live here:

  * ``bwd="fused"`` (default) -- :func:`flash_bwd_fused`, ONE kv-major
    launch. Each (bh, j) owns a KV block; the sequential axis streams
    visible Q tiles past it. Per tile, ``(s, p)`` is recomputed ONCE and
    feeds all five streamed matmuls (dV, dP, dK, dQ plus the s recompute),
    dK/dV accumulate in VMEM scratch across the KV run, and the tile's dQ
    contribution is added to a revisited f32 output block (the atomic-add
    replacement: the grid's step axis is ``"arbitrary"``/sequential, so
    revisits are ordered and race-free). ``delta = rowsum(dO o O)`` is
    fused into the q-row prologue: the schedule's STEP_QFIRST step for each
    q tile zero-inits the dq block and computes delta into a lane-major
    VMEM scratch row that later visits read back -- delta never exists in
    HBM. 3 launches -> 1, one exp per visible tile instead of two, and
    Q/dO/lse stream once instead of twice.
  * ``bwd="split"`` -- the parity baseline: ``flash_bwd_delta`` +
    ``flash_bwd_dkv`` (KV-stationary, scratch-accumulated, GQA-summed) +
    ``flash_bwd_dq`` (Q-stationary, the paper's own recompute-vs-
    communication trade). Two exps and two Q/dO streams per visible tile.

All kernels support two schedules (see flash_fwd.py / kernels/schedule.py):
``"compact"`` (default) flattens the visible tile pairs into a scalar-
prefetched table -- kv-major for dkv/fused (grid ``(BHk, n_steps, G)``),
q-major for dq (grid ``(BH, n_steps)``) -- so masked-out tiles cost no grid
steps and no DMAs; ``"dense"`` is the legacy visit-everything grid.

All recompute P = exp(S - L) from the logsumexp only (C1b, line 11).
Softmax statistics arrive LANE-MAJOR: lse and delta are ``(BH, Sqp)`` f32
with the sequence on the 128-lane axis (BlockSpec ``(1, block_q)``) -- the
memory-diet contract shared with flash_fwd.py. In the split backward,
D = rowsum(dO o O) (line 4) is computed by :func:`flash_bwd_delta`, a
one-pass Pallas kernel, instead of an XLA elementwise pass over the
broadcast layout; the fused backward absorbs even that launch.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels.compat import CompilerParams, resolve_interpret
from repro.kernels.flash_fwd import _tile_mask, _visibility
from repro.kernels.schedule import (
    STEP_QFIRST,
    build_tile_schedule,
    decode_step_bits,
    segment_step_tables,
)


def _recompute_p(q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask,
                 q_seg=None, kv_seg=None):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
    s = jnp.where(jnp.logical_or(~needs_mask, mask), s, DEFAULT_MASK_VALUE)
    return jnp.exp(s - lse), s


# ---------------------------------------------------------------------------
# delta = rowsum(dO o O) preprocess (Algorithm 2 line 4)
# ---------------------------------------------------------------------------


def _delta_kernel(o_ref, do_ref, delta_ref):
    delta_ref[0] = jnp.sum(
        o_ref[0].astype(jnp.float32) * do_ref[0].astype(jnp.float32), axis=-1
    )


def flash_bwd_delta(o, do, *, block_q: int, interpret: Optional[bool] = None):
    """rowsum(dO o O) over prepped (BH, Sqp, D) tensors -> (BH, Sqp) f32.

    One fused read of O and dO per tile, emitting the lane-major delta the
    backward kernels consume directly (no 128x broadcast round-trip).
    """
    interpret = resolve_interpret(interpret)
    BH, Sqp, D = o.shape
    assert Sqp % block_q == 0
    spec = pl.BlockSpec((1, block_q, D), lambda bh, i: (bh, i, 0))
    return pl.pallas_call(
        _delta_kernel,
        grid=(BH, Sqp // block_q),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, block_q), lambda bh, i: (bh, i)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=2 * o.size,
            bytes_accessed=o.size * o.dtype.itemsize
            + do.size * do.dtype.itemsize + BH * Sqp * 4,
            transcendentals=0,
        ),
        interpret=interpret,
        name="fa2_bwd_delta",
    )(o, do)


# ---------------------------------------------------------------------------
# dK / dV kernel
# ---------------------------------------------------------------------------


def _dkv_tile_math(q, k, v, do, lse, delta, dk_scr, dv_scr,
                   spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg):
    """Algorithm 2 lines 11-16 for one tile: accumulate dK_j, dV_j into the
    run scratch and return dS (the dq kernel / fused kernel's input for
    line 15). Shared by the split dkv kernel and the fused kernel so the
    bitwise fused==split parity contract has a single source of truth.

    q (bq, d) pre-scaled; lse/delta (bq, 1) f32 columns.
    """
    p, _ = _recompute_p(
        q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg
    )  # line 11
    # dV_j += P^T dO_i                                          (line 12)
    dv_scr[...] += jax.lax.dot_general(
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # dP = dO_i V_j^T                                           (line 13)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    # dS = P o (dP - D_i)                                       (line 14)
    ds = p * (dp - delta)
    # dK_j += dS^T Q_i  (q pre-scaled => scale already folded)  (line 16)
    dk_scr[...] += jax.lax.dot_general(
        ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return ds


def _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                 dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                 q_seg, kv_seg):
    _dkv_tile_math(
        q_ref[0], k_ref[0], v_ref[0], do_ref[0],
        lse_ref[0][:, None], delta_ref[0][:, None],  # lane-major sources
        dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
        q_seg, kv_seg,
    )


def _dkv_kernel_dense(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_q: int, group: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg = kv_seg = None
    j = pl.program_id(1)
    g = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(jnp.logical_and(g == 0, i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                     q_seg, kv_seg)

    @pl.when(jnp.logical_and(g == group - 1, i == t_q - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dkv_kernel_compact(
    *refs,
    spec: MaskSpec, bq: int, bk: int, group: int, kv_valid: int, heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    g = pl.program_id(2)
    j = outer_ref[s]  # kv-major: the owned KV tile
    i = inner_ref[s]  # streamed Q tile
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[s], seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(jnp.logical_and(first, g == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(active)
    def _compute():
        _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                     q_seg, kv_seg)

    @pl.when(jnp.logical_and(last, g == group - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_dkv(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: Optional[bool] = None,
    schedule: str = "compact",
):
    """Returns (dk, dv) in (BHk, Skp, D) fp32. q pre-scaled by 1/sqrt(d).

    lse/delta are lane-major (BH, Sqp) f32; segment ids (if any) are
    unreplicated (B, Sqp)/(B, Skp).
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,  # 3 matmuls here
        bytes_accessed=2 * k.size * k.dtype.itemsize
        + BH * n_vis * 2 * block_q * D * q.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    out_shape = [
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((block_kv, D), jnp.float32),
        pltpu.VMEM((block_kv, D), jnp.float32),
    ]

    if schedule == "dense":
        kernel = functools.partial(
            _dkv_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_q=t_q,
            group=group, kv_valid=kv_valid, has_segments=has_segments,
        )
        qspec = pl.BlockSpec(
            (1, block_q, D), lambda bh, j, g, i, grp=group: (bh * grp + g, i, 0)
        )
        lspec = pl.BlockSpec(
            (1, block_q), lambda bh, j, g, i, grp=group: (bh * grp + g, i)
        )
        kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, j, g, i: (bh, j, 0))
        in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
        inputs = [q, k, v, do, lse, delta]
        if has_segments:
            heads = BHk // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, j, g, i, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, j, g, i, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BHk, t_kv, group, t_q),
            in_specs=in_specs,
            out_specs=[kvspec, kvspec],
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_bwd_dkv_varlen" if has_segments else "fa2_bwd_dkv",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    sched = build_tile_schedule(
        spec, t_q, t_kv, block_q, block_kv, kv_valid, kv_major=True
    )
    heads = BHk // q_seg.shape[0] if has_segments else 1
    kernel = functools.partial(
        _dkv_kernel_compact, spec=spec, bq=block_q, bk=block_kv, group=group,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s], 0),
    )
    lspec = pl.BlockSpec(
        (1, block_q),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s]),
    )
    kvspec = pl.BlockSpec(
        (1, block_kv, D), lambda bh, s, g, o_, i_, f_, *_: (bh, o_[s], 0)
    )
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv, kv_major=True)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, i_[s])
            ),
            pl.BlockSpec(
                (1, block_kv), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, o_[s])
            ),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BHk, sched.n_steps, group),
        in_specs=in_specs,
        out_specs=[kvspec, kvspec],
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dkv_compact_varlen" if has_segments else "fa2_bwd_dkv_compact",
    )(*scalar_args, *inputs)


# ---------------------------------------------------------------------------
# dQ kernel
# ---------------------------------------------------------------------------


def _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]
    p, _ = _recompute_p(
        q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    # dQ_i += dS K_j                                            (line 15)
    dq_scr[...] += jax.lax.dot_general(
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _dq_kernel_dense(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_kv: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, dq_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, dq_scr) = refs
        q_seg = kv_seg = None
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                    spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg)

    @pl.when(j == t_kv - 1)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dq_kernel_compact(
    *refs,
    spec: MaskSpec, bq: int, bk: int, kv_valid: int, heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, dq_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, dq_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    i = outer_ref[s]
    j = inner_ref[s]
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[s], seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(first)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(active)
    def _compute():
        _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                    spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg)

    @pl.when(last)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def flash_bwd_dq(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: Optional[bool] = None,
    schedule: str = "compact",
):
    """Returns dq in (BH, Sq, D) fp32 (gradient w.r.t. *scaled* q).

    lse/delta are lane-major (BH, Sqp) f32; segment ids (if any) are
    unreplicated (B, Sqp)/(B, Skp).
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,
        bytes_accessed=2 * q.size * q.dtype.itemsize
        + BH * n_vis * 2 * block_kv * D * k.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    out_shape = jax.ShapeDtypeStruct((BH, Sq, D), jnp.float32)
    scratch_shapes = [pltpu.VMEM((block_q, D), jnp.float32)]

    if schedule == "dense":
        kernel = functools.partial(
            _dq_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_kv=t_kv,
            kv_valid=kv_valid, has_segments=has_segments,
        )
        qspec = pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0))
        lspec = pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i))
        kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0))
        in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
        inputs = [q, k, v, do, lse, delta]
        if has_segments:
            heads = BH // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, i, j, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, i, j, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BH, t_q, t_kv),
            in_specs=in_specs,
            out_specs=qspec,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_bwd_dq_varlen" if has_segments else "fa2_bwd_dq",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    sched = build_tile_schedule(spec, t_q, t_kv, block_q, block_kv, kv_valid)
    heads = BH // q_seg.shape[0] if has_segments else 1
    kernel = functools.partial(
        _dq_kernel_compact, spec=spec, bq=block_q, bk=block_kv,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D), lambda bh, s, o_, i_, f_, *_: (bh, o_[s], 0)
    )
    lspec = pl.BlockSpec((1, block_q), lambda bh, s, o_, i_, f_, *_: (bh, o_[s]))
    kvspec = pl.BlockSpec(
        (1, block_kv, D), lambda bh, s, o_, i_, f_, *_, g=group: (bh // g, i_[s], 0)
    )
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, o_[s])
            ),
            pl.BlockSpec(
                (1, block_kv), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, i_[s])
            ),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BH, sched.n_steps),
        in_specs=in_specs,
        out_specs=qspec,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dq_compact_varlen" if has_segments else "fa2_bwd_dq_compact",
    )(*scalar_args, *inputs)


# ---------------------------------------------------------------------------
# Fused one-pass backward: delta + dK + dV + dQ in a single launch
# ---------------------------------------------------------------------------
#
# kv-major like the dkv kernel, but the step body also emits the tile's dQ
# contribution, so (s, p) is recomputed once per visible tile instead of
# twice and Q/dO/lse tiles stream once instead of twice. dQ lives in an f32
# OUTPUT revisited across the sequential axis ("arbitrary" semantics: steps
# run in order, and an output block whose index map returns to a previously
# written block sees the written values -- the interpret-mode executor
# carries outputs block-by-block, and the Mosaic pipeline re-fetches a
# non-immediately-revisited window). The schedule's STEP_QFIRST bit marks
# each q tile's first visit: zero the dq block and compute
# delta = rowsum(dO o O) into a lane-major VMEM scratch row, keyed by
# (g, q_tile) so it survives the revisits of that q tile later in the
# sweep; no separate flash_bwd_delta launch, no delta HBM array at all.


def _fused_qrow_prologue(o_ref, do_ref, delta_scr, dq_ref, g, i, q_first):
    """QFIRST work: delta = rowsum(dO o O) (Algorithm 2 line 4) + dq = 0.

    Runs before the tile compute so the same step can consume the delta it
    just wrote. Returns the (bq, 1) delta column for the current q tile.
    """

    @pl.when(q_first)
    def _init():
        delta_scr[g, i] = jnp.sum(
            o_ref[0].astype(jnp.float32) * do_ref[0].astype(jnp.float32), axis=-1
        )
        dq_ref[0] = jnp.zeros_like(dq_ref[0])

    return delta_scr[g, i][:, None]


def _fused_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta,
                   dk_scr, dv_scr, dq_ref, spec, i, j, bq, bk, kv_valid,
                   needs_mask, q_seg, kv_seg):
    """One visible tile of the fused backward: 5 streamed matmuls total.

    The (s, p) recompute and the dK/dV/dS math are the shared
    :func:`_dkv_tile_math`; the fused kernel adds only the lse cleanup (the
    split path does it outside the kernel) and the dQ contribution.
    """
    k = k_ref[0]      # (bk, d)
    lse = lse_ref[0]  # (bq,), lane-major source
    # Fully-masked rows carry lse = -inf; zero it so exp(S - lse) stays 0
    # (S is DEFAULT_MASK_VALUE there) instead of producing inf.
    lse = jnp.where(jnp.isneginf(lse), 0.0, lse)[:, None]
    ds = _dkv_tile_math(
        q_ref[0], k, v_ref[0], do_ref[0], lse, delta,
        dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
        q_seg, kv_seg,
    )
    # dQ_i += dS K_j -- revisit-accumulated in the f32 output   (line 15)
    dq_ref[0] += jax.lax.dot_general(
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _fused_kernel_dense(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_q: int, group: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dq_ref, dk_scr, dv_scr, delta_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref,
         dk_ref, dv_ref, dq_ref, dk_scr, dv_scr, delta_scr) = refs
        q_seg = kv_seg = None
    j = pl.program_id(1)
    g = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(jnp.logical_and(g == 0, i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    # Dense q-row prologue: every (i, g) is first visited at j == 0.
    delta = _fused_qrow_prologue(o_ref, do_ref, delta_scr, dq_ref, g, i, j == 0)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        _fused_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta,
                       dk_scr, dv_scr, dq_ref, spec, i, j, bq, bk, kv_valid,
                       needs_mask, q_seg, kv_seg)

    @pl.when(jnp.logical_and(g == group - 1, i == t_q - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _fused_kernel_compact(
    *refs,
    spec: MaskSpec, bq: int, bk: int, group: int, kv_valid: int, heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dq_ref, dk_scr, dv_scr, delta_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, do_ref, o_ref, lse_ref,
         dk_ref, dv_ref, dq_ref, dk_scr, dv_scr, delta_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    g = pl.program_id(2)
    j = outer_ref[s]  # kv-major: the owned KV tile
    i = inner_ref[s]  # streamed Q tile
    flags = flags_ref[s]
    active, first, last, needs_mask = decode_step_bits(
        flags, seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(jnp.logical_and(first, g == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    delta = _fused_qrow_prologue(
        o_ref, do_ref, delta_scr, dq_ref, g, i, (flags & STEP_QFIRST) != 0
    )

    @pl.when(active)
    def _compute():
        _fused_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta,
                       dk_scr, dv_scr, dq_ref, spec, i, j, bq, bk, kv_valid,
                       needs_mask, q_seg, kv_seg)

    @pl.when(jnp.logical_and(last, g == group - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_fused(
    q, k, v, o, do, lse, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: Optional[bool] = None,
    schedule: str = "compact",
):
    """One-pass Algorithm 2: (dk, dv, dq) from a single pallas_call.

    q pre-scaled by 1/sqrt(d); o/do are the prepped (BH, Sqp, D) residual
    and cotangent; lse is the RAW lane-major (BH, Sqp) f32 logsumexp (the
    -inf cleanup for fully-masked rows happens in-kernel). Returns

      dk, dv  (BHk, Skp, D) f32
      dq      (BH, Sqp, D) f32, w.r.t. the *scaled* q

    delta = rowsum(dO o O) never touches HBM at all: each q tile's first
    visit computes its (block_q,) row into the lane-major (G, t_q, block_q)
    VMEM scratch and revisits read it back from there. That scratch is
    O(G * Sqp) f32 -- the caller (ops._resolve_bwd) falls back to
    bwd="split" when it would not fit the VMEM budget.

    Per visible tile this runs 5 matmuls and ONE exp; the split baseline
    (delta + dkv + dq launches) runs 7 matmuls (+ the delta rowsum pass)
    and two exps.
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 5,  # 5 matmuls/tile
        bytes_accessed=2 * k.size * k.dtype.itemsize
        + BH * n_vis * 3 * block_q * D * q.dtype.itemsize   # q, do, o tiles
        + BH * n_vis * 2 * block_q * D * 4,                 # dq revisit r/w
        transcendentals=BH * n_vis * block_q * block_kv,    # ONE exp/tile
    )
    out_shape = [
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),  # dk
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),  # dv
        jax.ShapeDtypeStruct((BH, Sq, D), jnp.float32),    # dq (revisited)
    ]
    scratch_shapes = [
        pltpu.VMEM((block_kv, D), jnp.float32),             # dk run scratch
        pltpu.VMEM((block_kv, D), jnp.float32),             # dv run scratch
        pltpu.VMEM((group, t_q, block_q), jnp.float32),     # delta rows
    ]

    if schedule == "dense":
        kernel = functools.partial(
            _fused_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_q=t_q,
            group=group, kv_valid=kv_valid, has_segments=has_segments,
        )
        qspec = pl.BlockSpec(
            (1, block_q, D), lambda bh, j, g, i, grp=group: (bh * grp + g, i, 0)
        )
        lspec = pl.BlockSpec(
            (1, block_q), lambda bh, j, g, i, grp=group: (bh * grp + g, i)
        )
        kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, j, g, i: (bh, j, 0))
        in_specs = [qspec, kvspec, kvspec, qspec, qspec, lspec]
        inputs = [q, k, v, do, o, lse]
        if has_segments:
            heads = BHk // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, j, g, i, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, j, g, i, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BHk, t_kv, group, t_q),
            in_specs=in_specs,
            out_specs=[kvspec, kvspec, qspec],
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                # j is sequential here (dq accumulates across KV runs) --
                # the dense-fused baseline gives up dkv's parallel j axis.
                dimension_semantics=("parallel", "arbitrary", "arbitrary", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_bwd_fused_varlen" if has_segments else "fa2_bwd_fused",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    sched = build_tile_schedule(
        spec, t_q, t_kv, block_q, block_kv, kv_valid, kv_major=True
    )
    heads = BHk // q_seg.shape[0] if has_segments else 1
    kernel = functools.partial(
        _fused_kernel_compact, spec=spec, bq=block_q, bk=block_kv, group=group,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s], 0),
    )
    lspec = pl.BlockSpec(
        (1, block_q),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s]),
    )
    kvspec = pl.BlockSpec(
        (1, block_kv, D), lambda bh, s, g, o_, i_, f_, *_: (bh, o_[s], 0)
    )
    in_specs = [qspec, kvspec, kvspec, qspec, qspec, lspec]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v, do, o, lse]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv, kv_major=True)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, i_[s])
            ),
            pl.BlockSpec(
                (1, block_kv), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, o_[s])
            ),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BHk, sched.n_steps, group),
        in_specs=in_specs,
        out_specs=[kvspec, kvspec, qspec],
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_fused_compact_varlen" if has_segments else "fa2_bwd_fused_compact",
    )(*scalar_args, *inputs)
