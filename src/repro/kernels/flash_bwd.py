"""FlashAttention-2 backward Pallas TPU kernels (the paper's Algorithm 2).

GPU->TPU adaptation (DESIGN.md Section 2): the paper parallelizes the
backward over *column* (KV) blocks, with thread blocks doing **atomic adds**
into dQ. TPUs have no HBM atomics, so we split into two kernels -- the
standard TPU flash scheme:

  * ``dkv`` kernel -- each (bh, j) owns one KV block (the paper's column-
    block worker, Fig. 2 right); the sequential axes stream Q/dO blocks past
    it, accumulating dK_j, dV_j in VMEM scratch (Algorithm 2 lines 12, 16)
    -- and summing over the GQA group g, the paper's "sum dK/dV across
    duplicated heads".
  * ``dq`` kernel -- each (bh, i) owns one Q block; the inner KV loop
    accumulates dQ_i in scratch (line 15). This replaces the atomic-add
    cross-worker communication with a second pass that recomputes S -- extra
    *matmul* FLOPs in exchange for zero communication, which is the paper's
    own trade (matmul FLOPs are ~16x cheaper).

Both kernels support two schedules (see flash_fwd.py / kernels/schedule.py):
``"compact"`` (default) flattens the visible tile pairs into a scalar-
prefetched table -- kv-major for dkv (grid ``(BHk, n_steps, G)``), q-major
for dq (grid ``(BH, n_steps)``) -- so masked-out tiles cost no grid steps
and no DMAs; ``"dense"`` is the legacy visit-everything grid.

Both kernels recompute P = exp(S - L) from the logsumexp only (C1b, line 11).
Softmax statistics arrive LANE-MAJOR: lse and delta are ``(BH, Sqp)`` f32
with the sequence on the 128-lane axis (BlockSpec ``(1, block_q)``) -- the
memory-diet contract shared with flash_fwd.py. D = rowsum(dO o O) (line 4)
is computed by :func:`flash_bwd_delta`, a one-pass Pallas kernel, instead of
an XLA elementwise pass over the broadcast layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.masks import DEFAULT_MASK_VALUE, MaskSpec
from repro.kernels.compat import CompilerParams, resolve_interpret
from repro.kernels.flash_fwd import _tile_mask, _visibility
from repro.kernels.schedule import (
    build_tile_schedule,
    decode_step_bits,
    segment_step_tables,
)


def _recompute_p(q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask,
                 q_seg=None, kv_seg=None):
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    mask = _tile_mask(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)
    s = jnp.where(jnp.logical_or(~needs_mask, mask), s, DEFAULT_MASK_VALUE)
    return jnp.exp(s - lse), s


# ---------------------------------------------------------------------------
# delta = rowsum(dO o O) preprocess (Algorithm 2 line 4)
# ---------------------------------------------------------------------------


def _delta_kernel(o_ref, do_ref, delta_ref):
    delta_ref[0] = jnp.sum(
        o_ref[0].astype(jnp.float32) * do_ref[0].astype(jnp.float32), axis=-1
    )


def flash_bwd_delta(o, do, *, block_q: int, interpret: Optional[bool] = None):
    """rowsum(dO o O) over prepped (BH, Sqp, D) tensors -> (BH, Sqp) f32.

    One fused read of O and dO per tile, emitting the lane-major delta the
    backward kernels consume directly (no 128x broadcast round-trip).
    """
    interpret = resolve_interpret(interpret)
    BH, Sqp, D = o.shape
    assert Sqp % block_q == 0
    spec = pl.BlockSpec((1, block_q, D), lambda bh, i: (bh, i, 0))
    return pl.pallas_call(
        _delta_kernel,
        grid=(BH, Sqp // block_q),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, block_q), lambda bh, i: (bh, i)),
        out_shape=jax.ShapeDtypeStruct((BH, Sqp), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        cost_estimate=pl.CostEstimate(
            flops=2 * o.size,
            bytes_accessed=o.size * o.dtype.itemsize
            + do.size * do.dtype.itemsize + BH * Sqp * 4,
            transcendentals=0,
        ),
        interpret=interpret,
        name="fa2_bwd_delta",
    )(o, do)


# ---------------------------------------------------------------------------
# dK / dV kernel
# ---------------------------------------------------------------------------


def _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                 dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                 q_seg, kv_seg):
    q = q_ref[0]      # (bq, d), pre-scaled
    k = k_ref[0]      # (bk, d)
    v = v_ref[0]
    do = do_ref[0]    # (bq, d)
    lse = lse_ref[0][:, None]    # (bq, 1), lane-major source
    delta = delta_ref[0][:, None]
    p, _ = _recompute_p(
        q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg
    )  # line 11
    # dV_j += P^T dO_i                                          (line 12)
    dv_scr[...] += jax.lax.dot_general(
        p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # dP = dO_i V_j^T                                           (line 13)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    # dS = P o (dP - D_i)                                       (line 14)
    ds = p * (dp - delta)
    # dK_j += dS^T Q_i  (q pre-scaled => scale already folded)  (line 16)
    dk_scr[...] += jax.lax.dot_general(
        ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _dkv_kernel_dense(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_q: int, group: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg = kv_seg = None
    j = pl.program_id(1)
    g = pl.program_id(2)
    i = pl.program_id(3)

    @pl.when(jnp.logical_and(g == 0, i == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                     q_seg, kv_seg)

    @pl.when(jnp.logical_and(g == group - 1, i == t_q - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dkv_kernel_compact(
    *refs,
    spec: MaskSpec, bq: int, bk: int, group: int, kv_valid: int, heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_scr, dv_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    g = pl.program_id(2)
    j = outer_ref[s]  # kv-major: the owned KV tile
    i = inner_ref[s]  # streamed Q tile
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[s], seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(jnp.logical_and(first, g == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    @pl.when(active)
    def _compute():
        _dkv_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                     dk_scr, dv_scr, spec, i, j, bq, bk, kv_valid, needs_mask,
                     q_seg, kv_seg)

    @pl.when(jnp.logical_and(last, g == group - 1))
    def _emit():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_dkv(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: Optional[bool] = None,
    schedule: str = "compact",
):
    """Returns (dk, dv) in (BHk, Skp, D) fp32. q pre-scaled by 1/sqrt(d).

    lse/delta are lane-major (BH, Sqp) f32; segment ids (if any) are
    unreplicated (B, Sqp)/(B, Skp).
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,  # 3 matmuls here
        bytes_accessed=2 * k.size * k.dtype.itemsize
        + BH * n_vis * 2 * block_q * D * q.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    out_shape = [
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
        jax.ShapeDtypeStruct((BHk, Skp, D), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((block_kv, D), jnp.float32),
        pltpu.VMEM((block_kv, D), jnp.float32),
    ]

    if schedule == "dense":
        kernel = functools.partial(
            _dkv_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_q=t_q,
            group=group, kv_valid=kv_valid, has_segments=has_segments,
        )
        qspec = pl.BlockSpec(
            (1, block_q, D), lambda bh, j, g, i, grp=group: (bh * grp + g, i, 0)
        )
        lspec = pl.BlockSpec(
            (1, block_q), lambda bh, j, g, i, grp=group: (bh * grp + g, i)
        )
        kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, j, g, i: (bh, j, 0))
        in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
        inputs = [q, k, v, do, lse, delta]
        if has_segments:
            heads = BHk // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, j, g, i, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, j, g, i, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BHk, t_kv, group, t_q),
            in_specs=in_specs,
            out_specs=[kvspec, kvspec],
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_bwd_dkv_varlen" if has_segments else "fa2_bwd_dkv",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    sched = build_tile_schedule(
        spec, t_q, t_kv, block_q, block_kv, kv_valid, kv_major=True
    )
    heads = BHk // q_seg.shape[0] if has_segments else 1
    kernel = functools.partial(
        _dkv_kernel_compact, spec=spec, bq=block_q, bk=block_kv, group=group,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s], 0),
    )
    lspec = pl.BlockSpec(
        (1, block_q),
        lambda bh, s, g, o_, i_, f_, *_, grp=group: (bh * grp + g, i_[s]),
    )
    kvspec = pl.BlockSpec(
        (1, block_kv, D), lambda bh, s, g, o_, i_, f_, *_: (bh, o_[s], 0)
    )
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv, kv_major=True)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, i_[s])
            ),
            pl.BlockSpec(
                (1, block_kv), lambda bh, s, g, o_, i_, f_, t_, h=heads: (bh // h, o_[s])
            ),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BHk, sched.n_steps, group),
        in_specs=in_specs,
        out_specs=[kvspec, kvspec],
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dkv_compact_varlen" if has_segments else "fa2_bwd_dkv_compact",
    )(*scalar_args, *inputs)


# ---------------------------------------------------------------------------
# dQ kernel
# ---------------------------------------------------------------------------


def _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg):
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0][:, None]
    delta = delta_ref[0][:, None]
    p, _ = _recompute_p(
        q, k, lse, spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta)
    # dQ_i += dS K_j                                            (line 15)
    dq_scr[...] += jax.lax.dot_general(
        ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _dq_kernel_dense(
    *refs,
    spec: MaskSpec, bq: int, bk: int, t_kv: int, kv_valid: int,
    has_segments: bool = False,
):
    if has_segments:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, dq_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, dq_scr) = refs
        q_seg = kv_seg = None
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    empty, needs_mask = _visibility(spec, i, j, bq, bk, kv_valid, q_seg, kv_seg)

    @pl.when(~empty)
    def _compute():
        _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                    spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg)

    @pl.when(j == t_kv - 1)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dq_kernel_compact(
    *refs,
    spec: MaskSpec, bq: int, bk: int, kv_valid: int, heads: int,
    has_segments: bool = False,
):
    if has_segments:
        (outer_ref, inner_ref, flags_ref, seg_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, dq_scr) = refs
        q_seg, kv_seg = qs_ref[0], ks_ref[0]
    else:
        (outer_ref, inner_ref, flags_ref,
         q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, dq_scr) = refs
        q_seg = kv_seg = None
    bh = pl.program_id(0)
    s = pl.program_id(1)
    i = outer_ref[s]
    j = inner_ref[s]
    active, first, last, needs_mask = decode_step_bits(
        flags_ref[s], seg_ref[bh // heads, s] if has_segments else None
    )

    @pl.when(first)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    @pl.when(active)
    def _compute():
        _dq_compute(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_scr,
                    spec, i, j, bq, bk, kv_valid, needs_mask, q_seg, kv_seg)

    @pl.when(last)
    def _emit():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def flash_bwd_dq(
    q, k, v, do, lse, delta, spec: MaskSpec, *,
    group: int, block_q: int, block_kv: int, kv_valid: int,
    q_seg=None, kv_seg=None, interpret: Optional[bool] = None,
    schedule: str = "compact",
):
    """Returns dq in (BH, Sq, D) fp32 (gradient w.r.t. *scaled* q).

    lse/delta are lane-major (BH, Sqp) f32; segment ids (if any) are
    unreplicated (B, Sqp)/(B, Skp).
    """
    interpret = resolve_interpret(interpret)
    BH, Sq, D = q.shape
    BHk, Skp, _ = k.shape
    t_q, t_kv = Sq // block_q, Skp // block_kv
    has_segments = q_seg is not None
    from repro.core.flash import _visible_pairs

    n_vis = len(_visible_pairs(spec, t_q, t_kv, block_q, block_kv)[0])
    cost = pl.CostEstimate(
        flops=BH * n_vis * 2 * block_q * block_kv * D * 3,
        bytes_accessed=2 * q.size * q.dtype.itemsize
        + BH * n_vis * 2 * block_kv * D * k.dtype.itemsize,
        transcendentals=BH * n_vis * block_q * block_kv,
    )
    out_shape = jax.ShapeDtypeStruct((BH, Sq, D), jnp.float32)
    scratch_shapes = [pltpu.VMEM((block_q, D), jnp.float32)]

    if schedule == "dense":
        kernel = functools.partial(
            _dq_kernel_dense, spec=spec, bq=block_q, bk=block_kv, t_kv=t_kv,
            kv_valid=kv_valid, has_segments=has_segments,
        )
        qspec = pl.BlockSpec((1, block_q, D), lambda bh, i, j: (bh, i, 0))
        lspec = pl.BlockSpec((1, block_q), lambda bh, i, j: (bh, i))
        kvspec = pl.BlockSpec((1, block_kv, D), lambda bh, i, j, g=group: (bh // g, j, 0))
        in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
        inputs = [q, k, v, do, lse, delta]
        if has_segments:
            heads = BH // q_seg.shape[0]
            in_specs += [
                pl.BlockSpec((1, block_q), lambda bh, i, j, h=heads: (bh // h, i)),
                pl.BlockSpec((1, block_kv), lambda bh, i, j, h=heads: (bh // h, j)),
            ]
            inputs += [q_seg, kv_seg]
        return pl.pallas_call(
            kernel,
            grid=(BH, t_q, t_kv),
            in_specs=in_specs,
            out_specs=qspec,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
            cost_estimate=cost,
            interpret=interpret,
            name="fa2_bwd_dq_varlen" if has_segments else "fa2_bwd_dq",
        )(*inputs)

    if schedule != "compact":
        raise ValueError(f"unknown tile schedule: {schedule!r}")
    sched = build_tile_schedule(spec, t_q, t_kv, block_q, block_kv, kv_valid)
    heads = BH // q_seg.shape[0] if has_segments else 1
    kernel = functools.partial(
        _dq_kernel_compact, spec=spec, bq=block_q, bk=block_kv,
        kv_valid=kv_valid, heads=heads, has_segments=has_segments,
    )
    qspec = pl.BlockSpec(
        (1, block_q, D), lambda bh, s, o_, i_, f_, *_: (bh, o_[s], 0)
    )
    lspec = pl.BlockSpec((1, block_q), lambda bh, s, o_, i_, f_, *_: (bh, o_[s]))
    kvspec = pl.BlockSpec(
        (1, block_kv, D), lambda bh, s, o_, i_, f_, *_, g=group: (bh // g, i_[s], 0)
    )
    in_specs = [qspec, kvspec, kvspec, qspec, lspec, lspec]
    scalar_args = [
        jnp.asarray(sched.outer), jnp.asarray(sched.inner), jnp.asarray(sched.flags)
    ]
    inputs = [q, k, v, do, lse, delta]
    if has_segments:
        scalar_args.append(
            segment_step_tables(q_seg, kv_seg, sched, block_q, block_kv)
        )
        in_specs += [
            pl.BlockSpec(
                (1, block_q), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, o_[s])
            ),
            pl.BlockSpec(
                (1, block_kv), lambda bh, s, o_, i_, f_, t_, h=heads: (bh // h, i_[s])
            ),
        ]
        inputs += [q_seg, kv_seg]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(BH, sched.n_steps),
        in_specs=in_specs,
        out_specs=qspec,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
        name="fa2_bwd_dq_compact_varlen" if has_segments else "fa2_bwd_dq_compact",
    )(*scalar_args, *inputs)
