"""Pure-jnp reference oracle for attention (forward and the Algorithm-2 bwd).

This is the ground truth every other implementation (XLA flash, Pallas
kernels, decode paths, context-parallel attention) is tested against.
It deliberately materializes the N x N score matrix -- O(N^2) memory --
and is also the "standard attention" baseline of the paper's benchmarks.

Layout convention (whole repo): q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D)
with Hq % Hkv == 0 (GQA). Output (B, Sq, Hq, D); LSE (B, Hq, Sq).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.masks import MaskSpec, make_segment_mask, make_tile_mask


def attention_reference(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    spec: MaskSpec = MaskSpec(),
    scale: Optional[float] = None,
    kv_length: Optional[jnp.ndarray] = None,
    segment_ids: Optional[jnp.ndarray] = None,
    kv_segment_ids: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Naive exact attention. Returns (o, lse).

    kv_length: optional (B,) int32 of valid KV lengths (for padded caches).
    segment_ids / kv_segment_ids: optional (B, Sq) / (B, Sk) int32 packed
    varlen ids -- visibility additionally requires equal ids (the dense
    ground truth the varlen kernels are tested against).
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    assert Hq % Hk == 0, (Hq, Hk)
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    qf = qf.reshape(B, Sq, Hk, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)  # (B, Hk, G, Sq, Sk)

    q_ids = jnp.arange(Sq, dtype=jnp.int32) + spec.q_offset
    kv_ids = jnp.arange(Sk, dtype=jnp.int32)
    mask = make_tile_mask(spec, q_ids, kv_ids)  # (Sq, Sk) or None
    if segment_ids is not None:
        if kv_segment_ids is None:
            kv_segment_ids = segment_ids
        seg = make_segment_mask(segment_ids, kv_segment_ids)  # (B, Sq, Sk)
        seg = seg[:, None, None]  # broadcast over (Hk, G)
        mask = seg if mask is None else (mask & seg)
    if kv_length is not None:
        valid = kv_ids[None, :] < kv_length[:, None]  # (B, Sk)
        valid = valid[:, None, None, None, :]
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)

    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isneginf(s), 0.0, p)  # exact zeros for masked entries
    l = jnp.sum(p, axis=-1)
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p / l_safe[..., None], vf)
    lse = jnp.where(l == 0.0, -jnp.inf, m_safe + jnp.log(l_safe))
    return (
        o.reshape(B, Sq, Hq, D).astype(q.dtype),
        lse.reshape(B, Hk * G, Sq),
    )


def attention_reference_bwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    o: jnp.ndarray,
    do: jnp.ndarray,
    lse: jnp.ndarray,
    spec: MaskSpec = MaskSpec(),
    scale: Optional[float] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference backward implementing the paper's Section 2.2 equations,
    recomputing P from (q, k, lse) exactly as Algorithm 2 does.

    Returns (dq, dk, dv). Used to sanity-check custom VJPs independently of
    jax.grad through the reference forward.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    qf = q.astype(jnp.float32).reshape(B, Sq, Hk, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = do.astype(jnp.float32).reshape(B, Sq, Hk, G, D)
    of = o.astype(jnp.float32).reshape(B, Sq, Hk, G, D)
    lsef = lse.reshape(B, Hk, G, Sq)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf * scale, kf)
    q_ids = jnp.arange(Sq, dtype=jnp.int32) + spec.q_offset
    kv_ids = jnp.arange(Sk, dtype=jnp.int32)
    mask = make_tile_mask(spec, q_ids, kv_ids)
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    # P = exp(S - L): Algorithm 2 line 11 -- the FA2 tweak (LSE only).
    p = jnp.exp(s - jnp.where(jnp.isneginf(lsef), 0.0, lsef)[..., None])
    p = jnp.where(jnp.isneginf(s), 0.0, p)

    dv = jnp.einsum("bhgqk,bqhgd->bkhd", p, dof)
    dp = jnp.einsum("bqhgd,bkhd->bhgqk", dof, vf)
    delta = jnp.sum(dof * of, axis=-1)  # D = rowsum(dO o O), line 4
    ds = p * (dp - delta.transpose(0, 2, 3, 1)[..., None])
    dq = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf) * scale
    dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf) * scale
    return (
        dq.reshape(B, Sq, Hq, D).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )
