"""Empirical autotuner for the Pallas kernel knob space.

FlashAttention-2 (paper Sec 3.2 / Sec 4) gets its last 20-30% of FLOPs
utilization from picking the right work partitioning per shape, tuned
empirically per (head dim, causal, seq) -- not from algorithm changes.
This module replaces the repo's hand heuristics with measurement for the
five interacting forward/backward knobs

    block_q, block_kv, schedule, bwd, num_q_bands, kv_splits

plus the split-KV decode's ``num_splits``:

  * **Sweep** (``run_sweep`` / the CLI): measure candidate knob settings
    per (shape, dtype, mask family) with the interleaved min-of-N timer
    (``repro.utils.timing`` -- the same fixed discipline the benchmarks
    use; the old mean-of-3 timer recorded fwd slower than fwd+bwd and
    could not rank knobs). Candidates always include the existing
    heuristic's choice, so a winner is never worse than the heuristic
    *as measured*.
  * **Cache**: winners persist to a committed JSON cache
    (``src/repro/kernels/tuned.json``), keyed like the BENCH_attn.json
    configs: ``impl/causal=<0|1>/seq=<S>/heads=<H>/hd=<D>/dtype=<dt>``.
    An entry stores only the knobs the sweep fixed; omitted knobs defer
    to the heuristic at resolution time.
  * **Resolution**: ``kernels/ops.resolve_pallas_knobs`` consults
    :func:`lookup` whenever a ``PallasFlashConfig`` knob is ``None``.
    Precedence is explicit arg > tuned cache > heuristic
    (``default_block_sizes`` / ``default_forward_partitions`` /
    ``_resolve_bwd``). Lookup is exact-key first, then nearest-shape:
    same impl/causal/head-dim/dtype, nearest seq within a 2x radius
    (preferring a heads match) -- knob landscapes are smooth in seq but
    cliff-shaped in head dim, so head dim never relaxes. Mask families
    beyond plain causal/full (windows, sinks) skip the cache entirely.
  * **Escape hatches**: ``use_tuned=False`` on the config, or env
    ``REPRO_TUNED_CACHE=0`` globally; ``REPRO_TUNED_CACHE_PATH`` points
    resolution at an alternate cache file (tests and CI use this).

The committed cache is honest only for the environment that produced it
(the ``backend`` field records it; this repo's CI measures CPU interpret
mode, where step count dominates). ``--check`` guards staleness: it
re-sweeps the smoke shapes and fails if the committed knobs measure more
than ``--tol`` slower than a fresh winner.

CLI::

    python -m repro.kernels.autotune [--out PATH] [--smoke] [--check]
        [--iters N] [--tol F] [--shapes seq:heads:hd:causal:batch[:dtype],...]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import warnings
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_PATH",
    "ENV_DISABLE",
    "ENV_PATH",
    "cache_enabled",
    "cache_key",
    "clear_cache",
    "load_cache",
    "lookup",
    "new_doc",
    "parse_key",
    "resolve_decode_splits",
    "run_sweep",
    "save_cache",
    "sweep_attention_shape",
    "sweep_decode_shape",
    "sweep_paged_decode_shape",
    "validate_doc",
]

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "tuned.json")
ENV_DISABLE = "REPRO_TUNED_CACHE"       # "0" disables the cache globally
ENV_PATH = "REPRO_TUNED_CACHE_PATH"     # alternate cache file

SCHEMA_VERSION = 1
# Knobs an entry may pin, per key family (impl prefix). Entries storing
# other keys (or illegal values) fail validate_doc.
ATTN_KNOBS = {
    "block_q": int, "block_kv": int, "schedule": str, "bwd": str,
    "num_q_bands": int, "kv_splits": int,
}
DECODE_KNOBS = {"num_splits": int}
# Provenance fields entries may carry alongside knobs (ignored at lookup).
PROVENANCE = ("us_fwd", "us_fwdbwd", "batch", "iters")
# Nearest-shape fallback never reaches past this seq ratio.
NEAREST_SEQ_RADIUS = 2.0


# ---------------------------------------------------------------------------
# Cache file: key format, schema, load/save
# ---------------------------------------------------------------------------


def cache_key(impl: str, causal: bool, seq: int, heads: int, head_dim: int,
              dtype) -> str:
    """BENCH_attn.json-style config key for one tuned entry."""
    import jax.numpy as jnp

    dt = str(jnp.dtype(dtype))
    return (
        f"{impl}/causal={int(bool(causal))}/seq={int(seq)}"
        f"/heads={int(heads)}/hd={int(head_dim)}/dtype={dt}"
    )


def parse_key(key: str) -> Dict[str, object]:
    """Inverse of :func:`cache_key`; raises ValueError on malformed keys."""
    impl, _, rest = key.partition("/")
    fields = {}
    for part in rest.split("/"):
        name, eq, val = part.partition("=")
        if not impl or not eq or name in fields:
            raise ValueError(f"malformed tuned-cache key: {key!r}")
        fields[name] = val
    if set(fields) != {"causal", "seq", "heads", "hd", "dtype"}:
        raise ValueError(f"malformed tuned-cache key: {key!r}")
    return dict(
        impl=impl, causal=bool(int(fields["causal"])), seq=int(fields["seq"]),
        heads=int(fields["heads"]), head_dim=int(fields["hd"]),
        dtype=fields["dtype"],
    )


def new_doc(backend: str, entries: Optional[dict] = None) -> dict:
    return {"version": SCHEMA_VERSION, "backend": backend,
            "entries": dict(entries or {})}


def _knob_spec(impl: str) -> Dict[str, type]:
    # Paged decode entries key as "flash_decode_paged<page_size>": the page
    # size changes the kernel's DMA granularity, so geometries tuned at one
    # page size never answer lookups for another.
    return DECODE_KNOBS if impl.startswith("flash_decode") else ATTN_KNOBS


def validate_doc(doc: object) -> dict:
    """Schema-check a cache document; returns it, raises ValueError if bad."""
    if not isinstance(doc, dict):
        raise ValueError("tuned cache must be a JSON object")
    if doc.get("version") != SCHEMA_VERSION:
        raise ValueError(f"tuned cache version must be {SCHEMA_VERSION}, "
                         f"got {doc.get('version')!r}")
    if not isinstance(doc.get("backend"), str):
        raise ValueError("tuned cache needs a string 'backend' field")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        raise ValueError("tuned cache needs an 'entries' object")
    for key, entry in entries.items():
        meta = parse_key(key)  # raises on malformed keys
        if not isinstance(entry, dict):
            raise ValueError(f"entry {key!r} must be an object")
        spec = _knob_spec(meta["impl"])
        for name, val in entry.items():
            if name in PROVENANCE:
                continue
            if name not in spec:
                raise ValueError(f"entry {key!r}: unknown knob {name!r}")
            if not isinstance(val, spec[name]) or isinstance(val, bool):
                raise ValueError(f"entry {key!r}: knob {name} has bad value "
                                 f"{val!r}")
        if entry.get("schedule") not in (None, "compact", "dense"):
            raise ValueError(f"entry {key!r}: bad schedule")
        if entry.get("bwd") not in (None, "fused", "split"):
            raise ValueError(f"entry {key!r}: bad bwd")
        for name in ("block_q", "block_kv", "num_q_bands", "kv_splits",
                     "num_splits"):
            v = entry.get(name)
            if v is not None and v < 1:
                raise ValueError(f"entry {key!r}: {name} must be >= 1")
    return doc


_LOAD_CACHE: Dict[str, Tuple[Optional[int], dict]] = {}


def _cache_path(path: Optional[str] = None) -> str:
    return path or os.environ.get(ENV_PATH) or DEFAULT_PATH


def clear_cache() -> None:
    """Drop the in-process load cache (tests that swap cache files)."""
    _LOAD_CACHE.clear()


def load_cache(path: Optional[str] = None) -> dict:
    """Load + validate the tuned cache; {} entries when absent or invalid.

    Tolerant by design: a missing, unreadable, or schema-invalid file
    disables tuning (with a warning) rather than breaking attention calls
    -- strict validation belongs to ``--check`` / CI, not the hot path.
    Results are memoized per (path, mtime).
    """
    path = _cache_path(path)
    try:
        mtime: Optional[int] = os.stat(path).st_mtime_ns
    except OSError:
        mtime = None
    hit = _LOAD_CACHE.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    doc = new_doc(backend="empty")
    if mtime is not None:
        try:
            with open(path) as f:
                doc = validate_doc(json.load(f))
        except (OSError, ValueError) as e:
            warnings.warn(
                f"ignoring invalid tuned cache {path}: {e}", stacklevel=2
            )
            doc = new_doc(backend="empty")
    _LOAD_CACHE[path] = (mtime, doc)
    return doc


def save_cache(doc: dict, path: Optional[str] = None) -> str:
    path = _cache_path(path)
    validate_doc(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    clear_cache()
    return path


def cache_enabled(use_tuned: Optional[bool] = None) -> bool:
    """Config knob (tri-state) + env escape hatch -> concrete bool."""
    if use_tuned is not None:
        return use_tuned
    return os.environ.get(ENV_DISABLE, "1") != "0"


def lookup(impl: str, causal: bool, seq: int, heads: int, head_dim: int,
           dtype, *, path: Optional[str] = None) -> Dict[str, object]:
    """Tuned knobs for a shape; {} when no (near-enough) entry exists.

    Exact key first; otherwise the nearest entry with the same
    impl/causal/head-dim/dtype whose seq is within NEAREST_SEQ_RADIUS
    (2x), ranked by (heads mismatch, |log2 seq ratio|). Null-valued knobs
    and provenance fields are stripped so callers can treat the result as
    "knobs this entry pins".
    """
    import math

    import jax.numpy as jnp

    entries = load_cache(path)["entries"]
    key = cache_key(impl, causal, seq, heads, head_dim, dtype)
    entry = entries.get(key)
    if entry is None:
        dt = str(jnp.dtype(dtype))
        best_rank = None
        for k, e in entries.items():
            m = parse_key(k)
            if (m["impl"] != impl or m["causal"] != bool(causal)
                    or m["head_dim"] != head_dim or m["dtype"] != dt):
                continue
            dist = abs(math.log2(m["seq"] / seq)) if seq else float("inf")
            if dist > math.log2(NEAREST_SEQ_RADIUS):
                continue
            rank = (m["heads"] != heads, dist)
            if best_rank is None or rank < best_rank:
                best_rank, entry = rank, e
    if entry is None:
        return {}
    spec = _knob_spec(impl)
    return {k: v for k, v in entry.items() if k in spec and v is not None}


def resolve_decode_splits(seq: int, heads: int, head_dim: int, dtype, *,
                          page_size: Optional[int] = None,
                          use_tuned: Optional[bool] = None,
                          default: int = 8) -> int:
    """Tuned ``num_splits`` for split-KV decode against a seq-long cache.

    ``page_size`` switches to the paged-decode key family
    (``flash_decode_paged<ps>``, ``seq`` = the *logical* capacity
    ``n_pages * page_size``) so the serving engine's page-indirect step
    consults its own tuned entries rather than the contiguous cache's."""
    from repro.obs.metrics import count_knob

    impl = ("flash_decode" if page_size is None
            else f"flash_decode_paged{int(page_size)}")
    if not cache_enabled(use_tuned):
        count_knob(impl, "heuristic")
        return default
    tuned = lookup(impl, True, seq, heads, head_dim, dtype)
    count_knob(impl, "tuned" if "num_splits" in tuned else "heuristic")
    return int(tuned.get("num_splits", default))


# ---------------------------------------------------------------------------
# Sweep harness
# ---------------------------------------------------------------------------


def _attention_candidates(seq: int, heads: int, head_dim: int, batch: int,
                          causal: bool) -> List[Dict[str, object]]:
    """Concrete five-knob candidate set for one shape (heuristic included).

    Kept deliberately small -- interpret mode pays Python per grid step, so
    the sweep prunes block sizes that would explode the step count
    (anything under seq/8) and only toggles the knobs that can matter:
    dense-vs-compact once (at default blocks), partitions on-vs-off.
    The backward knob is staged separately (see sweep_attention_shape).
    """
    from repro.kernels.ops import (
        default_block_sizes,
        default_forward_partitions,
    )

    def _round8(x):
        return (x + 7) // 8 * 8

    bq_def, bk_def = default_block_sizes(seq, seq, head_dim)
    pairs = {(bq_def, bk_def)}
    for b in (64, 128, 256, 512):
        if b <= _round8(seq) and b * 8 >= seq:
            pairs.add((b, b))
    cands: List[Dict[str, object]] = []
    seen = set()

    def _add(bq, bk, schedule, nb, ks):
        knobs = dict(block_q=bq, block_kv=bk, schedule=schedule,
                     num_q_bands=nb, kv_splits=ks)
        sig = tuple(sorted(knobs.items()))
        if sig not in seen:
            seen.add(sig)
            cands.append(knobs)

    for bq, bk in sorted(pairs):
        t_q, t_kv = -(-seq // bq), -(-seq // bk)
        nb_auto, ks_auto = default_forward_partitions(
            batch * heads, max(1, t_q), max(1, t_kv)
        )
        _add(bq, bk, "compact", nb_auto, ks_auto)  # the heuristic's pick
        if (nb_auto, ks_auto) != (1, 1):
            _add(bq, bk, "compact", 1, 1)
    _add(bq_def, bk_def, "dense", 1, 1)
    return cands


def _fmt_knobs(knobs: Dict[str, object]) -> str:
    return ";".join(f"{k}={v}" for k, v in sorted(knobs.items()))


def sweep_attention_shape(
    *, seq: int, heads: int, head_dim: int, causal: bool, batch: int,
    dtype="float32", iters: int = 3, interpret: Optional[bool] = None,
    log=None,
) -> Tuple[str, Dict[str, object]]:
    """Measure the knob space for one attention shape -> (key, entry).

    Two stages keep the candidate count linear instead of multiplicative:
    stage A sweeps the forward knobs (blocks x schedule x partitions) on
    forward wall time; stage B fixes the stage-A winner and sweeps the
    backward knob on forward+backward wall time. Every knob in the
    returned entry is concrete (the resolution layer's precedence then
    reads: explicit > this entry > heuristic).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.masks import MaskSpec
    from repro.kernels.ops import flash_attention_pallas
    from repro.utils.timing import interleaved_timeit

    spec = MaskSpec(causal=causal)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    shape = (batch, seq, heads, head_dim)
    dt = jnp.dtype(dtype)
    q = jax.random.normal(kq, shape, jnp.float32).astype(dt)
    k = jax.random.normal(kk, shape, jnp.float32).astype(dt)
    v = jax.random.normal(kv, shape, jnp.float32).astype(dt)

    def _fwd(knobs):
        return jax.jit(lambda q, k, v: flash_attention_pallas(
            q, k, v, spec, interpret=interpret, use_tuned=False, **knobs
        ))

    cands = _attention_candidates(seq, heads, head_dim, batch, causal)
    fwd_fns = {_fmt_knobs(kn): _fwd(kn) for kn in cands}
    fwd_best = interleaved_timeit(fwd_fns, q, k, v, iters=iters)
    by_sig = {_fmt_knobs(kn): kn for kn in cands}
    win_sig = min(fwd_best, key=fwd_best.get)
    winner = dict(by_sig[win_sig])
    if log:
        for sig in sorted(fwd_best, key=fwd_best.get):
            log(f"  fwd {fwd_best[sig]*1e6:10.0f}us  {sig}")

    def _fwdbwd(bwd):
        return jax.jit(jax.grad(lambda q, k, v: flash_attention_pallas(
            q, k, v, spec, interpret=interpret, use_tuned=False,
            bwd=bwd, **winner
        ).astype(jnp.float32).sum()))

    bwd_best = interleaved_timeit(
        {bwd: _fwdbwd(bwd) for bwd in ("fused", "split")}, q, k, v,
        iters=iters,
    )
    winner["bwd"] = min(bwd_best, key=bwd_best.get)
    if log:
        for name, t in sorted(bwd_best.items(), key=lambda kv: kv[1]):
            log(f"  fwd+bwd {t*1e6:10.0f}us  bwd={name}")
    entry = dict(winner)
    entry["us_fwd"] = round(fwd_best[win_sig] * 1e6, 1)
    entry["us_fwdbwd"] = round(bwd_best[winner["bwd"]] * 1e6, 1)
    entry["batch"] = batch
    entry["iters"] = iters
    return cache_key("flash_pallas", causal, seq, heads, head_dim, dt), entry


def sweep_decode_shape(
    *, seq: int, heads: int, head_dim: int, batch: int = 4, dtype="float32",
    iters: int = 3, interpret: Optional[bool] = None, log=None,
) -> Tuple[str, Dict[str, object]]:
    """Measure split-KV decode ``num_splits`` for one cache size."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import flash_decode_pallas
    from repro.utils.timing import interleaved_timeit

    dt = jnp.dtype(dtype)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (batch, 1, heads, head_dim), jnp.float32).astype(dt)
    kc = jax.random.normal(kk, (batch, seq, heads, head_dim), jnp.float32).astype(dt)
    vc = jax.random.normal(kv, (batch, seq, heads, head_dim), jnp.float32).astype(dt)
    lens = jnp.full((batch,), seq, jnp.int32)

    def _fn(ns):
        return jax.jit(lambda q, kc, vc, lens: flash_decode_pallas(
            q, kc, vc, lens, num_splits=ns, interpret=interpret
        )[0])

    splits = sorted({ns for ns in (1, 4, 8, 16) if ns <= max(1, seq // 8)})
    best = interleaved_timeit(
        {str(ns): _fn(ns) for ns in splits}, q, kc, vc, lens, iters=iters
    )
    win = min(best, key=best.get)
    if log:
        for name, t in sorted(best.items(), key=lambda kv: kv[1]):
            log(f"  decode {t*1e6:10.0f}us  num_splits={name}")
    entry = dict(num_splits=int(win), us_fwd=round(best[win] * 1e6, 1),
                 batch=batch, iters=iters)
    return cache_key("flash_decode", True, seq, heads, head_dim, dt), entry


def _paged_fixture(seq, heads, head_dim, batch, page_size, dt):
    """Random paged-decode operands at full logical occupancy, with the
    physical pages deliberately shuffled (the serving steady state)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    n_pages = seq // page_size
    P = batch * n_pages + 1  # + the reserved null page 0
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (batch, 1, heads, head_dim), jnp.float32).astype(dt)
    kp = jax.random.normal(kk, (heads, P, page_size, head_dim), jnp.float32).astype(dt)
    vp = jax.random.normal(kv, (heads, P, page_size, head_dim), jnp.float32).astype(dt)
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    tbl = jnp.asarray(perm.reshape(batch, n_pages), jnp.int32)
    lens = jnp.full((batch,), seq, jnp.int32)
    return q, kp, vp, lens, tbl


def sweep_paged_decode_shape(
    *, seq: int, heads: int, head_dim: int, page_size: int, batch: int = 4,
    dtype="float32", iters: int = 3, interpret: Optional[bool] = None,
    log=None,
) -> Tuple[str, Dict[str, object]]:
    """Measure page-indirect decode ``num_splits`` for one logical capacity
    (``seq = n_pages * page_size``) at one page size -- the serving path's
    geometry (kernels/flash_decode.flash_decode_paged_kernel)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import flash_decode_paged_pallas
    from repro.utils.timing import interleaved_timeit

    dt = jnp.dtype(dtype)
    assert seq % page_size == 0, "logical capacity must be page-aligned"
    n_pages = seq // page_size
    q, kp, vp, lens, tbl = _paged_fixture(seq, heads, head_dim, batch,
                                          page_size, dt)

    def _fn(ns):
        return jax.jit(lambda q, kp, vp, lens, tbl: flash_decode_paged_pallas(
            q, kp, vp, lens, tbl, num_splits=ns, interpret=interpret
        )[0])

    splits = sorted({ns for ns in (1, 2, 4, 8, 16) if ns <= n_pages})
    best = interleaved_timeit(
        {str(ns): _fn(ns) for ns in splits}, q, kp, vp, lens, tbl, iters=iters
    )
    win = min(best, key=best.get)
    if log:
        for name, t in sorted(best.items(), key=lambda kv: kv[1]):
            log(f"  paged_decode {t*1e6:10.0f}us  num_splits={name}")
    entry = dict(num_splits=int(win), us_fwd=round(best[win] * 1e6, 1),
                 batch=batch, iters=iters)
    return cache_key(f"flash_decode_paged{page_size}", True, seq, heads,
                     head_dim, dt), entry


# The BENCH_attn.json benchmark shapes (fig4_6 protocol: batch*seq = 4096
# tokens, 4 heads, head dim 64; flash_pallas rows run seq <= 512, the
# bwd_cmp/kernel-layer rows run causal seq 1024/2048) plus the decode
# serving shapes. Each is (kind, seq, heads, head_dim, causal, batch) with
# optional trailing fields: an int is the page_size for
# kind == "paged_decode" (seq is then the logical capacity
# n_pages * page_size), a str is the dtype (default float32).
BENCH_SHAPES: Tuple[Tuple, ...] = (
    ("attn", 256, 4, 64, False, 16),
    ("attn", 256, 4, 64, True, 16),
    ("attn", 512, 4, 64, False, 8),
    ("attn", 512, 4, 64, True, 8),
    ("attn", 1024, 4, 64, True, 4),
    ("attn", 2048, 4, 64, True, 2),
    # ISSUE 9: ring-shard geometries. The ring's rectangle kernels resolve
    # knobs at the per-chunk seq (S / 2P) in the run's compute dtype;
    # bf16 is what long-context training keeps KV in on the wire, and the
    # ring's off-diagonal rectangles are *non*-causal.
    ("attn", 512, 4, 64, True, 8, "bfloat16"),
    ("attn", 512, 4, 64, False, 8, "bfloat16"),
    ("attn", 1024, 4, 64, True, 4, "bfloat16"),
    ("decode", 512, 4, 64, True, 4),
    ("paged_decode", 512, 4, 64, True, 4, 64),
)

# Tiny shapes for the CI interpret-mode smoke sweep (seconds, not minutes).
SMOKE_SHAPES: Tuple[Tuple, ...] = (
    ("attn", 128, 2, 32, True, 2),
    ("attn", 128, 2, 32, False, 2),
    ("attn", 128, 2, 32, True, 2, "bfloat16"),
    ("decode", 128, 2, 32, True, 2),
    ("paged_decode", 128, 2, 32, True, 2, 32),
)


def _shape_extras(extras) -> Tuple[Optional[int], str]:
    """Optional trailing shape-tuple fields -> (page_size, dtype).

    Order-free by type: an int is a page size, a str is a dtype name."""
    page, dtype = None, "float32"
    for x in extras:
        if isinstance(x, str):
            dtype = x
        else:
            page = int(x)
    return page, dtype


def _sweep_one(kind_shape, iters, log):
    kind, seq, heads, hd, causal, batch = kind_shape[:6]
    page, dtype = _shape_extras(kind_shape[6:])
    if log:
        log(f"sweep {kind} seq={seq} heads={heads} hd={hd} "
            f"causal={int(causal)} batch={batch} dtype={dtype}"
            + (f" page={page}" if page else ""))
    if kind == "paged_decode":
        return sweep_paged_decode_shape(seq=seq, heads=heads, head_dim=hd,
                                        page_size=page, batch=batch,
                                        dtype=dtype, iters=iters, log=log)
    if kind == "decode":
        return sweep_decode_shape(seq=seq, heads=heads, head_dim=hd,
                                  batch=batch, dtype=dtype, iters=iters,
                                  log=log)
    return sweep_attention_shape(seq=seq, heads=heads, head_dim=hd,
                                 causal=causal, batch=batch, dtype=dtype,
                                 iters=iters, log=log)


def run_sweep(shapes, *, iters: int = 3, backend: Optional[str] = None,
              base: Optional[dict] = None, log=None) -> dict:
    """Sweep ``shapes`` and merge winners into a (copy of) ``base`` doc."""
    import jax

    backend = backend or f"{jax.default_backend()}/interpret"
    doc = new_doc(backend, (base or {}).get("entries"))
    for kind_shape in shapes:
        key, entry = _sweep_one(kind_shape, iters, log)
        doc["entries"][key] = entry
    return doc


def check_cache(shapes, *, path: Optional[str] = None, iters: int = 3,
                tol: float = 0.25, log=print) -> List[str]:
    """Drift check: committed knobs must keep up with a fresh sweep.

    For each shape: the committed cache must hold the exact key, and the
    committed knobs must time within ``tol`` (fractional) of a freshly
    swept winner's knobs in a HEAD-TO-HEAD interleaved run -- the two
    candidates share one timing block, so host drift between "sweep now"
    and "committed then" cannot fail the check (comparing times from
    different timing blocks is the exact bug class this module's timer
    exists to kill). Knob-identity is deliberately not required: near-tied
    candidates may swap places between runs without the cache being
    meaningfully stale. Returns a list of failure strings (empty = pass).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.masks import MaskSpec
    from repro.kernels.ops import (
        flash_attention_pallas,
        flash_decode_paged_pallas,
        flash_decode_pallas,
    )
    from repro.utils.timing import interleaved_timeit

    path = _cache_path(path)
    with open(path) as f:  # strict here, unlike load_cache
        doc = validate_doc(json.load(f))
    failures: List[str] = []
    for kind_shape in shapes:
        kind, seq, heads, hd, causal, batch = kind_shape[:6]
        page, dtype = _shape_extras(kind_shape[6:])
        dt = jnp.dtype(dtype)
        impl = ("flash_pallas" if kind == "attn"
                else f"flash_decode_paged{page}" if kind == "paged_decode"
                else "flash_decode")
        key = cache_key(impl, causal, seq, heads, hd, dt)
        committed = doc["entries"].get(key)
        if committed is None:
            failures.append(f"missing committed entry for {key}")
            continue
        fresh_key, fresh = _sweep_one(kind_shape, iters, log)
        assert fresh_key == key
        knob_names = _knob_spec(impl)
        knobs = {k: v for k, v in committed.items()
                 if k in knob_names and v is not None}
        fresh_knobs = {k: v for k, v in fresh.items()
                       if k in knob_names and v is not None}
        if kind == "paged_decode":
            args = _paged_fixture(seq, heads, hd, batch, page, dt)

            def _mk(kn):
                return jax.jit(
                    lambda q, kp, vp, lens, tbl: flash_decode_paged_pallas(
                        q, kp, vp, lens, tbl, **kn)[0])
        elif kind == "decode":
            kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
            q = jax.random.normal(kq, (batch, 1, heads, hd), jnp.float32).astype(dt)
            kc = jax.random.normal(kk, (batch, seq, heads, hd), jnp.float32).astype(dt)
            vc = jax.random.normal(kv, (batch, seq, heads, hd), jnp.float32).astype(dt)
            args = (q, kc, vc, jnp.full((batch,), seq, jnp.int32))

            def _mk(kn):
                return jax.jit(lambda q, kc, vc, lens: flash_decode_pallas(
                    q, kc, vc, lens, **kn)[0])
        else:
            spec = MaskSpec(causal=causal)
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            args = tuple(jax.random.normal(k_, (batch, seq, heads, hd),
                                           jnp.float32).astype(dt)
                         for k_ in ks)
            # fwd-time check; bwd is staged separately in the sweep
            knobs.pop("bwd", None)
            fresh_knobs.pop("bwd", None)

            def _mk(kn):
                return jax.jit(lambda q, k, v: flash_attention_pallas(
                    q, k, v, spec, use_tuned=False, **kn))

        if knobs == fresh_knobs:
            log(f"check {key}: committed knobs == fresh winner -> ok")
            continue
        best = interleaved_timeit(
            {"committed": _mk(knobs), "fresh": _mk(fresh_knobs)},
            *args, iters=iters,
        )
        t, t_fresh = best["committed"], best["fresh"]
        verdict = "ok" if t <= t_fresh * (1 + tol) else "STALE"
        log(f"check {key}: committed {t*1e6:.0f}us vs fresh winner "
            f"{t_fresh*1e6:.0f}us -> {verdict}")
        if verdict != "ok":
            failures.append(
                f"{key}: committed knobs measure {t*1e6:.0f}us, fresh winner "
                f"{t_fresh*1e6:.0f}us (> {tol:.0%} slower -- re-run "
                f"`python -m repro.kernels.autotune` and commit tuned.json)"
            )
    return failures


def _parse_shapes(text: str):
    shapes = []
    for part in text.split(","):
        fields = part.split(":")
        dtype = None
        if fields and not fields[-1].lstrip("-").isdigit():
            dtype = fields.pop()
        seq, heads, hd, causal, batch = (int(x) for x in fields)
        shape = ("attn", seq, heads, hd, bool(causal), batch)
        shapes.append(shape + ((dtype,) if dtype else ()))
    return shapes


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out", default=None,
                   help=f"cache file to write (default {DEFAULT_PATH})")
    p.add_argument("--smoke", action="store_true",
                   help="sweep only the tiny CI smoke shapes")
    p.add_argument("--check", action="store_true",
                   help="don't write: verify the committed cache against a "
                        "fresh sweep of the selected shapes")
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--tol", type=float, default=0.25)
    p.add_argument("--shapes", default=None,
                   help="seq:heads:hd:causal:batch[:dtype][,...] "
                        "(attention shapes; dtype defaults to float32)")
    args = p.parse_args(argv)
    shapes = (_parse_shapes(args.shapes) if args.shapes
              else SMOKE_SHAPES if args.smoke
              else BENCH_SHAPES + SMOKE_SHAPES)
    log = lambda *a: print(*a, file=sys.stderr)  # noqa: E731
    if args.check:
        failures = check_cache(shapes, path=args.out, iters=args.iters,
                               tol=args.tol, log=log)
        for fail in failures:
            log(f"FAIL: {fail}")
        log(f"--check: {len(shapes) - len(failures)}/{len(shapes)} shapes ok")
        return 1 if failures else 0
    base = load_cache(args.out)
    doc = run_sweep(shapes, iters=args.iters, base=base, log=log)
    path = save_cache(doc, args.out)
    log(f"wrote {path} ({len(doc['entries'])} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
