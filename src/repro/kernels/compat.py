"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat aliases come and go between releases); the kernels only ever
need "the dataclass that accepts dimension_semantics". Resolve it once here
so flash_fwd / flash_bwd / flash_decode are version-agnostic.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # very old jax: dimension_semantics went via a plain dict
    def CompilerParams(**kwargs):  # type: ignore[no-redef]
        return dict(**kwargs)
