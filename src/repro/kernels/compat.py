"""Version + backend shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back-compat aliases come and go between releases); the kernels only ever
need "the dataclass that accepts dimension_semantics". Resolve it once here
so flash_fwd / flash_bwd / flash_decode are version-agnostic.

``resolve_interpret`` is the single place where ``interpret=None`` (the
default everywhere: ops.py, AttentionConfig, kernel entry points) becomes a
concrete bool: interpret off on real TPUs, on everywhere else. Callers that
pass an explicit bool keep full control (tests, benchmarks).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> 'not on a TPU'; an explicit bool passes through unchanged."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:  # very old jax: dimension_semantics went via a plain dict
    def CompilerParams(**kwargs):  # type: ignore[no-redef]
        return dict(**kwargs)
