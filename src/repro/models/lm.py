"""Generic decoder-only LM covering the dense / MoE / SSM / hybrid / VLM
families, with scan-over-layer-groups so HLO size is depth-independent.

A model is: embed (+ optional vision-patch prefix, + optional learnable
meta-token prefix) -> num_groups x layer_pattern (lax.scan, remat) ->
tail layers (unrolled) -> final norm. Heads:
  forward()      hidden states (loss/unembed applied by the caller so the
                 training loss can chunk the vocab dim)
  prefill()      hidden of the last position + per-layer decode caches
  decode_step()  one token in, logits + updated caches

Layer kinds (configs.base.LAYER_KINDS) pick the mixer: FA2 attention
(global or SWA), Mamba, or Hymba hybrid. MoE replaces the MLP when
cfg.moe is set. All masks are MaskSpec-symbolic; meta tokens become a
`sink` prefix for windowed layers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig
from repro.core import masks as masks_mod
from repro.core.masks import MaskSpec
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.attention_layer import (
    apply_attention,
    decode_attention_step,
    init_attention,
    prefill_attention,
)
from repro.models.hybrid import (
    apply_hybrid,
    decode_hybrid_step,
    init_hybrid,
    prefill_hybrid,
)
from repro.models.mamba import apply_mamba, decode_mamba_step, init_mamba
from repro.models.moe import apply_moe, init_moe


# ---------------------------------------------------------------------------
# Per-kind helpers
# ---------------------------------------------------------------------------


def _spec_for(cfg, kind: str) -> MaskSpec:
    window = cfg.kind_window(kind)
    sink = cfg.meta_tokens if (window is not None and cfg.meta_tokens) else 0
    return MaskSpec(causal=True, window=window, sink=sink)


def _theta_for(cfg, kind: str) -> float:
    if kind == "attn_local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _has_mlp(cfg, kind: str) -> bool:
    return kind != "mamba"


def init_layer(kind: str, key, cfg, dtype) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.init_norm(cfg, dtype)}
    if kind in ("attn", "attn_local"):
        p["mixer"] = init_attention(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg, dtype)
    elif kind in ("hybrid", "hybrid_global"):
        p["mixer"] = init_hybrid(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        p["ln2"] = L.init_norm(cfg, dtype)
        if cfg.moe is not None:
            p["mlp"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg, cfg.d_ff, dtype)
    return p


def _apply_mlp_block(p, cfg, x):
    """Second residual sub-block; returns (delta, aux)."""
    h = L.apply_norm(p["ln2"], x, cfg.norm_eps, cfg.norm)
    if cfg.moe is not None:
        return apply_moe(p["mlp"], cfg, h)
    return L.apply_mlp(p["mlp"], h, cfg.mlp), jnp.zeros((), jnp.float32)


def apply_layer(
    kind, p, cfg, x, positions, attn_cfg, segment_ids=None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm)
    spec = _spec_for(cfg, kind)
    if kind in ("attn", "attn_local"):
        mix = apply_attention(
            p["mixer"], cfg, h, positions, spec, attn_cfg,
            rope_theta=_theta_for(cfg, kind), segment_ids=segment_ids,
        )
    elif kind == "mamba":
        if segment_ids is not None:
            raise ValueError("packed (varlen) mode supports attention layers only; "
                             f"got layer kind {kind!r} (SSM state crosses segments)")
        mix = apply_mamba(p["mixer"], cfg, h, remat=cfg.remat)
    else:
        if segment_ids is not None:
            raise ValueError("packed (varlen) mode supports attention layers only; "
                             f"got layer kind {kind!r}")
        mix = apply_hybrid(
            p["mixer"], cfg, h, positions, spec, attn_cfg,
            rope_theta=_theta_for(cfg, kind), remat=cfg.remat,
        )
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if _has_mlp(cfg, kind):
        delta, aux = _apply_mlp_block(p, cfg, x)
        x = x + delta
    return constrain(x, "batch", "seq", "embed"), aux


def prefill_layer(kind, p, cfg, x, positions, attn_cfg, cache_size):
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm)
    spec = _spec_for(cfg, kind)
    if kind in ("attn", "attn_local"):
        mix, cache = prefill_attention(
            p["mixer"], cfg, h, positions, spec, attn_cfg,
            rope_theta=_theta_for(cfg, kind), cache_size=cache_size,
        )
        cache = {"kv": cache}
    elif kind == "mamba":
        mix, ssm = apply_mamba(p["mixer"], cfg, h, remat=cfg.remat, return_state=True)
        cache = {"ssm": ssm}
    else:
        mix, cache = prefill_hybrid(
            p["mixer"], cfg, h, positions, spec, attn_cfg,
            rope_theta=_theta_for(cfg, kind), cache_size=cache_size, remat=cfg.remat,
        )
    x = x + mix
    if _has_mlp(cfg, kind):
        delta, _ = _apply_mlp_block(p, cfg, x)
        x = x + delta
    return constrain(x, "batch", "seq", "embed"), cache


def decode_layer(kind, p, cfg, x, cache, cache_len, attn_cfg, block_table=None):
    h = L.apply_norm(p["ln1"], x, cfg.norm_eps, cfg.norm)
    spec = _spec_for(cfg, kind)
    theta = _theta_for(cfg, kind)
    if kind in ("attn", "attn_local"):
        mix, kv = decode_attention_step(
            p["mixer"], cfg, h, cache["kv"], cache_len, attn_cfg,
            rope_theta=theta, window=spec.window, sink=spec.sink,
            block_table=block_table,
        )
        new_cache = {"kv": kv}
    elif kind == "mamba":
        if block_table is not None:
            raise ValueError("paged decode serves attention layers only "
                             f"(got layer kind {kind!r})")
        mix, ssm = decode_mamba_step(p["mixer"], cfg, h, cache["ssm"])
        new_cache = {"ssm": ssm}
    else:
        if block_table is not None:
            raise ValueError("paged decode serves attention layers only "
                             f"(got layer kind {kind!r})")
        mix, new_cache = decode_hybrid_step(
            p["mixer"], cfg, h, cache, cache_len, attn_cfg,
            rope_theta=theta, window=spec.window, sink=spec.sink,
        )
    x = x + mix
    if _has_mlp(cfg, kind):
        delta, _ = _apply_mlp_block(p, cfg, x)
        x = x + delta
    return x, new_cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------


def init_lm(cfg, key, dtype=None) -> dict:
    cfg.validate()
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(keys[0], cfg, dtype),
        "ln_f": L.init_norm(cfg, dtype),
    }
    if cfg.meta_tokens:
        params["meta"] = L._normal(keys[1], (cfg.meta_tokens, cfg.d_model), 0.02, dtype)

    U, NG = cfg.group_size, cfg.num_groups
    if NG:
        def init_group(gkey):
            gks = jax.random.split(gkey, U)
            return {f"slot_{u}": init_layer(cfg.layer_pattern[u], gks[u], cfg, dtype)
                    for u in range(U)}

        group_keys = jax.random.split(keys[2], NG)
        if cfg.scan_layers and NG > 1:
            params["groups"] = jax.vmap(init_group)(group_keys)
        else:
            params["groups"] = [init_group(k) for k in group_keys]
    tail = cfg.tail_pattern
    if tail:
        tks = jax.random.split(keys[3], len(tail))
        params["tail"] = [init_layer(kind, tks[i], cfg, dtype) for i, kind in enumerate(tail)]
    return params


def _embed_inputs(cfg, params, tokens, patches=None):
    """tokens (B,S) [+ patches (B,P,d)] -> (h, positions, n_prefix)."""
    h = L.embed_tokens(params["embed"], tokens)
    if cfg.embed_scale_by_dim:
        h = (h.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(h.dtype)
    parts = []
    if patches is not None:
        parts.append(patches.astype(h.dtype))
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"][None], (h.shape[0], cfg.meta_tokens, cfg.d_model)
        )
        parts = [meta] + parts
    if parts:
        h = jnp.concatenate(parts + [h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.learned_pos_embed:
        h = h + params["embed"]["positions"][:S][None].astype(h.dtype)
    n_prefix = S - tokens.shape[1]
    return constrain(h, "batch", "seq", "embed"), positions, n_prefix


def _run_groups(cfg, params, h, positions, attn_cfg, segment_ids=None):
    """Scan the grouped layers; returns (h, aux_sum)."""
    U = cfg.group_size
    aux0 = jnp.zeros((), jnp.float32)

    def group_body(carry, gp):
        x, aux = carry
        for u, kind in enumerate(cfg.layer_pattern):
            x, a = apply_layer(
                kind, gp[f"slot_{u}"], cfg, x, positions, attn_cfg, segment_ids
            )
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(group_body, prevent_cse=False) if cfg.remat else group_body
    if cfg.num_groups:
        if cfg.scan_layers and cfg.num_groups > 1:
            (h, aux0), _ = jax.lax.scan(body, (h, aux0), params["groups"])
        else:
            gs = params["groups"]
            for gp in gs:
                (h, aux0), _ = body((h, aux0), gp)
    for i, kind in enumerate(cfg.tail_pattern):
        h, a = apply_layer(kind, params["tail"][i], cfg, h, positions, attn_cfg, segment_ids)
        aux0 = aux0 + a
    return h, aux0


def forward(cfg, params, tokens, attn_cfg: AttentionConfig, patches=None,
            segment_ids=None):
    """-> (hidden (B, S_total, d), aux_loss, n_prefix). Caller unembeds.

    segment_ids (B, S) int32 turns on packed (varlen) training: attention
    stays within segments and RoPE positions restart at each segment start.
    Incompatible with patches/meta-token prefixes (no prefix in packed rows).
    """
    h, positions, n_prefix = _embed_inputs(cfg, params, tokens, patches)
    if segment_ids is not None:
        assert n_prefix == 0, "packed mode does not support prefix tokens"
        assert not cfg.learned_pos_embed, "packed mode needs RoPE positions"
        positions = masks_mod.segment_positions(segment_ids)
    h, aux = _run_groups(cfg, params, h, positions, attn_cfg, segment_ids)
    h = L.apply_norm(params["ln_f"], h, cfg.norm_eps, cfg.norm)
    return h, aux, n_prefix


def logits_from_hidden(cfg, params, hidden):
    return L.unembed(params["embed"], hidden, cfg.tie_embeddings)


# ------------------------------------------------------------------ serving


def prefill(cfg, params, tokens, attn_cfg: AttentionConfig, cache_size: int,
            patches=None, lens=None):
    """-> (hidden_last (B,1,d), caches, lens_total (B,) int32). Caches are
    per-layer trees stacked over groups; cache_size is the padded KV
    capacity.

    ``lens`` (B,) int32 marks the true token count per row when ``tokens``
    is right-padded to a bucket length (serving admission): the returned
    hidden is taken at each row's last *real* position and ``lens_total``
    counts only real tokens (+ any prefix). Causality keeps padding out of
    the real positions' attention, and the caller masks the padded cache
    tail via its per-slot cache length. Not supported for SSM/hybrid
    configs (recurrent state would consume the padding).
    """
    if lens is not None and cfg.ssm is not None:
        raise ValueError("lens-padded prefill needs attention-only configs "
                         "(SSM state crosses the padding)")
    h, positions, n_prefix = _embed_inputs(cfg, params, tokens, patches)

    def group_body(x, gp):
        caches = {}
        for u, kind in enumerate(cfg.layer_pattern):
            x, c = prefill_layer(kind, gp[f"slot_{u}"], cfg, x, positions, attn_cfg, cache_size)
            caches[f"slot_{u}"] = c
        return x, caches

    caches: Dict[str, Any] = {}
    if cfg.num_groups:
        if cfg.scan_layers and cfg.num_groups > 1:
            h, caches["groups"] = jax.lax.scan(group_body, h, params["groups"])
        else:
            caches["groups"] = []
            for gp in params["groups"]:
                h, c = group_body(h, gp)
                caches["groups"].append(c)
    if cfg.tail_pattern:
        caches["tail"] = []
        for i, kind in enumerate(cfg.tail_pattern):
            h, c = prefill_layer(kind, params["tail"][i], cfg, h, positions, attn_cfg, cache_size)
            caches["tail"].append(c)
    h = L.apply_norm(params["ln_f"], h, cfg.norm_eps, cfg.norm)
    B = h.shape[0]
    if lens is None:
        return h[:, -1:], caches, jnp.full((B,), h.shape[1], jnp.int32)
    lens = lens.astype(jnp.int32)
    last = (n_prefix + lens - 1)[:, None, None]  # (B,1,1) last real position
    h_last = jnp.take_along_axis(h, jnp.broadcast_to(last, (B, 1, h.shape[2])), axis=1)
    return h_last, caches, n_prefix + lens


def decode_step(cfg, params, token, caches, cache_len, attn_cfg: AttentionConfig,
                block_table=None):
    """token (B,1) int32; cache_len (B,) valid entries per sequence.
    -> (logits (B,1,V), new_caches).

    ``block_table`` (B, n_pages) int32 switches every attention layer to
    the paged cache path (pool page planes instead of per-slot contiguous
    caches -- see attention_layer.decode_attention_step); the table is
    shared by all layers."""
    h = L.embed_tokens(params["embed"], token)
    if cfg.embed_scale_by_dim:
        h = (h.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(h.dtype)
    if cfg.learned_pos_embed:
        pos_e = jnp.take(params["embed"]["positions"], cache_len, axis=0)[:, None]
        h = h + pos_e.astype(h.dtype)

    def group_body(x, gp_cache):
        gp, cache = gp_cache
        new_caches = {}
        for u, kind in enumerate(cfg.layer_pattern):
            x, nc = decode_layer(
                kind, gp[f"slot_{u}"], cfg, x, cache[f"slot_{u}"], cache_len,
                attn_cfg, block_table,
            )
            new_caches[f"slot_{u}"] = nc
        return x, new_caches

    new_caches: Dict[str, Any] = {}
    if cfg.num_groups:
        if cfg.scan_layers and cfg.num_groups > 1:
            h, new_caches["groups"] = jax.lax.scan(
                group_body, h, (params["groups"], caches["groups"])
            )
        else:
            new_caches["groups"] = []
            for gp, c in zip(params["groups"], caches["groups"]):
                h, nc = group_body(h, (gp, c))
                new_caches["groups"].append(nc)
    if cfg.tail_pattern:
        new_caches["tail"] = []
        for i, kind in enumerate(cfg.tail_pattern):
            h, nc = decode_layer(
                kind, params["tail"][i], cfg, h, caches["tail"][i], cache_len,
                attn_cfg, block_table,
            )
            new_caches["tail"].append(nc)
    h = L.apply_norm(params["ln_f"], h, cfg.norm_eps, cfg.norm)
    logits = logits_from_hidden(cfg, params, h)
    return logits, new_caches
