"""GQA/MQA/MHA attention layer built on the FlashAttention-2 core.

Supports: RoPE (per-layer theta override), qk-norm (qwen3), sliding windows
(mixtral/gemma3/hymba), sink prefixes (hymba meta tokens), cross-attention
(whisper), KV-cache prefill + single-token decode. The attention math itself
is always ``repro.core.attention`` -- the layer never materializes S or P.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import (
    AttentionConfig,
    attention,
    decode_attention,
    decode_attention_paged,
)
from repro.core.masks import MaskSpec
from repro.distributed import sharding as shd
from repro.distributed.context_parallel import gather_kv
from repro.distributed.sharding import constrain
from repro.models.layers import _normal, apply_rope, rms_norm_vec


def _expand_gqa_for_sharding(cfg, k, v):
    """GQA -> MHA expansion when query heads are sharded over 'model'.

    The flash blocked layout groups heads as (Hkv, G); with Hq sharded
    16-way that split is unshardable (16@model -> (8, 2) has no valid
    SPMD mapping) and XLA *replicates the whole attention computation*
    (measured: granite prefill_32k ran ~73% of the global attention FLOPs
    on every chip -- EXPERIMENTS.md Section Perf iteration G1). Expanding
    K/V to one head per query head (the paper's MQA/GQA note: heads are
    "implicitly duplicated", dK/dV summed back by autodiff through the
    broadcast) makes G=1 so the merged (B*Hq) dim shards over
    (data, model). Per chip this *reduces* KV memory: one expanded head
    instead of all kv heads replicated."""
    state = shd.current()
    if state is None:
        return k, v
    _, rules = state
    if rules.table.get("heads") != "model":
        return k, v
    G = cfg.num_heads // cfg.num_kv_heads
    if G == 1:
        return k, v
    B, S, Hk, D = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hk, G, D)).reshape(B, S, Hk * G, D)
    v = jnp.broadcast_to(v[:, :, :, None, :], (B, S, Hk, G, D)).reshape(B, S, Hk * G, D)
    k = constrain(k, "batch", "kv_seq", "heads", None)
    v = constrain(v, "batch", "kv_seq", "heads", None)
    return k, v


def init_attention(key, cfg, dtype, cross: bool = False) -> dict:
    d, qd, kd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "wq": _normal(ks[0], (d, qd), std, dtype),
        "wk": _normal(ks[1], (d, kd), std, dtype),
        "wv": _normal(ks[2], (d, kd), std, dtype),
        "wo": _normal(ks[3], (qd, d), 1.0 / math.sqrt(qd), dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kd,), dtype)
        p["bv"] = jnp.zeros((kd,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def _project_q(p, cfg, x):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm_vec(q, p["q_norm"], cfg.norm_eps)
    return constrain(q, "batch", "seq", "heads", None)


def _project_kv(p, cfg, x):
    B, S, _ = x.shape
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if "k_norm" in p:
        k = rms_norm_vec(k, p["k_norm"], cfg.norm_eps)
    k = constrain(k, "batch", "kv_seq", "kv_heads", None)
    v = constrain(v, "batch", "kv_seq", "kv_heads", None)
    return k, v


def _out(p, cfg, o):
    B, S, _, _ = o.shape
    y = jnp.einsum("bsq,qd->bsd", o.reshape(B, S, cfg.q_dim), p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return constrain(y, "batch", "seq", "embed")


def apply_attention(
    p: dict,
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    spec: MaskSpec,
    attn_cfg: AttentionConfig,
    *,
    rope_theta: Optional[float] = None,
    x_kv: Optional[jnp.ndarray] = None,
    segment_ids: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence attention (train / encoder / cross). x (B,S,d).

    segment_ids (B, S) enables packed varlen training: attention never
    crosses a segment boundary (``packed=True`` mode; the caller supplies
    within-segment RoPE positions). Not combined with context parallelism
    -- packed rows are data-sharded like any other batch row.
    """
    q = _project_q(p, cfg, x)
    k, v = _project_kv(p, cfg, x_kv if x_kv is not None else x)
    if x_kv is None and rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    # Context parallelism (C2 at mesh level): gather KV once per layer; the
    # flash scan then runs sharded Q rows against full KV. No-op when the
    # 'kv_seq' logical axis is unsharded (heads-sharded archs, CPU tests)
    # and for ring-mode self-attention (KV stays sharded and rotates); a
    # cross-attention call always keeps the gather -- the ring only covers
    # Sq == Skv self-attention.
    k, v = gather_kv(k, v, cross=x_kv is not None)
    k, v = _expand_gqa_for_sharding(cfg, k, v)
    o = attention(q, k, v, spec, attn_cfg, segment_ids=segment_ids)
    return _out(p, cfg, o)


def prefill_attention(
    p, cfg, x, positions, spec, attn_cfg, *, rope_theta=None,
    cache_size: Optional[int] = None,
):
    """Like apply_attention but also returns the KV cache (padded to
    cache_size along seq)."""
    q = _project_q(p, cfg, x)
    k, v = _project_kv(p, cfg, x)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    kg, vg = gather_kv(k, v)
    kg, vg = _expand_gqa_for_sharding(cfg, kg, vg)
    o = attention(q, kg, vg, spec, attn_cfg)
    S = k.shape[1]
    if cache_size is not None and cache_size > S:
        pad = cache_size - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = constrain(k, "batch", "cache_seq", "kv_heads", None)
    v = constrain(v, "batch", "cache_seq", "kv_heads", None)
    return _out(p, cfg, o), {"k": k, "v": v}


def decode_attention_step(
    p, cfg, x_new: jnp.ndarray, cache: dict, cache_len: jnp.ndarray,
    attn_cfg: AttentionConfig, *, rope_theta=None, window=None, sink: int = 0,
    block_table: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, dict]:
    """One decode step. x_new (B,1,d); cache_len (B,) = number of valid
    entries BEFORE this token.

    Contiguous cache (``block_table=None``): cache k/v (B,S,Hk,hd), the new
    KV is inserted at position cache_len.

    Paged cache (``block_table`` (B, n_pages) int32): cache k/v are the
    pool's physical page planes (Hk, P, page_size, hd); the new KV scatters
    into page ``table[b, L // ps]`` at offset ``L % ps`` and attention runs
    page-indirect (core.attention.decode_attention_paged). Rows with
    cache_len == 0 are *inactive slots* (a real sequence always has a
    non-empty prompt): their write lands in the reserved null page 0 and
    their attention length is forced to 0, so a free/finished slot costs no
    KV reads at all."""
    B = x_new.shape[0]
    q = _project_q(p, cfg, x_new)
    k_new, v_new = _project_kv(p, cfg, x_new)
    if rope_theta is not None:
        pos = cache_len[:, None]  # (B,1) absolute position of the new token
        q = apply_rope(q, pos, rope_theta)
        k_new = apply_rope(k_new, pos, rope_theta)

    if block_table is not None:
        ps = cache["k"].shape[2]
        page = jnp.take_along_axis(
            block_table, (cache_len // ps)[:, None], axis=1
        )[:, 0]  # (B,) physical page of the write position
        off = cache_len % ps
        def scatter(planes, new):
            vals = new[:, 0].transpose(1, 0, 2)  # (Hk, B, hd)
            return planes.at[:, page, off].set(vals.astype(planes.dtype))
        k_pages = scatter(cache["k"], k_new)
        v_pages = scatter(cache["v"], v_new)
        lengths = jnp.where(cache_len > 0, cache_len + 1, 0)
        o = decode_attention_paged(
            q, k_pages, v_pages, lengths, block_table, attn_cfg,
            window=window, sink=sink,
        )
        return _out(p, cfg, o), {"k": k_pages, "v": v_pages}

    def insert(buf, new):
        def one(b_row, n_row, idx):
            return jax.lax.dynamic_update_slice_in_dim(b_row, n_row, idx, axis=0)
        return jax.vmap(one)(buf, new, cache_len)

    k_cache = insert(cache["k"], k_new)
    v_cache = insert(cache["v"], v_new)
    o = decode_attention(
        q, k_cache, v_cache, cache_len + 1, attn_cfg, window=window, sink=sink
    )
    return _out(p, cfg, o), {"k": k_cache, "v": v_cache}


def cross_attention_step(p, cfg, x_new, enc_cache, enc_len, attn_cfg):
    """Decode-time cross attention: q from x_new, kv precomputed from the
    encoder output (enc_cache = {'k','v'}), enc_len (B,)."""
    q = _project_q(p, cfg, x_new)
    o = decode_attention(q, enc_cache["k"], enc_cache["v"], enc_len, attn_cfg)
    return _out(p, cfg, o)
