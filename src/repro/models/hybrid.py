"""Hymba-style hybrid head block: parallel attention + Mamba on the same
input, outputs fused by per-branch RMSNorm and averaging (arXiv:2411.13676).

The attention half uses the FA2 stack (SWA for 'hybrid' layers, full for
'hybrid_global'); meta tokens are handled at the model level as a learnable
prefix + sink mask. The SSM half is models.mamba. KV/SSM caches for decode
hold both branches' state.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig
from repro.core.masks import MaskSpec
from repro.models.attention_layer import (
    apply_attention,
    decode_attention_step,
    init_attention,
    prefill_attention,
)
from repro.models.layers import rms_norm_vec
from repro.models.mamba import apply_mamba, decode_mamba_step, init_mamba


def init_hybrid(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(k1, cfg, dtype),
        "ssm": init_mamba(k2, cfg, dtype),
        "attn_out_norm": jnp.ones((cfg.d_model,), dtype),
        "ssm_out_norm": jnp.ones((cfg.d_model,), dtype),
    }


def _fuse(p, y_attn, y_ssm, eps):
    return 0.5 * (
        rms_norm_vec(y_attn, p["attn_out_norm"], eps)
        + rms_norm_vec(y_ssm, p["ssm_out_norm"], eps)
    )


def apply_hybrid(
    p, cfg, x, positions, spec: MaskSpec, attn_cfg: AttentionConfig,
    *, rope_theta: float, remat: bool = True,
) -> jnp.ndarray:
    y_a = apply_attention(p["attn"], cfg, x, positions, spec, attn_cfg, rope_theta=rope_theta)
    y_s = apply_mamba(p["ssm"], cfg, x, remat=remat)
    return _fuse(p, y_a, y_s, cfg.norm_eps)


def prefill_hybrid(
    p, cfg, x, positions, spec, attn_cfg, *, rope_theta, cache_size=None, remat=True,
) -> Tuple[jnp.ndarray, dict]:
    y_a, kv = prefill_attention(
        p["attn"], cfg, x, positions, spec, attn_cfg,
        rope_theta=rope_theta, cache_size=cache_size,
    )
    y_s, ssm_state = apply_mamba(p["ssm"], cfg, x, remat=remat, return_state=True)
    return _fuse(p, y_a, y_s, cfg.norm_eps), {"kv": kv, "ssm": ssm_state}


def decode_hybrid_step(
    p, cfg, x_new, cache: dict, cache_len, attn_cfg,
    *, rope_theta, window: Optional[int], sink: int,
) -> Tuple[jnp.ndarray, dict]:
    y_a, kv = decode_attention_step(
        p["attn"], cfg, x_new, cache["kv"], cache_len, attn_cfg,
        rope_theta=rope_theta, window=window, sink=sink,
    )
    y_s, ssm_state = decode_mamba_step(p["ssm"], cfg, x_new, cache["ssm"])
    return _fuse(p, y_a, y_s, cfg.norm_eps), {"kv": kv, "ssm": ssm_state}
