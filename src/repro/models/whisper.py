"""Whisper-style encoder-decoder (whisper-base assignment).

Per the brief, the conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings (B, T_frames, d_model) straight into the
encoder (sinusoidal positions added here). The transformer backbone is
faithful: pre-LN, full bidirectional encoder self-attention, causal decoder
self-attention, encoder-decoder cross-attention, GELU MLPs, LayerNorm,
learned decoder positions, biases on projections.

FA2 applies to all three attention sites; cross-attention exercises the
asymmetric-N (Sq != Skv, non-causal) tiling path of the kernel.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import AttentionConfig
from repro.core.masks import CAUSAL, FULL, MaskSpec
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.attention_layer import (
    _project_kv,
    apply_attention,
    cross_attention_step,
    decode_attention_step,
    init_attention,
    prefill_attention,
)


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(k2, cfg, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "self": init_attention(k1, cfg, dtype),
        "lnx": L.init_norm(cfg, dtype),
        "cross": init_attention(k2, cfg, dtype, cross=True),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(k3, cfg, cfg.d_ff, dtype),
    }


def init_whisper(cfg, key, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.encoder.num_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "encoder": {
            "layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
            "ln_post": L.init_norm(cfg, dtype),
        },
        "decoder": {
            "embed": L.init_embedding(ks[2], cfg, dtype),
            "layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
            "ln_f": L.init_norm(cfg, dtype),
        },
    }


def encode(cfg, params, frames: jnp.ndarray, attn_cfg: AttentionConfig) -> jnp.ndarray:
    """frames (B, T, d_model) -- precomputed frame embeddings (stub frontend)."""
    B, T, d = frames.shape
    h = frames + L.sinusoidal_positions(T, d)[None].astype(frames.dtype)
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, lp):
        y = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm)
        x = x + apply_attention(lp["attn"], cfg, y, positions, FULL, attn_cfg)
        y = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm)
        x = x + L.apply_mlp(lp["mlp"], y, cfg.mlp)
        return constrain(x, "batch", "seq", "embed"), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["encoder"]["layers"])
    return L.apply_norm(params["encoder"]["ln_post"], h, cfg.norm_eps, cfg.norm)


def _dec_embed(cfg, params, tokens, start: int | jnp.ndarray = 0):
    h = L.embed_tokens(params["decoder"]["embed"], tokens)
    S = tokens.shape[1]
    table = params["decoder"]["embed"]["positions"]
    if isinstance(start, int):
        pos_e = table[start : start + S][None]
    else:  # (B,) dynamic decode positions
        pos_e = jnp.take(table, start, axis=0)[:, None]
    return h + pos_e.astype(h.dtype)


def forward(cfg, params, frames, tokens, attn_cfg: AttentionConfig):
    """Teacher-forced training forward -> decoder hidden (B, S, d)."""
    enc = encode(cfg, params, frames, attn_cfg)
    h = _dec_embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(x, lp):
        y = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm)
        x = x + apply_attention(lp["self"], cfg, y, positions, CAUSAL, attn_cfg)
        y = L.apply_norm(lp["lnx"], x, cfg.norm_eps, cfg.norm)
        x = x + apply_attention(lp["cross"], cfg, y, positions, FULL, attn_cfg, x_kv=enc)
        y = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm)
        x = x + L.apply_mlp(lp["mlp"], y, cfg.mlp)
        return constrain(x, "batch", "seq", "embed"), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["decoder"]["layers"])
    h = L.apply_norm(params["decoder"]["ln_f"], h, cfg.norm_eps, cfg.norm)
    return h, jnp.zeros((), jnp.float32), 0


def prefill(cfg, params, frames, tokens, attn_cfg: AttentionConfig, cache_size: int):
    """-> (hidden_last, caches). caches: per-layer self-KV (padded to
    cache_size) + cross-KV over the encoder output."""
    enc = encode(cfg, params, frames, attn_cfg)
    h = _dec_embed(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def body(x, lp):
        y = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm)
        dy, kv = prefill_attention(
            lp["self"], cfg, y, positions, CAUSAL, attn_cfg, cache_size=cache_size
        )
        x = x + dy
        y = L.apply_norm(lp["lnx"], x, cfg.norm_eps, cfg.norm)
        xk, xv = _project_kv(lp["cross"], cfg, enc)  # cross KV cached once
        x = x + apply_attention(lp["cross"], cfg, y, positions, FULL, attn_cfg, x_kv=enc)
        y = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm)
        x = x + L.apply_mlp(lp["mlp"], y, cfg.mlp)
        return x, {"kv": kv, "cross": {"k": xk, "v": xv}}

    h, caches = jax.lax.scan(body, h, params["decoder"]["layers"])
    h = L.apply_norm(params["decoder"]["ln_f"], h, cfg.norm_eps, cfg.norm)
    return h[:, -1:], caches, tokens.shape[1]


def decode_step(cfg, params, token, caches, cache_len, attn_cfg: AttentionConfig):
    """token (B,1); cache_len (B,). -> (logits, new_caches)."""
    B = token.shape[0]
    h = _dec_embed(cfg, params, token, start=cache_len)

    def body(x, lp_cache):
        lp, cache = lp_cache
        y = L.apply_norm(lp["ln1"], x, cfg.norm_eps, cfg.norm)
        dy, kv = decode_attention_step(
            lp["self"], cfg, y, cache["kv"], cache_len, attn_cfg
        )
        x = x + dy
        y = L.apply_norm(lp["lnx"], x, cfg.norm_eps, cfg.norm)
        enc_n = jnp.full((B,), cache["cross"]["k"].shape[1], jnp.int32)
        x = x + cross_attention_step(lp["cross"], cfg, y, cache["cross"], enc_n, attn_cfg)
        y = L.apply_norm(lp["ln2"], x, cfg.norm_eps, cfg.norm)
        x = x + L.apply_mlp(lp["mlp"], y, cfg.mlp)
        return x, {"kv": kv, "cross": cache["cross"]}

    h, new_caches = jax.lax.scan(body, h, (params["decoder"]["layers"], caches))
    h = L.apply_norm(params["decoder"]["ln_f"], h, cfg.norm_eps, cfg.norm)
    logits = L.unembed(params["decoder"]["embed"], h, cfg.tie_embeddings)
    return logits, new_caches
