"""Shared neural-net layers (pure JAX, param trees are plain dicts).

Initializers return {name: array} trees; apply functions are pure. Param
naming is stable -- the sharding rules in distributed/sharding.py match on
path suffixes, and checkpoints key on the same paths.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape) * std).astype(dtype)


# --------------------------------------------------------------------- norms


def init_norm(cfg, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, eps: float, kind: str) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def rms_norm_vec(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm over the last axis with an arbitrary-width scale (qk-norm etc)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- rope


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Half-split (NeoX-style) rotary embedding.

    x: (B, S, H, D); positions: (B, S) or (S,) absolute token positions.
    """
    B, S, H, D = x.shape
    half = D // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------- mlp


def init_mlp(key, cfg, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(d_ff)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": _normal(ks[0], (d, d_ff), std_in, dtype),
            "w_up": _normal(ks[1], (d, d_ff), std_in, dtype),
            "w_down": _normal(ks[2], (d_ff, d), std_out, dtype),
        }
    p = {
        "w_in": _normal(ks[0], (d, d_ff), std_in, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": _normal(ks[1], (d_ff, d), std_out, dtype),
        "b_out": jnp.zeros((d,), dtype),
    }
    return p


def apply_mlp(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    from repro.distributed.sharding import constrain

    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        h = constrain(h, "batch", "seq", "ff_act")
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"]) + p["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, "batch", "seq", "ff_act")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"]) + p["b_out"]


# ----------------------------------------------------------------- embedding


def init_embedding(key, cfg, dtype) -> dict:
    V = cfg.padded_vocab
    p = {"tokens": _normal(key, (V, cfg.d_model), 1.0, dtype)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = _normal(k2, (cfg.d_model, V), 1.0 / math.sqrt(cfg.d_model), dtype)
    if cfg.learned_pos_embed:
        k3 = jax.random.fold_in(key, 2)
        p["positions"] = _normal(k3, (cfg.learned_pos_embed, cfg.d_model), 0.02, dtype)
    return p


def embed_tokens(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tokens"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray, tie: bool) -> jnp.ndarray:
    if tie:
        return jnp.einsum("bsd,vd->bsv", x, p["tokens"])
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"])


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal table (n, d)."""
    half = d // 2
    log_timescale = math.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
