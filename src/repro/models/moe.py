"""Mixture-of-Experts layer (granite 32e/top-8, mixtral 8e/top-2).

Two execution paths, same math:

* ``_moe_local`` -- single-device path (CPU tests, no mesh context):
  TPU-idiomatic sort-based capacity dispatch, all static shapes.

* ``_moe_shard_map`` -- the production expert-parallel path. Activations are
  sharded over `data` and *replicated* over `model`; expert weights are
  sharded over `model` (by expert for granite-32e, by FFN dim for
  mixtral-8e whose expert count doesn't divide the axis). Each chip
  therefore: routes its local tokens (replicated compute, negligible),
  gathers the tokens assigned to *its* experts (local gather -- the
  dispatch "all-to-all" degenerates because tokens are already present),
  runs its expert FFN slice, scatter-adds its partial outputs locally, and
  contributes them to one bf16 ``psum`` over `model` -- the only collective
  in the layer, the same activation-sized all-reduce Megatron TP pays.
  This replaced a naive pjit scatter that XLA replicated (241 GB/device of
  all-reduce in the dry run -- see EXPERIMENTS.md Section Perf).

Capacity: per data-shard, C = ceil(T_local * k / E * capacity_factor);
overflow tokens are dropped (standard GShard-style token dropping).
Aux loss: switch load-balancing loss, computed on the pjit level.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.layers import _normal


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, de, E = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _normal(ks[0], (d, E), 1.0 / math.sqrt(d), jnp.float32),
        "we_gate": _normal(ks[1], (E, d, de), 1.0 / math.sqrt(d), dtype),
        "we_up": _normal(ks[2], (E, d, de), 1.0 / math.sqrt(d), dtype),
        "we_down": _normal(ks[3], (E, de, d), 1.0 / math.sqrt(de), dtype),
    }


def _route(router, xf):
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    return logits


def _capacity(m, T: int) -> int:
    cap = int(math.ceil(T * m.top_k / m.num_experts * m.capacity_factor))
    return max(4, -(-cap // 4) * 4)


def _dispatch_indices(flat_e, E_total: int, e_lo: int, E_local: int, cap: int, k: int):
    """Sorted-dispatch bookkeeping for experts [e_lo, e_lo+E_local).

    Returns (token_of, dest, keep) over the sorted assignment slots, where
    dest indexes a (E_local * cap) group buffer (OOB == dropped/foreign).
    """
    n = flat_e.shape[0]
    local_e = flat_e - e_lo
    mine = (local_e >= 0) & (local_e < E_local)
    sort_key = jnp.where(mine, local_e, E_local)
    order = jnp.argsort(sort_key, stable=True)
    sorted_e = sort_key[order]
    counts = jnp.bincount(sort_key, length=E_local + 1)
    offsets = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(n, dtype=jnp.int32) - offsets[sorted_e]
    keep = (sorted_e < E_local) & (pos_in_e < cap)
    token_of = order // k
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, E_local * cap)
    return token_of, dest, keep, order


def _expert_ffn(x_groups, wg, wu, wd, act_dtype):
    g = jnp.einsum("ecd,edf->ecf", x_groups, wg)
    u = jnp.einsum("ecd,edf->ecf", x_groups, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(act_dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_body(xf, router, wg, wu, wd, m, e_lo, cap, k):
    """Shared per-shard MoE computation. xf (T, d) local tokens; expert
    weights are this shard's slice. Returns local partial y (T, d)."""
    T, d = xf.shape
    E_local = wg.shape[0]
    logits = _route(router, xf)
    top_logit, top_e = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_logit, axis=-1)
    flat_e = top_e.reshape(-1).astype(jnp.int32)
    token_of, dest, keep, order = _dispatch_indices(
        flat_e, m.num_experts, e_lo, E_local, cap, k
    )
    # dispatch: int scatter to build the slot->token map, then GATHER tokens
    token_at = (
        jnp.zeros((E_local * cap,), jnp.int32).at[dest].set(token_of, mode="drop")
    )
    slot_used = (
        jnp.zeros((E_local * cap,), jnp.bool_).at[dest].set(keep, mode="drop")
    )
    x_groups = xf[token_at] * slot_used[:, None].astype(xf.dtype)
    y_groups = _expert_ffn(x_groups.reshape(E_local, cap, d), wg, wu, wd, xf.dtype)
    # combine: local scatter-add weighted by gates
    y_slots = y_groups.reshape(E_local * cap, d)[jnp.minimum(dest, E_local * cap - 1)]
    w = jnp.where(keep, gates.reshape(-1)[order], 0.0).astype(jnp.float32)
    y = (
        jnp.zeros((T, d), jnp.float32)
        .at[token_of]
        .add(y_slots.astype(jnp.float32) * w[:, None], mode="drop")
    )
    return y.astype(xf.dtype)


def _aux_loss(m, logits, top_e):
    probs = jax.nn.softmax(logits, axis=-1)
    E = m.num_experts
    f = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(axis=-2), axis=0
    ) / m.top_k
    p = jnp.mean(probs, axis=0)
    return m.router_aux_weight * E * jnp.sum(f * p)


def apply_moe(p: dict, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (y, aux_loss). Picks the expert-parallel shard_map path
    when a mesh context is installed and the model axis is >1."""
    m = cfg.moe
    B, S, d = x.shape
    state = shd.current()
    use_shard_map = False
    if state is not None:
        mesh, rules = state
        model_ax = "model"
        if model_ax in mesh.shape and mesh.shape[model_ax] > 1:
            use_shard_map = True

    # aux loss on the pjit level (local elementwise; batch stays sharded)
    xf_flat = x.reshape(B * S, d)
    logits = _route(p["router"], xf_flat)
    _, top_e = jax.lax.top_k(logits, m.top_k)
    aux = _aux_loss(m, logits, top_e)

    if not use_shard_map:
        T = B * S
        y = _moe_body(
            xf_flat, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            m, 0, _capacity(m, T), m.top_k,
        )
        return y.reshape(B, S, d), aux

    mesh, rules = state
    P = jax.sharding.PartitionSpec
    batch_ax = rules.table.get("batch")
    experts_sharded = rules.table.get("p_experts") == "model"
    w_spec = P("model", None, None) if experts_sharded else P(None, None, "model")
    wd_spec = P("model", None, None) if experts_sharded else P(None, "model", None)
    x_spec = P(batch_ax, None, None)
    n_data = math.prod(
        mesh.shape[a] for a in (batch_ax if isinstance(batch_ax, tuple) else (batch_ax,))
        if a is not None
    ) if batch_ax else 1
    T_local = (B // max(n_data, 1)) * S
    cap = _capacity(m, T_local)
    n_model = mesh.shape["model"]
    E_local = m.num_experts // n_model if experts_sharded else m.num_experts

    def shard_body(x_blk, router, wg, wu, wd):
        Bl, Sl, _ = x_blk.shape
        xf = x_blk.reshape(Bl * Sl, d)
        e_lo = jax.lax.axis_index("model") * E_local if experts_sharded else 0
        y = _moe_body(xf, router, wg, wu, wd, m, e_lo, cap, m.top_k)
        # the only collective: combine partial expert outputs (bf16)
        y = jax.lax.psum(y.astype(jnp.bfloat16), "model")
        return y.reshape(Bl, Sl, d).astype(x_blk.dtype)

    # shd.shard_map: version-portable (jax.shard_map only exists on newer
    # jax; 0.4.x ships jax.experimental.shard_map) with replication checks
    # off -- the in-body psum is invisible to the checker.
    y = shd.shard_map(
        shard_body,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, wd_spec),
        out_specs=x_spec,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    return y, aux
