"""Mamba-1 selective SSM block (falcon-mamba-7b; also the SSM half of Hymba).

TPU adaptation: the CUDA selective-scan kernel keeps h in SRAM over a
sequential time loop. The JAX/TPU-native equivalent is a *chunked
associative scan*: an outer ``lax.scan`` over time-chunks carries the
(B, d_inner, d_state) state in registers/VMEM, and within a chunk the
linear recurrence h_t = a_t h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` (log-depth, VPU-friendly). The (chunk, d_inner,
d_state) tensors exist only inside the (remat'ed) chunk body, so memory
stays O(S/chunk * d_inner * d_state) for the saved carries -- linear in S,
analogous to FlashAttention's O(N) residual memory.

FA2 applicability note (DESIGN.md Section 4): this block is attention-free;
the paper's technique does not apply here and the arch runs without it.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import _normal, rms_norm_vec


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return d_in, dt_rank, s.d_state, s.d_conv


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": _normal(ks[0], (d, 2 * d_in), 1.0 / math.sqrt(d), dtype),
        "conv_w": _normal(ks[1], (d_conv, d_in), 1.0 / math.sqrt(d_conv), dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _normal(ks[2], (d_in, dt_rank + 2 * d_state), 1.0 / math.sqrt(d_in), dtype),
        "dt_w": _normal(ks[3], (dt_rank, d_in), 1.0 / math.sqrt(dt_rank), dtype),
        "dt_bias": jnp.full((d_in,), math.log(math.expm1(0.01)), dtype),  # softplus^-1(0.01)
        # S4D-real init: A = -(1..d_state) per channel
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)), (d_in, d_state)
        ).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _normal(ks[4], (d_in, d), 1.0 / math.sqrt(d_in), dtype),
    }
    if cfg.ssm.bcdt_norm:  # falcon-mamba stability norms
        p["dt_norm"] = jnp.ones((dt_rank,), dtype)
        p["b_norm"] = jnp.ones((d_state,), dtype)
        p["c_norm"] = jnp.ones((d_state,), dtype)
    return p


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv along seq. x (B,S,din); w (W,din).

    state: (B, W-1, din) tail of the previous segment (decode), else zeros.
    """
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(W))
    return y + b


def _ssm_inputs(p, cfg, x_conv):
    """Project conv output to (dt, B, C) with optional falcon norms."""
    d_in, dt_rank, d_state, _ = _dims(cfg)
    dbc = jnp.einsum("bsi,ir->bsr", x_conv, p["x_proj"])
    dt_low = dbc[..., :dt_rank]
    B_ = dbc[..., dt_rank : dt_rank + d_state]
    C_ = dbc[..., dt_rank + d_state :]
    if "dt_norm" in p:
        dt_low = rms_norm_vec(dt_low, p["dt_norm"], cfg.norm_eps)
        B_ = rms_norm_vec(B_, p["b_norm"], cfg.norm_eps)
        C_ = rms_norm_vec(C_, p["c_norm"], cfg.norm_eps)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_low, p["dt_w"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,d_in) fp32
    return dt, B_.astype(jnp.float32), C_.astype(jnp.float32)


def _chunk_scan(dt, B_, C_, x_conv, A, h0, *, remat: bool):
    """Linear recurrence over one layer. dt (B,S,din) fp32; returns (y, h_last)."""
    Bsz, S, d_in = dt.shape
    d_state = A.shape[-1]

    def chunk_body(h, xs):
        dt_c, B_c, C_c, u_c = xs  # (B, c, ...)
        a = jnp.exp(dt_c[..., None] * A)  # (B,c,din,state)
        bx = dt_c[..., None] * B_c[:, :, None, :] * u_c[..., None].astype(jnp.float32)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        cum_a, cum_b = jax.lax.associative_scan(op, (a, bx), axis=1)
        hs = cum_a * h[:, None] + cum_b  # (B,c,din,state)
        y_c = jnp.einsum("bcis,bcs->bci", hs, C_c)
        return hs[:, -1], y_c

    body = jax.checkpoint(chunk_body) if remat else chunk_body
    chunk = min(128, S)
    n = S // chunk if S % chunk == 0 else 1
    chunk = S // n

    def split(t):
        return t.reshape(Bsz, n, chunk, *t.shape[2:]).swapaxes(0, 1)

    h_last, ys = jax.lax.scan(body, h0, (split(dt), split(B_), split(C_), split(x_conv)))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, d_in)
    return y, h_last


def apply_mamba(
    p: dict, cfg, x: jnp.ndarray, *, remat: bool = True,
    init_state: Optional[dict] = None, return_state: bool = False,
):
    """Full-sequence Mamba block. x (B,S,d) -> y (B,S,d) [+ state dict]."""
    Bsz, S, _ = x.shape
    d_in, _, d_state, d_conv = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", "ssm_seq", "inner")
    conv_state = None if init_state is None else init_state["conv"]
    x_conv = jax.nn.silu(
        _conv_causal(x_in, p["conv_w"], p["conv_b"], conv_state).astype(jnp.float32)
    ).astype(x.dtype)
    dt, B_, C_ = _ssm_inputs(p, cfg, x_conv)
    A = -jnp.exp(p["A_log"])  # (din, state) fp32
    h0 = (
        jnp.zeros((Bsz, d_in, d_state), jnp.float32)
        if init_state is None
        else init_state["h"]
    )
    y, h_last = _chunk_scan(dt, B_, C_, x_conv, A, h0, remat=remat)
    y = (y + p["D"] * x_conv.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    out = constrain(out, "batch", "ssm_seq", "embed")
    if return_state:
        state = {"h": h_last, "conv": x_in[:, S - (d_conv - 1):, :]}
        return out, state
    return out


def decode_mamba_step(p: dict, cfg, x_new: jnp.ndarray, state: dict) -> Tuple[jnp.ndarray, dict]:
    """Single-token step. x_new (B,1,d); state {'h': (B,din,state),
    'conv': (B, d_conv-1, din)}."""
    d_in, _, d_state, d_conv = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x_new, p["in_proj"])
    x_in, z = jnp.split(xz, 2, axis=-1)  # (B,1,din)
    conv_in = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
    x_conv = jax.nn.silu(
        (jnp.einsum("bwi,wi->bi", conv_in, p["conv_w"]) + p["conv_b"]).astype(jnp.float32)
    )[:, None, :].astype(x_new.dtype)  # (B,1,din)
    dt, B_, C_ = _ssm_inputs(p, cfg, x_conv)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)  # (B,din,state)
    bx = dt[:, 0, :, None] * B_[:, 0, None, :] * x_conv[:, 0, :, None].astype(jnp.float32)
    h = a * state["h"] + bx
    y = jnp.einsum("bis,bs->bi", h, C_[:, 0]) + p["D"] * x_conv[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x_new.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x_new.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_state = {"h": h, "conv": conv_in[:, 1:, :]}
    return out, new_state
