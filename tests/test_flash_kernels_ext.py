"""Extended sweep: paths added/rewritten in the perf pass.

Covers the dense *unblocked* backward (context-parallel formulation), the
interior/boundary split scans, MQA (Hk=1), asymmetric cross-attention
(whisper shapes) incl. gradients, short-query chunks, and bf16 backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import flash_attention as flash_xla
from repro.core.masks import MaskSpec
from repro.kernels.ops import flash_attention_pallas
from repro.kernels.ref import attention_reference

KEY = jax.random.PRNGKey(7)


def _mk(B, Sq, Sk, Hq, Hk, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
        jax.random.normal(ks[1], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[2], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[3], (B, Sq, Hq, D), dtype),
    )


def _grads_match(f, g, args, atol=1e-3, rtol=1e-3):
    for a, b in zip(jax.grad(f, (0, 1, 2))(*args), jax.grad(g, (0, 1, 2))(*args)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol, rtol=rtol)


@pytest.mark.parametrize("mode", ["dense", "packed"])
@pytest.mark.parametrize("spec", [
    MaskSpec(causal=True),
    pytest.param(MaskSpec(), marks=pytest.mark.slow),
    MaskSpec(causal=True, window=48),
], ids=["causal", "full", "window"])
def test_xla_bwd_both_modes(mode, spec):
    """The dense backward is the unblocked context-parallel formulation;
    packed is the two-scan blocked one. Both must equal the oracle."""
    q, k, v, do = _mk(2, 160, 160, 4, 2, 32)
    f = lambda q, k, v: (flash_xla(q, k, v, spec, block_q=64, block_kv=64,
                                   mode=mode) * do).sum()
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
    _grads_match(f, g, (q, k, v))


@pytest.mark.slow
def test_mqa_extreme():
    """Hk=1 (whisper-style MQA limit of GQA)."""
    q, k, v, do = _mk(2, 128, 128, 8, 1, 32)
    spec = MaskSpec(causal=True)
    o_ref = attention_reference(q, k, v, spec)[0]
    o = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)
    f = lambda q, k, v: (flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64) * do).sum()
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
    _grads_match(f, g, (q, k, v))


@pytest.mark.slow
def test_cross_attention_asymmetric_grads():
    """Whisper decoder cross-attn: Nq != Nkv, non-causal, with grads
    through both the XLA and Pallas paths."""
    q, k, v, do = _mk(1, 96, 224, 4, 4, 32)
    spec = MaskSpec()  # trivial mask
    for impl in ("xla", "pallas"):
        fn = flash_xla if impl == "xla" else flash_attention_pallas
        f = lambda q, k, v: (fn(q, k, v, spec, block_q=32, block_kv=64) * do).sum()
        g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
        _grads_match(f, g, (q, k, v))


def test_short_query_long_kv():
    """Chunked-decode shape: Sq=8 against Sk=256 at offset (like speculative
    or chunked serving steps)."""
    q, k, v, _ = _mk(2, 8, 256, 4, 2, 64)
    spec = MaskSpec(causal=True, q_offset=248)
    o_ref = attention_reference(q, k, v, spec)[0]
    o_x = flash_xla(q, k, v, spec, block_q=8, block_kv=64)
    np.testing.assert_allclose(o_x, o_ref, atol=3e-5, rtol=1e-4)


@pytest.mark.slow
def test_bf16_backward():
    q, k, v, do = _mk(1, 128, 128, 2, 2, 64, jnp.bfloat16)
    spec = MaskSpec(causal=True)
    f = lambda q, k, v: (flash_xla(q, k, v, spec, block_q=64, block_kv=64)
                         .astype(jnp.float32) * do.astype(jnp.float32)).sum()
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0]
                         .astype(jnp.float32) * do.astype(jnp.float32)).sum()
    _grads_match(f, g, (q, k, v), atol=6e-2, rtol=6e-2)


def test_interior_boundary_split_matches_single_scan():
    """The §3.1-pt-2 split must be numerically indistinguishable from the
    oracle even when every tile is boundary (tiny window) or interior
    (trivial mask)."""
    q, k, v, _ = _mk(1, 128, 128, 2, 2, 32)
    for spec in (MaskSpec(causal=True, window=8),  # all tiles boundary
                 MaskSpec()):                      # all tiles interior
        o_ref = attention_reference(q, k, v, spec)[0]
        o = flash_xla(q, k, v, spec, block_q=32, block_kv=32, mode="packed")
        np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)


def test_window_larger_than_seq():
    """Degenerate window >= seq must reduce to plain causal."""
    q, k, v, _ = _mk(1, 64, 64, 2, 2, 32)
    o_w = flash_xla(q, k, v, MaskSpec(causal=True, window=1024), block_q=32, block_kv=32)
    o_c = flash_xla(q, k, v, MaskSpec(causal=True), block_q=32, block_kv=32)
    np.testing.assert_allclose(o_w, o_c, atol=1e-6, rtol=1e-6)
