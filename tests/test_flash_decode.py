"""Split-KV decode (XLA + Pallas) vs oracle: ragged cache lengths, windows,
sinks, split-count invariance (the associative-combine property C2 relies
on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decode import flash_decode
from repro.core.masks import MaskSpec
from repro.kernels.ops import flash_decode_pallas
from repro.kernels.ref import attention_reference

KEY = jax.random.PRNGKey(2)
B, S, Hq, Hk, D = 3, 256, 8, 2, 64


@pytest.fixture(scope="module")
def data():
    ks = jax.random.split(KEY, 3)
    kc = jax.random.normal(ks[0], (B, S, Hk, D))
    vc = jax.random.normal(ks[1], (B, S, Hk, D))
    q = jax.random.normal(ks[2], (B, 1, Hq, D))
    lens = jnp.array([256, 100, 37], jnp.int32)
    return q, kc, vc, lens


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize(
    "splits", [1, pytest.param(4, marks=pytest.mark.slow), 8, 16]
)
def test_decode_matches_ref(data, impl, splits):
    q, kc, vc, lens = data
    fn = flash_decode if impl == "xla" else flash_decode_pallas
    o, lse = fn(q, kc, vc, lens, num_splits=splits)
    for b in range(B):
        L = int(lens[b])
        o_ref, lse_ref = attention_reference(q[b : b + 1], kc[b : b + 1, :L], vc[b : b + 1, :L], MaskSpec())
        np.testing.assert_allclose(o[b : b + 1], o_ref, atol=5e-6, rtol=1e-5)
        np.testing.assert_allclose(lse[b : b + 1], lse_ref[..., :1].transpose(0, 1, 2), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_decode_window_and_sink(data, impl):
    q, kc, vc, lens = data
    fn = flash_decode if impl == "xla" else flash_decode_pallas
    o, _ = fn(q, kc, vc, lens, window=64, sink=16, num_splits=8)
    for b in range(B):
        L = int(lens[b])
        idx = np.concatenate([np.arange(min(16, L)), np.arange(max(16, L - 64), L)])
        idx = np.unique(idx)
        o_ref, _ = attention_reference(q[b : b + 1], kc[b : b + 1, idx], vc[b : b + 1, idx], MaskSpec())
        np.testing.assert_allclose(o[b : b + 1], o_ref, atol=5e-6, rtol=1e-5)


def test_prime_cache_length_keeps_splits():
    """ISSUE 5 satellite: a prime-length KV cache must NOT silently degrade
    to one split (the old `while S % ns: ns -= 1` resolution did). Ceil-div
    chunks + the masked tail keep the partial merge exact."""
    import math

    from repro.kernels import flash_decode as FD
    from repro.kernels.ops import _heads_layout

    Bp, Sp = 2, 97  # prime cache length
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    kc = jax.random.normal(ks[0], (Bp, Sp, Hk, D))
    vc = jax.random.normal(ks[1], (Bp, Sp, Hk, D))
    q = jax.random.normal(ks[2], (Bp, 1, Hq, D))
    lens = jnp.array([97, 61], jnp.int32)

    # kernel layer: the split axis survives the prime length
    qh = (q.astype(jnp.float32) / math.sqrt(D)).astype(q.dtype)
    qh = qh.reshape(Bp, Hk, Hq // Hk, D).reshape(Bp * Hk, Hq // Hk, D)
    o_parts, _ = FD.flash_decode_kernel(
        qh, _heads_layout(kc), _heads_layout(vc), jnp.repeat(lens, Hk),
        num_splits=8,
    )
    assert o_parts.shape[1] > 1, "prime cache length degraded to 1 split"

    o, _ = flash_decode_pallas(q, kc, vc, lens, num_splits=8)
    for b in range(Bp):
        L = int(lens[b])
        o_ref, _ = attention_reference(
            q[b : b + 1], kc[b : b + 1, :L], vc[b : b + 1, :L], MaskSpec()
        )
        np.testing.assert_allclose(o[b : b + 1], o_ref, atol=5e-6, rtol=1e-5)


def test_split_invariance(data):
    """The split-KV merge is exact for ANY split count (associativity)."""
    q, kc, vc, lens = data
    outs = [flash_decode(q, kc, vc, lens, num_splits=n)[0] for n in (1, 2, 4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)
