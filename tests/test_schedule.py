"""Compact-grid tile scheduling (kernels/schedule.py): parity, accounting,
and the lane-major lse layout contract.

Three claims (ISSUE 2 / DESIGN.md Section 2):
  (a) the compact schedule is *semantics-free*: outputs and grads match the
      dense schedule and the ref.py oracle across specs x GQA x dtypes,
      including packed varlen;
  (b) the built schedule is exactly the ``_visible_pairs`` accounting -- in
      particular the causal step count is triangular, not t_q * t_kv;
  (c) the lane-major lse is a faithful logsumexp: split-KV pieces recombine
      through ``combine_lse_outputs`` to the unsplit result.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import _visible_pairs
from repro.core.masks import MaskSpec
from repro.core.online_softmax import combine_lse_outputs
from repro.kernels.ops import (
    flash_attention_pallas,
    flash_attention_pallas_varlen,
    flash_attention_pallas_with_lse,
    flash_attention_pallas_varlen_with_lse,
)
from repro.kernels.ref import attention_reference
from repro.kernels.schedule import (
    STEP_ACTIVE,
    STEP_FIRST,
    STEP_LAST,
    build_tile_schedule,
    segment_step_tables,
)

KEY = jax.random.PRNGKey(7)

SPECS = {
    "causal": MaskSpec(causal=True),
    "window": MaskSpec(causal=True, window=64),
    "sink": MaskSpec(causal=True, window=64, sink=16),
    "full": MaskSpec(),
}


def _mk(B, Sq, Sk, Hq, Hk, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
        jax.random.normal(ks[1], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[2], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[3], (B, Sq, Hq, D), dtype),
    )


def _mk_segments(B, S, seed=0):
    rng = np.random.default_rng(seed)
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(8, S - 8), 2, replace=False))
        seg[b, : cuts[0]] = 1
        seg[b, cuts[0] : cuts[1]] = 2
        seg[b, cuts[1] :] = 3 if b % 2 == 0 else 0
    return jnp.asarray(seg)


# ---------------------------------------------------------------------------
# (a) compact == dense == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
def test_compact_matches_dense_and_ref(spec_name):
    spec = SPECS[spec_name]
    B, Sq, Sk, Hq, Hk, D = 2, 192, 192, 4, 2, 32  # GQA group 2
    q, k, v, do = _mk(B, Sq, Sk, Hq, Hk, D)
    o_ref, _ = attention_reference(q, k, v, spec)

    def grads(schedule):
        f = lambda q, k, v: (
            flash_attention_pallas(
                q, k, v, spec, block_q=64, block_kv=64, schedule=schedule
            ) * do
        ).sum()
        return jax.grad(f, (0, 1, 2))(q, k, v)

    o_c = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    o_d = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64, schedule="dense")
    np.testing.assert_allclose(o_c, o_ref, atol=2e-3, rtol=1e-4)
    # compact vs dense run the same tile updates in the same order:
    np.testing.assert_allclose(o_c, o_d, atol=1e-6, rtol=1e-6)

    g_ref = jax.grad(
        lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum(), (0, 1, 2)
    )(q, k, v)
    for a, d, r in zip(grads("compact"), grads("dense"), g_ref):
        np.testing.assert_allclose(a, d, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(a, r, atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("spec_name", ["causal", pytest.param("full", marks=pytest.mark.slow)])
def test_compact_varlen_matches_dense_and_ref(spec_name):
    spec = SPECS[spec_name]
    B, S, Hq, Hk, D = 2, 192, 4, 2, 32
    q, k, v, do = _mk(B, S, S, Hq, Hk, D)
    seg = _mk_segments(B, S)
    o_ref, lse_ref = attention_reference(q, k, v, spec, segment_ids=seg)

    outs = {}
    for schedule in ("compact", "dense"):
        o, lse = flash_attention_pallas_varlen_with_lse(
            q, k, v, seg, spec, block_q=64, block_kv=64, schedule=schedule
        )
        f = lambda q, k, v: (
            flash_attention_pallas_varlen(
                q, k, v, seg, spec, block_q=64, block_kv=64, schedule=schedule
            ) * do
        ).sum()
        outs[schedule] = (o, lse, jax.grad(f, (0, 1, 2))(q, k, v))
    o_c, lse_c, g_c = outs["compact"]
    o_d, lse_d, g_d = outs["dense"]
    np.testing.assert_allclose(o_c, o_d, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(o_c, o_ref, atol=2e-3, rtol=1e-4)
    m = ~np.isneginf(np.asarray(lse_ref))
    np.testing.assert_allclose(
        np.asarray(lse_c)[m], np.asarray(lse_ref)[m], atol=1e-4, rtol=1e-5
    )
    g_ref = jax.grad(
        lambda q, k, v: (attention_reference(q, k, v, spec, segment_ids=seg)[0] * do).sum(),
        (0, 1, 2),
    )(q, k, v)
    for a, d, r in zip(g_c, g_d, g_ref):
        np.testing.assert_allclose(a, d, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(a, r, atol=2e-3, rtol=1e-3)


def test_compact_bf16():
    spec = MaskSpec(causal=True)
    q, k, v, _ = _mk(2, 128, 128, 4, 2, 64, jnp.bfloat16)
    o_ref, _ = attention_reference(q, k, v, spec)
    o = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=2e-2, rtol=2e-2
    )


def test_compact_nondivisible_padding():
    """Sq=Sk=200 with 64-blocks: KV padding tiles must stay masked."""
    spec = MaskSpec(causal=True)
    q, k, v, _ = _mk(1, 200, 200, 2, 1, 32)
    o_ref, _ = attention_reference(q, k, v, spec)
    o = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# (b) schedule accounting == _visible_pairs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
@pytest.mark.parametrize("kv_major", [False, True])
def test_schedule_matches_visible_pairs(spec_name, kv_major):
    spec = SPECS[spec_name]
    t_q = t_kv = 16
    bq = bk = 128
    sched = build_tile_schedule(spec, t_q, t_kv, bq, bk, t_kv * bk, kv_major=kv_major)
    ii, jj = _visible_pairs(spec, t_q, t_kv, bq, bk)
    assert sched.n_active == len(ii)
    # the active (i, j) set is identical to the oracle's
    act = sched.flags & STEP_ACTIVE != 0
    got_i = sched.inner[act] if kv_major else sched.outer[act]
    got_j = sched.outer[act] if kv_major else sched.inner[act]
    assert set(zip(got_i.tolist(), got_j.tolist())) == set(zip(ii.tolist(), jj.tolist()))
    # every outer tile inits exactly once and emits exactly once
    n_outer = t_kv if kv_major else t_q
    assert (sched.flags & STEP_FIRST != 0).sum() == n_outer
    assert (sched.flags & STEP_LAST != 0).sum() == n_outer


def test_causal_step_count_bound():
    """Acceptance: causal S=2048 fwd executes <= t*(t+1)/2 + t KV steps."""
    t = 16  # S=2048 at block 128
    sched = build_tile_schedule(MaskSpec(causal=True), t, t, 128, 128, t * 128)
    assert sched.n_steps <= t * (t + 1) // 2 + t, sched.n_steps
    assert sched.n_active == t * (t + 1) // 2  # exactly triangular
    # dense grid would execute t*t steps; the compact grid must not.
    assert sched.n_steps < t * t


def test_window_step_count_drops():
    """Sliding window drops O(S/W)x of the steps, not just the matmuls."""
    t, b = 16, 128
    full = build_tile_schedule(MaskSpec(causal=True), t, t, b, b, t * b)
    win = build_tile_schedule(MaskSpec(causal=True, window=b), t, t, b, b, t * b)
    assert win.n_steps < full.n_steps / 3
    assert win.n_active == len(
        _visible_pairs(MaskSpec(causal=True, window=b), t, t, b, b)[0]
    )


def test_segment_tables_match_kernel_accounting():
    """The prefetched per-(batch, step) table drops exactly the tiles the
    _visible_pairs(segments=...) oracle drops (contiguous packing)."""
    from repro.kernels.schedule import SEG_ACTIVE

    B, S, bq, bk = 1, 256, 64, 64
    t = S // bq
    seg = _mk_segments(B, S, seed=3)
    spec = MaskSpec(causal=True)
    sched = build_tile_schedule(spec, t, t, bq, bk, S)
    table = np.asarray(segment_step_tables(seg, seg, sched, bq, bk))
    both_active = (sched.flags & STEP_ACTIVE != 0) & (table[0] & SEG_ACTIVE != 0)
    segs_np = np.asarray(seg[0])
    ii, jj = _visible_pairs(spec, t, t, bq, bk, segments=segs_np)
    assert both_active.sum() == len(ii)
    got = set(zip(sched.outer[both_active].tolist(), sched.inner[both_active].tolist()))
    assert got == set(zip(ii.tolist(), jj.tolist()))


# ---------------------------------------------------------------------------
# (c) lane-major lse round-trips through the split merge
# ---------------------------------------------------------------------------


def test_lse_roundtrips_through_split_merge():
    """Attention over [KV0 | KV1] == combine of per-half (o, lse) -- the
    contract decode's split merge relies on, fed by the kernel's lane-major
    lse (B, Hq, Sq)."""
    B, S, H, D = 2, 128, 2, 32
    q, k, v, _ = _mk(B, S, S, H, H, D)
    spec = MaskSpec()  # decode halves see disjoint KV: non-causal per piece
    o_full, lse_full = flash_attention_pallas_with_lse(q, k, v, spec, block_q=64, block_kv=64)
    half = S // 2
    o0, lse0 = flash_attention_pallas_with_lse(q, k[:, :half], v[:, :half], spec, block_q=64, block_kv=64)
    o1, lse1 = flash_attention_pallas_with_lse(q, k[:, half:], v[:, half:], spec, block_q=64, block_kv=64)
    # combine wants (..., rows, d) with heads leading: (B, Hq, Sq, D)
    to_rows = lambda o: jnp.moveaxis(o, 1, 2)  # (B, Hq, Sq, D)
    o_c, lse_c = combine_lse_outputs(
        jnp.stack([to_rows(o0), to_rows(o1)]), jnp.stack([lse0, lse1])
    )
    np.testing.assert_allclose(jnp.moveaxis(o_c, 2, 1), o_full, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(lse_c, lse_full, atol=1e-5, rtol=1e-5)


def test_decode_packed_lse_merge():
    """Packed-cache split-KV decode (lane-major lse merge) vs the oracle."""
    from repro.kernels.ops import flash_decode_pallas

    B, S, Hq, Hk, D = 2, 64, 4, 2, 32
    q, kc, vc, _ = _mk(B, 1, S, Hq, Hk, D)
    kv_seg = jnp.asarray(np.repeat([[1, 2]], B, 0).repeat(S // 2, 1))
    lens = jnp.asarray([S, S], jnp.int32)
    q_seg = jnp.asarray([2, 1], jnp.int32)
    o, lse = flash_decode_pallas(
        q, kc, vc, lens, num_splits=4, kv_segment_ids=kv_seg, q_segment=q_seg
    )
    for b in range(B):
        sel = np.asarray(kv_seg[b]) == int(q_seg[b])
        o_ref, lse_ref = attention_reference(
            q[b : b + 1], kc[b : b + 1, sel], vc[b : b + 1, sel], MaskSpec()
        )
        np.testing.assert_allclose(o[b : b + 1], o_ref, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(
            lse[b : b + 1, :, 0], lse_ref[:, :, 0], atol=1e-5, rtol=1e-5
        )
