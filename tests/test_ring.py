"""Ring attention (context parallelism with sharded KV).

Three groups:

  * merge-helper + layout/accounting tests — pure math, run on any host;
  * parity + memory tests on a 4-virtual-device mesh — need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` *before* jax
    starts (the CI ``multidevice`` job sets it; single-device runs skip);
  * an end-to-end LM forward + the ``attention()`` routing under
    ``attn_sharding='ring'`` rules.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import flash_attention, flash_attention_with_lse
from repro.core.masks import MaskSpec
from repro.core.online_softmax import combine_lse_outputs, merge_partials
from repro.distributed import ring_schedule as rs

def assert_allclose(a, b, atol=1e-5, rtol=1e-5, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=atol, rtol=rtol, err_msg=msg,
    )


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


multidevice = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)

multidevice8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

SPECS = {
    "full": MaskSpec(),
    "causal": MaskSpec(causal=True),
    "window": MaskSpec(causal=True, window=128),
}


def _mesh4():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(model_axis=4)


# ---------------------------------------------------------------------------
# merge_partials: the shared (out, lse) merge primitive
# ---------------------------------------------------------------------------


def test_merge_partials_associative_commutative(rng):
    ks = jax.random.split(rng, 6)
    parts = [
        (rand(ks[2 * i], (2, 3, 16, 8)),
         rand(ks[2 * i + 1], (2, 3, 16)) * 3.0)
        for i in range(3)
    ]
    (a, b, c) = parts
    left = merge_partials(*merge_partials(*a, *b), *c)
    right = merge_partials(*a, *merge_partials(*b, *c))
    assert_allclose(left[0], right[0])
    assert_allclose(left[1], right[1])
    ab, ba = merge_partials(*a, *b), merge_partials(*b, *a)
    assert_allclose(ab[0], ba[0])
    assert_allclose(ab[1], ba[1])


def test_merge_partials_identity_and_empty(rng):
    o = rand(rng, (2, 8, 4))
    lse = rand(jax.random.fold_in(rng, 1), (2, 8))
    empty_o = jnp.full_like(o, 7.0)  # finite garbage must be erased
    empty_lse = jnp.full_like(lse, -jnp.inf)
    om, lm_ = merge_partials(o, lse, empty_o, empty_lse)
    assert_allclose(om, o)
    assert_allclose(lm_, lse)
    om, lm_ = merge_partials(empty_o, empty_lse, empty_o, empty_lse)
    assert np.all(np.isneginf(np.asarray(lm_)))
    assert_allclose(om, jnp.zeros_like(o))


def test_merge_roundtrip_vs_full_attention(rng):
    """Attention over split KV, merged with merge_partials, equals attention
    over the whole KV -- and matches the stacked combine_lse_outputs."""
    B, S, H, D = 2, 128, 2, 16
    ks = jax.random.split(rng, 3)
    q, k, v = (rand(ks[i], (B, S, H, D)) for i in range(3))
    o_full, lse_full = flash_attention_with_lse(q, k, v, MaskSpec(), block_q=32, block_kv=32)
    halves = []
    for lo, hi in ((0, S // 2), (S // 2, S)):
        o_h, lse_h = flash_attention_with_lse(
            q, k[:, lo:hi], v[:, lo:hi], MaskSpec(), block_q=32, block_kv=32
        )
        halves.append((o_h.transpose(0, 2, 1, 3), lse_h))  # (B,H,S,D)
    o_m, lse_m = merge_partials(*halves[0], *halves[1])
    assert_allclose(o_m.transpose(0, 2, 1, 3), o_full, atol=1e-5)
    assert_allclose(lse_m, lse_full, atol=1e-5)
    o_c, lse_c = combine_lse_outputs(
        jnp.stack([h[0] for h in halves]), jnp.stack([h[1] for h in halves])
    )
    assert_allclose(o_c, o_m)
    assert_allclose(lse_c, lse_m)


# ---------------------------------------------------------------------------
# Layout + schedule accounting (host-side, no devices needed)
# ---------------------------------------------------------------------------


def test_zigzag_layout_roundtrip():
    layout = rs.make_layout(512, 4, MaskSpec(causal=True))
    assert layout.chunks_per_device == 2 and layout.chunk == 64
    chunks = [c for d in range(4) for c in layout.device_chunks(d)]
    assert sorted(chunks) == list(range(8))
    perm = layout.permutation()
    assert sorted(perm.tolist()) == list(range(8))
    from repro.distributed.ring_attention import _from_layout, _to_layout

    x = jnp.arange(2 * 512 * 3, dtype=jnp.float32).reshape(2, 512, 3)
    np.testing.assert_array_equal(np.asarray(_from_layout(_to_layout(x, layout), layout)), np.asarray(x))


@multidevice
def test_shard_reorder_matches_reference_layout(rng):
    """The in-body half-shard ppermute conversion realizes exactly the
    reference chunk permutation (_to_layout) -- and round-trips."""
    from repro.distributed.ring_attention import (
        _from_layout,
        _shard_to_zigzag,
        _to_layout,
        _zigzag_to_shard,
    )
    from repro.distributed.sharding import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh4()
    layout = rs.make_layout(512, 4, MaskSpec(causal=True))
    x = rand(rng, (2, 512, 3))

    to_zig = shard_map(
        lambda x: _shard_to_zigzag(x, "model", layout),
        mesh, in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    from_zig = shard_map(
        lambda x: _zigzag_to_shard(x, "model", layout),
        mesh, in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    xz = to_zig(x)
    np.testing.assert_array_equal(np.asarray(xz), np.asarray(_to_layout(x, layout)))
    np.testing.assert_array_equal(np.asarray(from_zig(xz)), np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(_from_layout(xz, layout)), np.asarray(x)
    )


def test_zigzag_causal_load_balance():
    """The acceptance invariant: per-device visible-tile counts under a
    causal mask are equal to within one block, at several tile sizes."""
    for S, P in ((512, 4), (1024, 4), (1024, 8)):
        layout = rs.make_layout(S, P, MaskSpec(causal=True))
        for bq in (32, 64):
            counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), bq, bq)
            assert counts.max() - counts.min() <= 1, (S, P, bq, counts)
        # total work check: the ring visits exactly the causal-visible tiles
        t = S // 64
        counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), 64, 64)
        assert counts.sum() == t * (t + 1) // 2


def test_contiguous_causal_is_imbalanced():
    """Negative control: without zigzag the last device does ~P times the
    first device's work (why the layout exists)."""
    layout = rs.RingLayout(num_devices=4, chunk=128, chunks_per_device=1)
    counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), 64, 64)
    assert counts.max() >= 3 * counts.min()


def test_masked_steps_launch_no_kernels():
    """A sliding window empties whole (device, step) rectangles: the static
    schedule drops them before tracing, and the rebalanced itinerary
    truncates all-empty tail steps outright (fewer hops, not just fewer
    launches)."""
    spec = MaskSpec(causal=True, window=64)
    layout = rs.make_layout(1024, 4, spec)
    launches = rs.kernel_launch_counts(layout, spec)
    dense_launches = rs.kernel_launch_counts(layout, MaskSpec(causal=True))
    assert launches.sum() < dense_launches.sum()
    # the window leaves whole (device, shard) pairs empty -> fewer steps
    T = rs.num_steps(layout, spec)
    assert T < 4
    assert rs.num_steps(layout, MaskSpec(causal=True)) == 4
    # relative to the full rotation grid, the skipped slots are accounted
    assert rs.empty_slot_count(layout, spec) >= 4 * (4 - T)
    # every pair with visible work still appears in its device's itinerary
    visit = rs.visit_order(layout, spec)
    for d in range(4):
        for e in range(4):
            if rs.pair_tiles(layout, spec, d, e) > 0:
                assert e in visit[d]
    # truncation shrinks comm too
    kw = dict(kv_heads=2, head_dim=64, dtype_bytes=2)
    assert rs.comm_bytes_per_device(layout, spec=spec, **kw) \
        < rs.comm_bytes_per_device(layout, **kw)


def test_sparse_itinerary_per_step_balance():
    """The Latin-square itinerary never does worse than the rotation on the
    per-step critical path (sum over steps of the per-step max work), and
    its columns are valid permutations (realizable by ppermutes)."""
    for P, S, w in ((4, 4096, 128), (8, 8192, 256)):
        spec = MaskSpec(causal=True, window=w)
        layout = rs.make_layout(S, P, spec)
        visit = rs.visit_order(layout, spec)
        T = rs.num_steps(layout, spec)
        for t in range(T):
            assert sorted(visit[d][t] for d in range(P)) == list(range(P))
        for d in range(P):
            assert len(set(visit[d])) == T
        steps = rs.per_step_tile_counts(layout, spec, 128, 128)
        weight = [[rs.pair_tiles(layout, spec, d, e) for e in range(P)]
                  for d in range(P)]
        rotation_critical = sum(
            max(weight[d][(d - t) % P] for d in range(P)) for t in range(P)
        )
        assert steps.max(axis=1).sum() <= rotation_critical
        # per-device totals unchanged: rebalance moves work, never drops it
        totals = rs.visible_tile_counts(layout, spec, 128, 128)
        assert list(totals) == [sum(w_) for w_ in weight]


def test_layout_divisibility_error():
    with pytest.raises(ValueError, match="seq_len"):
        rs.make_layout(100, 4, MaskSpec(causal=True))


def test_ring_comm_accounting():
    layout = rs.make_layout(1024, 4, MaskSpec(causal=True))
    kw = dict(kv_heads=2, head_dim=64, dtype_bytes=2)
    ring = rs.comm_bytes_per_device(layout, **kw)
    gather = rs.gather_bytes_per_device(layout, **kw)
    assert ring == gather  # same bytes moved; the win is memory + overlap
    assert rs.peak_kv_bytes_per_device(layout, mode="gather", **kw) \
        == 2 * rs.peak_kv_bytes_per_device(layout, mode="ring", **kw)
    # backward hop structure (_local_bwd): P-1 KV rotations + P hops of the
    # traveling f32 (dK, dV) accumulators (final hop carries dkv alone).
    shard = 2 * layout.shard_len * 2 * 64 * 2
    dkv = 2 * layout.shard_len * 2 * 64 * 4
    assert rs.comm_bytes_per_device(layout, backward=True, **kw) \
        == 3 * shard + 4 * dkv


# ---------------------------------------------------------------------------
# Multi-device parity (4 virtual host devices)
# ---------------------------------------------------------------------------


def _qkv(rng, B=2, S=512, Hq=4, Hk=2, D=32, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], (B, S, Hq, D)).astype(dtype)
    k = rand(ks[1], (B, S, Hk, D)).astype(dtype)
    v = rand(ks[2], (B, S, Hk, D)).astype(dtype)
    return q, k, v


@multidevice
@pytest.mark.parametrize("impl", ["flash_pallas", "flash_xla"])
@pytest.mark.parametrize("desc", list(SPECS))
def test_ring_parity_fwd_and_grads(rng, impl, desc):
    """attn_sharding='ring' output AND grads match the single-device flash
    to fp32 tolerance (GQA everywhere: Hq=4, Hkv=2)."""
    from repro.distributed.ring_attention import ring_flash_attention

    spec = SPECS[desc]
    mesh = _mesh4()
    q, k, v = _qkv(rng)

    def ring(q, k, v):
        return ring_flash_attention(
            q, k, v, spec, mesh=mesh, impl=impl, block_q=64, block_kv=64
        )

    def ref(q, k, v):
        return flash_attention(q, k, v, spec, block_q=64, block_kv=64)

    assert_allclose(jax.jit(ring)(q, k, v), ref(q, k, v), atol=2e-5)

    def loss(f):
        return lambda q, k, v: (f(q, k, v) ** 2).sum()

    g_ring = jax.grad(loss(ring), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss(ref), argnums=(0, 1, 2))(q, k, v)
    for gr, gf, name in zip(g_ring, g_ref, "qkv"):
        assert_allclose(gr, gf, atol=5e-3, rtol=1e-3, msg=f"d{name}/{desc}/{impl}")


@multidevice
def test_ring_grads_fused_vs_split_bwd(rng):
    """The ring backward inherits the fused one-pass rectangle kernel
    (ops.flash_attention_pallas_shard_bwd, bwd='fused' default): grads must
    match the split-baseline ring bitwise-tight -- each rectangle runs the
    same tile updates in the same order, and the ring folds them the same
    way."""
    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng)
    spec = MaskSpec(causal=True)

    def grads(bwd):
        def loss(q, k, v):
            o = ring_flash_attention(
                q, k, v, spec, mesh=mesh, impl="flash_pallas",
                block_q=64, block_kv=64, bwd=bwd,
            )
            return (o ** 2).sum()

        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    for gf, gs, name in zip(grads("fused"), grads("split"), "qkv"):
        assert_allclose(gf, gs, atol=1e-6, rtol=1e-6, msg=f"d{name}")


@multidevice
def test_ring_parity_bf16(rng):
    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng, dtype=jnp.bfloat16)
    o = ring_flash_attention(
        q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
    )
    o_ref = flash_attention(q, k, v, MaskSpec(causal=True), block_q=64, block_kv=64)
    assert o.dtype == jnp.bfloat16
    assert_allclose(o, o_ref, atol=2e-2, rtol=2e-2)


@multidevice
def test_ring_no_replicated_arrays(rng):
    """The acceptance memory criterion, checked at BOTH levels:

    1. the SPMD-partitioned program for sequence-sharded inputs contains no
       all-gather at all (the zigzag reorder is half-shard ppermutes; a
       global chunk permutation outside the shard_map would silently lower
       to full-S all-gathers of Q/K/V -- the bug this guards against);
    2. inside the shard_map body no array carries a full-S dimension -- KV
       stays O(S / P) per device (the gather mode materializes
       (B, S, Hkv, D) per device by construction).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    S = 512
    q, k, v = _qkv(rng, S=S)

    def ring(q, k, v):
        return ring_flash_attention(
            q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
        )

    sh = NamedSharding(mesh, P(None, "model", None, None))
    hlo = (
        jax.jit(ring, in_shardings=(sh, sh, sh))
        .lower(q, k, v)
        .compile()
        .as_text()
    )
    assert "all-gather" not in hlo, "ring program re-replicates a sharded array"

    jaxpr = jax.make_jaxpr(ring)(q, k, v)

    def body_jaxprs(jpr, inside_shmap=False):
        for eqn in jpr.eqns:
            inside = inside_shmap or "shard_map" in eqn.primitive.name
            for sub in jax.core.jaxprs_in_params(eqn.params):
                sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                if inside:
                    yield sub
                yield from body_jaxprs(sub, inside)

    found = list(body_jaxprs(jaxpr.jaxpr))
    assert found, "no shard_map body found in the ring jaxpr"
    for sub in found:
        for eqn in sub.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                shape = getattr(getattr(var, "aval", None), "shape", ())
                assert all(dim < S for dim in shape), (
                    f"full-S array inside the shard body: {shape}"
                )


# ---------------------------------------------------------------------------
# HLO-level overlap pin (the double-buffer acceptance criterion)
# ---------------------------------------------------------------------------


def _entry_ops(hlo: str):
    """Instruction lines of the scheduled ENTRY computation, in schedule
    order (the compiled module is scheduled: textual order = issue order)."""
    lines = hlo.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY "))
    end = next(i for i in range(start + 1, len(lines)) if lines[i].startswith("}"))
    return lines[start + 1 : end]


def _hlo_graph(entry_lines):
    """(defs, deps): op name -> (schedule index, line) and direct operands."""
    import re

    defs, deps = {}, {}
    for i, l in enumerate(entry_lines):
        m = re.match(r"\s*(%[\w.\-]+)\s*=", l)
        if m:
            defs[m.group(1)] = (i, l)
    for name, (_, l) in defs.items():
        rhs = l.split("=", 1)[1]
        deps[name] = set(re.findall(r"(%[\w.\-]+)", rhs)) & set(defs)
    return defs, deps


def _transitive_deps(deps, name):
    out, stack = set(), [name]
    while stack:
        for d in deps.get(stack.pop(), ()):
            if d not in out:
                out.add(d)
                stack.append(d)
    return out


def _assert_hops_pinned(hlo: str, direction: str, num_steps: int):
    """The double-buffer contract, per ring step ``t``:

    1. the collective-permute of hop ``t+1`` is *scheduled* before step
       ``t``'s fusions complete (hop in flight while the step computes);
    2. the hop does not transitively depend on any step-``t`` op — the
       dependence structure a latency-hiding backend needs to overlap
       them (this is what the old backward violated by rotating (KV, dKV)
       together after the step's kernels).
    """
    import re

    entry = _entry_ops(hlo)
    defs, deps = _hlo_graph(entry)

    def in_scope(name, scope):
        return re.search(rf"{scope}/", defs[name][1]) is not None

    for t in range(num_steps - 1):
        hops = [
            n for n in defs
            if "collective-permute" in defs[n][1]
            and in_scope(n, f"{direction}_hop{t + 1}")
        ]
        step = [n for n in defs if in_scope(n, f"{direction}_step{t}")]
        assert hops, f"{direction} hop {t + 1}: no collective-permute in HLO"
        assert step, f"{direction} step {t}: no compute ops in HLO"
        last_step = max(defs[n][0] for n in step)
        for h in hops:
            assert defs[h][0] < last_step, (
                f"{direction} hop {t + 1} scheduled after step {t} retired "
                f"(hop at {defs[h][0]}, step ends at {last_step})"
            )
            stale = _transitive_deps(deps, h) & set(step)
            assert not stale, (
                f"{direction} hop {t + 1} depends on step {t} compute "
                f"({sorted(stale)[:3]}...): overlap impossible"
            )


@multidevice
def test_ring_fwd_overlap_pinned_in_hlo(rng):
    """Forward double buffer: hop t+1 issued before step t's fusions
    complete, with the optimization_barrier pin present in the lowered
    module (the barrier is what holds the schedule on latency-hiding
    backends; CPU expands it away after scheduling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng)

    def ring(q, k, v):
        return ring_flash_attention(
            q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
        )

    sh = NamedSharding(mesh, P(None, "model", None, None))
    lowered = jax.jit(ring, in_shardings=(sh, sh, sh)).lower(q, k, v)
    assert lowered.as_text().count("optimization_barrier") >= 3, (
        "fwd prefetch barriers missing from the lowered module"
    )
    _assert_hops_pinned(lowered.compile().as_text(), "ring_fwd", 4)


@multidevice
def test_ring_bwd_overlap_pinned_in_hlo(rng):
    """Backward double buffer: the KV hop is prefetched exactly like the
    forward (pinned ahead of the step), while the (dK, dV) hop trails the
    step it genuinely depends on."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng)

    def loss(q, k, v):
        o = ring_flash_attention(
            q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
        )
        return jnp.sum(o)

    sh = NamedSharding(mesh, P(None, "model", None, None))
    lowered = jax.jit(
        jax.grad(loss, argnums=(0, 1, 2)), in_shardings=(sh, sh, sh)
    ).lower(q, k, v)
    # 3 fwd (vjp replay) + 3 bwd prefetch barriers
    assert lowered.as_text().count("optimization_barrier") >= 6, (
        "bwd prefetch barriers missing from the lowered module"
    )
    hlo = lowered.compile().as_text()
    _assert_hops_pinned(hlo, "ring_fwd", 4)
    _assert_hops_pinned(hlo, "ring_bwd", 4)
    # sanity: the traveling accumulators DO depend on their step's compute
    # (their hop is the one collective that legitimately trails the step).
    import re

    entry = _entry_ops(hlo)
    defs, deps = _hlo_graph(entry)
    for t in range(4):
        dkv_hops = [
            n for n in defs
            if "collective-permute" in defs[n][1]
            and re.search(rf"ring_bwd_dkv_hop{t}/", defs[n][1])
        ]
        step = {n for n in defs if re.search(rf"ring_bwd_step{t}/", defs[n][1])}
        assert dkv_hops, f"dkv hop {t} missing"
        for h in dkv_hops:
            assert _transitive_deps(deps, h) & step


# ---------------------------------------------------------------------------
# 2D (data x ring) mesh parity (8 virtual host devices)
# ---------------------------------------------------------------------------


def _mesh_2d_and_1d():
    """(data=2, model=4) over 8 devices + a 1D (data=1, model=4) baseline
    over the first 4 — same ring size, so per-example math is identical."""
    from jax.sharding import Mesh

    mesh2d = jax.make_mesh((2, 4), ("data", "model"))
    mesh1d = Mesh(np.asarray(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
    return mesh2d, mesh1d


@multidevice8
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_2d_mesh_parity(rng, dtype):
    """Ring attention on the 2D (data x ring) mesh: bitwise-equal to the
    1D ring (same P=4 layout — the data axis only splits the batch) and
    allclose to the single-device flash reference, per dtype."""
    from repro.distributed.ring_attention import ring_flash_attention

    mesh2d, mesh1d = _mesh_2d_and_1d()
    q, k, v = _qkv(rng, B=2, dtype=dtype)
    spec = MaskSpec(causal=True)

    def ring(mesh):
        return jax.jit(lambda q, k, v: ring_flash_attention(
            q, k, v, spec, mesh=mesh, batch_axes="data",
            block_q=64, block_kv=64,
        ))

    o_2d = ring(mesh2d)(q, k, v)
    o_1d = ring(mesh1d)(q, k, v)
    np.testing.assert_array_equal(
        np.asarray(o_2d, np.float32), np.asarray(o_1d, np.float32),
        err_msg="2D-mesh ring diverges from the 1D ring",
    )
    o_ref = flash_attention(q, k, v, spec, block_q=64, block_kv=64)
    tol = dict(atol=2e-5, rtol=1e-5) if dtype == jnp.float32 \
        else dict(atol=2e-2, rtol=2e-2)
    assert_allclose(o_2d, o_ref, **tol)


@multidevice8
def test_ring_2d_mesh_grads_and_no_gather(rng):
    """Loss/grads on the 2D mesh match the 1D ring bitwise and the flash
    reference to tolerance; the compiled 2D program contains zero
    all-gathers of KV."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.ring_attention import ring_flash_attention

    mesh2d, mesh1d = _mesh_2d_and_1d()
    q, k, v = _qkv(rng, B=2)
    spec = MaskSpec(causal=True)

    def loss_fn(mesh):
        def loss(q, k, v):
            o = ring_flash_attention(
                q, k, v, spec, mesh=mesh, batch_axes="data",
                block_q=64, block_kv=64,
            )
            return (o.astype(jnp.float32) ** 2).sum()
        return loss

    # The attention outputs are bitwise equal across meshes (previous
    # test); the scalar .sum() is only ulp-close — XLA's cross-device
    # reduction tree differs between the 8- and 4-device meshes.
    l_2d = jax.jit(loss_fn(mesh2d))(q, k, v)
    l_1d = jax.jit(loss_fn(mesh1d))(q, k, v)
    np.testing.assert_allclose(np.asarray(l_2d), np.asarray(l_1d), rtol=1e-5)

    g_2d = jax.jit(jax.grad(loss_fn(mesh2d), argnums=(0, 1, 2)))(q, k, v)
    g_1d = jax.jit(jax.grad(loss_fn(mesh1d), argnums=(0, 1, 2)))(q, k, v)
    for a, b, name in zip(g_2d, g_1d, "qkv"):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"2D-mesh d{name} diverges from the 1D ring",
        )

    def ref_loss(q, k, v):
        o = flash_attention(q, k, v, spec, block_q=64, block_kv=64)
        return (o.astype(jnp.float32) ** 2).sum()

    g_ref = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_2d, g_ref, "qkv"):
        assert_allclose(a, b, atol=5e-3, rtol=1e-3, msg=f"d{name} vs reference")

    # the acceptance criterion: zero KV all-gathers on the 2D mesh
    sh = NamedSharding(mesh2d, P("data", "model", None, None))
    hlo = (
        jax.jit(jax.grad(loss_fn(mesh2d), argnums=(0, 1, 2)),
                in_shardings=(sh, sh, sh))
        .lower(q, k, v).compile().as_text()
    )
    assert "all-gather" not in hlo, "2D-mesh ring re-replicates a sharded array"


@multidevice
def test_attention_routes_to_ring_under_rules(rng):
    """core.attention.attention dispatches on the installed rules; packed
    varlen + ring is rejected loudly."""
    from repro.core.attention import AttentionConfig, attention
    from repro.distributed.sharding import lm_rules, use_rules

    mesh = _mesh4()
    rules = lm_rules(attn_sharding="ring", model_axis=4)
    q, k, v = _qkv(rng)
    spec = MaskSpec(causal=True)
    cfg = AttentionConfig(impl="flash_pallas", block_q=64, block_kv=64)
    o_plain = attention(q, k, v, spec, cfg)
    with mesh, use_rules(mesh, rules):
        o_ring = jax.jit(lambda q, k, v: attention(q, k, v, spec, cfg))(q, k, v)
        with pytest.raises(ValueError, match="ring"):
            attention(q, k, v, spec, cfg, segment_ids=jnp.zeros(q.shape[:2], jnp.int32))
    assert_allclose(o_ring, o_plain, atol=2e-5)


@multidevice
def test_lm_forward_under_ring_rules(rng):
    """End to end: a GPT forward under ring rules matches the unsharded
    forward (ring is wired through apply_attention / gather_kv no-op)."""
    from repro.core.attention import AttentionConfig
    from repro.distributed.sharding import lm_rules, use_rules
    from repro.launch.train import PRESETS
    from repro.models import lm

    mesh = _mesh4()
    cfg = dataclasses.replace(PRESETS["gpt-20m"], attn_sharding="ring")
    params = lm.init_lm(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(rng, (2, 256), 0, cfg.vocab_size)
    acfg = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64)
    h0, _, _ = lm.forward(cfg, params, toks, acfg)
    with mesh, use_rules(mesh, lm_rules(cfg, model_axis=4)):
        h1 = jax.jit(lambda p, t: lm.forward(cfg, p, t, acfg)[0])(params, toks)
    assert_allclose(h1, h0, atol=2e-4, rtol=2e-4)


def test_mode_switch_flushes_stale_traces():
    """Satellite 1 (ISSUE 9): the SAME jitted closure reused across
    sharding modes must retrace, not replay a trace that baked in the
    other mode's routing (jit caches key on function identity + avals,
    not the thread-local rules context). use_rules flushes jax's caches
    at every boundary where the effective attn_context_mode changes; an
    unchanged mode never flushes."""
    from jax.sharding import Mesh

    # NOTE: deliberately no ``with mesh:`` here — the ambient mesh context
    # is itself part of jit's cache key and would mask what this guards.
    from repro.distributed.context_parallel import attn_context_mode
    from repro.distributed.sharding import lm_rules, use_rules
    from repro.obs.metrics import default_registry

    traced = []

    @jax.jit
    def step(x):
        traced.append(attn_context_mode())
        return x * 2.0

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    x = jnp.ones((4,), jnp.float32)
    flushes = lambda: default_registry().counter(
        "sharding/trace_cache_flushes").value

    step(x)  # traced with mode None
    assert traced == [None]
    f0 = flushes()

    # 'gather' is effective even on a 1-wide model axis, so this runs on
    # any host. Entry boundary: None-trace on record, 'gather' installed
    # -> flush -> the SAME closure retraces and sees the new mode.
    with use_rules(mesh, lm_rules(attn_sharding="sequence", model_axis=1)):
        step(x)
        assert traced == [None, "gather"], "stale mode-None trace replayed"
    # Exit boundary: 'gather'-trace on record, None restored -> flush.
    step(x)
    assert traced == [None, "gather", None], "stale 'gather' trace replayed"
    assert flushes() >= f0 + 2

    # Unchanged effective mode ('heads' on model=1 is None, same as
    # outside): no flush, the cached trace replays.
    n, f1 = len(traced), flushes()
    with use_rules(mesh, lm_rules(attn_sharding="heads", model_axis=1)):
        step(x)
    step(x)
    assert len(traced) == n, "mode-preserving boundary forced a retrace"
    assert flushes() == f1, "mode-preserving boundary flushed the caches"
