"""Ring attention (context parallelism with sharded KV).

Three groups:

  * merge-helper + layout/accounting tests — pure math, run on any host;
  * parity + memory tests on a 4-virtual-device mesh — need
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` *before* jax
    starts (the CI ``multidevice`` job sets it; single-device runs skip);
  * an end-to-end LM forward + the ``attention()`` routing under
    ``attn_sharding='ring'`` rules.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import flash_attention, flash_attention_with_lse
from repro.core.masks import MaskSpec
from repro.core.online_softmax import combine_lse_outputs, merge_partials
from repro.distributed import ring_schedule as rs

def assert_allclose(a, b, atol=1e-5, rtol=1e-5, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=atol, rtol=rtol, err_msg=msg,
    )


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


multidevice = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4",
)

SPECS = {
    "full": MaskSpec(),
    "causal": MaskSpec(causal=True),
    "window": MaskSpec(causal=True, window=128),
}


def _mesh4():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(model_axis=4)


# ---------------------------------------------------------------------------
# merge_partials: the shared (out, lse) merge primitive
# ---------------------------------------------------------------------------


def test_merge_partials_associative_commutative(rng):
    ks = jax.random.split(rng, 6)
    parts = [
        (rand(ks[2 * i], (2, 3, 16, 8)),
         rand(ks[2 * i + 1], (2, 3, 16)) * 3.0)
        for i in range(3)
    ]
    (a, b, c) = parts
    left = merge_partials(*merge_partials(*a, *b), *c)
    right = merge_partials(*a, *merge_partials(*b, *c))
    assert_allclose(left[0], right[0])
    assert_allclose(left[1], right[1])
    ab, ba = merge_partials(*a, *b), merge_partials(*b, *a)
    assert_allclose(ab[0], ba[0])
    assert_allclose(ab[1], ba[1])


def test_merge_partials_identity_and_empty(rng):
    o = rand(rng, (2, 8, 4))
    lse = rand(jax.random.fold_in(rng, 1), (2, 8))
    empty_o = jnp.full_like(o, 7.0)  # finite garbage must be erased
    empty_lse = jnp.full_like(lse, -jnp.inf)
    om, lm_ = merge_partials(o, lse, empty_o, empty_lse)
    assert_allclose(om, o)
    assert_allclose(lm_, lse)
    om, lm_ = merge_partials(empty_o, empty_lse, empty_o, empty_lse)
    assert np.all(np.isneginf(np.asarray(lm_)))
    assert_allclose(om, jnp.zeros_like(o))


def test_merge_roundtrip_vs_full_attention(rng):
    """Attention over split KV, merged with merge_partials, equals attention
    over the whole KV -- and matches the stacked combine_lse_outputs."""
    B, S, H, D = 2, 128, 2, 16
    ks = jax.random.split(rng, 3)
    q, k, v = (rand(ks[i], (B, S, H, D)) for i in range(3))
    o_full, lse_full = flash_attention_with_lse(q, k, v, MaskSpec(), block_q=32, block_kv=32)
    halves = []
    for lo, hi in ((0, S // 2), (S // 2, S)):
        o_h, lse_h = flash_attention_with_lse(
            q, k[:, lo:hi], v[:, lo:hi], MaskSpec(), block_q=32, block_kv=32
        )
        halves.append((o_h.transpose(0, 2, 1, 3), lse_h))  # (B,H,S,D)
    o_m, lse_m = merge_partials(*halves[0], *halves[1])
    assert_allclose(o_m.transpose(0, 2, 1, 3), o_full, atol=1e-5)
    assert_allclose(lse_m, lse_full, atol=1e-5)
    o_c, lse_c = combine_lse_outputs(
        jnp.stack([h[0] for h in halves]), jnp.stack([h[1] for h in halves])
    )
    assert_allclose(o_c, o_m)
    assert_allclose(lse_c, lse_m)


# ---------------------------------------------------------------------------
# Layout + schedule accounting (host-side, no devices needed)
# ---------------------------------------------------------------------------


def test_zigzag_layout_roundtrip():
    layout = rs.make_layout(512, 4, MaskSpec(causal=True))
    assert layout.chunks_per_device == 2 and layout.chunk == 64
    chunks = [c for d in range(4) for c in layout.device_chunks(d)]
    assert sorted(chunks) == list(range(8))
    perm = layout.permutation()
    assert sorted(perm.tolist()) == list(range(8))
    from repro.distributed.ring_attention import _from_layout, _to_layout

    x = jnp.arange(2 * 512 * 3, dtype=jnp.float32).reshape(2, 512, 3)
    np.testing.assert_array_equal(np.asarray(_from_layout(_to_layout(x, layout), layout)), np.asarray(x))


@multidevice
def test_shard_reorder_matches_reference_layout(rng):
    """The in-body half-shard ppermute conversion realizes exactly the
    reference chunk permutation (_to_layout) -- and round-trips."""
    from repro.distributed.ring_attention import (
        _from_layout,
        _shard_to_zigzag,
        _to_layout,
        _zigzag_to_shard,
    )
    from repro.distributed.sharding import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh4()
    layout = rs.make_layout(512, 4, MaskSpec(causal=True))
    x = rand(rng, (2, 512, 3))

    to_zig = shard_map(
        lambda x: _shard_to_zigzag(x, "model", layout),
        mesh, in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    from_zig = shard_map(
        lambda x: _zigzag_to_shard(x, "model", layout),
        mesh, in_specs=P(None, "model", None), out_specs=P(None, "model", None),
    )
    xz = to_zig(x)
    np.testing.assert_array_equal(np.asarray(xz), np.asarray(_to_layout(x, layout)))
    np.testing.assert_array_equal(np.asarray(from_zig(xz)), np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(_from_layout(xz, layout)), np.asarray(x)
    )


def test_zigzag_causal_load_balance():
    """The acceptance invariant: per-device visible-tile counts under a
    causal mask are equal to within one block, at several tile sizes."""
    for S, P in ((512, 4), (1024, 4), (1024, 8)):
        layout = rs.make_layout(S, P, MaskSpec(causal=True))
        for bq in (32, 64):
            counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), bq, bq)
            assert counts.max() - counts.min() <= 1, (S, P, bq, counts)
        # total work check: the ring visits exactly the causal-visible tiles
        t = S // 64
        counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), 64, 64)
        assert counts.sum() == t * (t + 1) // 2


def test_contiguous_causal_is_imbalanced():
    """Negative control: without zigzag the last device does ~P times the
    first device's work (why the layout exists)."""
    layout = rs.RingLayout(num_devices=4, chunk=128, chunks_per_device=1)
    counts = rs.visible_tile_counts(layout, MaskSpec(causal=True), 64, 64)
    assert counts.max() >= 3 * counts.min()


def test_masked_steps_launch_no_kernels():
    """A sliding window empties whole (device, step) rectangles: the static
    schedule drops them before tracing."""
    spec = MaskSpec(causal=True, window=64)
    layout = rs.make_layout(1024, 4, spec)
    launches = rs.kernel_launch_counts(layout, spec)
    dense_launches = rs.kernel_launch_counts(layout, MaskSpec(causal=True))
    assert launches.sum() < dense_launches.sum()
    # at least one fully-empty step exists for some device
    empties = [
        (d, t)
        for d in range(4)
        for t in range(4)
        if not rs.step_pairs(layout, spec, d, t)
    ]
    assert empties


def test_layout_divisibility_error():
    with pytest.raises(ValueError, match="seq_len"):
        rs.make_layout(100, 4, MaskSpec(causal=True))


def test_ring_comm_accounting():
    layout = rs.make_layout(1024, 4, MaskSpec(causal=True))
    kw = dict(kv_heads=2, head_dim=64, dtype_bytes=2)
    ring = rs.comm_bytes_per_device(layout, **kw)
    gather = rs.gather_bytes_per_device(layout, **kw)
    assert ring == gather  # same bytes moved; the win is memory + overlap
    assert rs.peak_kv_bytes_per_device(layout, mode="gather", **kw) \
        == 2 * rs.peak_kv_bytes_per_device(layout, mode="ring", **kw)
    # backward hop structure (_local_bwd): P-1 KV rotations + P hops of the
    # traveling f32 (dK, dV) accumulators (final hop carries dkv alone).
    shard = 2 * layout.shard_len * 2 * 64 * 2
    dkv = 2 * layout.shard_len * 2 * 64 * 4
    assert rs.comm_bytes_per_device(layout, backward=True, **kw) \
        == 3 * shard + 4 * dkv


# ---------------------------------------------------------------------------
# Multi-device parity (4 virtual host devices)
# ---------------------------------------------------------------------------


def _qkv(rng, B=2, S=512, Hq=4, Hk=2, D=32, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    q = rand(ks[0], (B, S, Hq, D)).astype(dtype)
    k = rand(ks[1], (B, S, Hk, D)).astype(dtype)
    v = rand(ks[2], (B, S, Hk, D)).astype(dtype)
    return q, k, v


@multidevice
@pytest.mark.parametrize("impl", ["flash_pallas", "flash_xla"])
@pytest.mark.parametrize("desc", list(SPECS))
def test_ring_parity_fwd_and_grads(rng, impl, desc):
    """attn_sharding='ring' output AND grads match the single-device flash
    to fp32 tolerance (GQA everywhere: Hq=4, Hkv=2)."""
    from repro.distributed.ring_attention import ring_flash_attention

    spec = SPECS[desc]
    mesh = _mesh4()
    q, k, v = _qkv(rng)

    def ring(q, k, v):
        return ring_flash_attention(
            q, k, v, spec, mesh=mesh, impl=impl, block_q=64, block_kv=64
        )

    def ref(q, k, v):
        return flash_attention(q, k, v, spec, block_q=64, block_kv=64)

    assert_allclose(jax.jit(ring)(q, k, v), ref(q, k, v), atol=2e-5)

    def loss(f):
        return lambda q, k, v: (f(q, k, v) ** 2).sum()

    g_ring = jax.grad(loss(ring), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss(ref), argnums=(0, 1, 2))(q, k, v)
    for gr, gf, name in zip(g_ring, g_ref, "qkv"):
        assert_allclose(gr, gf, atol=5e-3, rtol=1e-3, msg=f"d{name}/{desc}/{impl}")


@multidevice
def test_ring_grads_fused_vs_split_bwd(rng):
    """The ring backward inherits the fused one-pass rectangle kernel
    (ops.flash_attention_pallas_shard_bwd, bwd='fused' default): grads must
    match the split-baseline ring bitwise-tight -- each rectangle runs the
    same tile updates in the same order, and the ring folds them the same
    way."""
    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng)
    spec = MaskSpec(causal=True)

    def grads(bwd):
        def loss(q, k, v):
            o = ring_flash_attention(
                q, k, v, spec, mesh=mesh, impl="flash_pallas",
                block_q=64, block_kv=64, bwd=bwd,
            )
            return (o ** 2).sum()

        return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

    for gf, gs, name in zip(grads("fused"), grads("split"), "qkv"):
        assert_allclose(gf, gs, atol=1e-6, rtol=1e-6, msg=f"d{name}")


@multidevice
def test_ring_parity_bf16(rng):
    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    q, k, v = _qkv(rng, dtype=jnp.bfloat16)
    o = ring_flash_attention(
        q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
    )
    o_ref = flash_attention(q, k, v, MaskSpec(causal=True), block_q=64, block_kv=64)
    assert o.dtype == jnp.bfloat16
    assert_allclose(o, o_ref, atol=2e-2, rtol=2e-2)


@multidevice
def test_ring_no_replicated_arrays(rng):
    """The acceptance memory criterion, checked at BOTH levels:

    1. the SPMD-partitioned program for sequence-sharded inputs contains no
       all-gather at all (the zigzag reorder is half-shard ppermutes; a
       global chunk permutation outside the shard_map would silently lower
       to full-S all-gathers of Q/K/V -- the bug this guards against);
    2. inside the shard_map body no array carries a full-S dimension -- KV
       stays O(S / P) per device (the gather mode materializes
       (B, S, Hkv, D) per device by construction).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.ring_attention import ring_flash_attention

    mesh = _mesh4()
    S = 512
    q, k, v = _qkv(rng, S=S)

    def ring(q, k, v):
        return ring_flash_attention(
            q, k, v, MaskSpec(causal=True), mesh=mesh, block_q=64, block_kv=64
        )

    sh = NamedSharding(mesh, P(None, "model", None, None))
    hlo = (
        jax.jit(ring, in_shardings=(sh, sh, sh))
        .lower(q, k, v)
        .compile()
        .as_text()
    )
    assert "all-gather" not in hlo, "ring program re-replicates a sharded array"

    jaxpr = jax.make_jaxpr(ring)(q, k, v)

    def body_jaxprs(jpr, inside_shmap=False):
        for eqn in jpr.eqns:
            inside = inside_shmap or "shard_map" in eqn.primitive.name
            for sub in jax.core.jaxprs_in_params(eqn.params):
                sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                if inside:
                    yield sub
                yield from body_jaxprs(sub, inside)

    found = list(body_jaxprs(jaxpr.jaxpr))
    assert found, "no shard_map body found in the ring jaxpr"
    for sub in found:
        for eqn in sub.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                shape = getattr(getattr(var, "aval", None), "shape", ())
                assert all(dim < S for dim in shape), (
                    f"full-S array inside the shard body: {shape}"
                )


@multidevice
def test_attention_routes_to_ring_under_rules(rng):
    """core.attention.attention dispatches on the installed rules; packed
    varlen + ring is rejected loudly."""
    from repro.core.attention import AttentionConfig, attention
    from repro.distributed.sharding import lm_rules, use_rules

    mesh = _mesh4()
    rules = lm_rules(attn_sharding="ring", model_axis=4)
    q, k, v = _qkv(rng)
    spec = MaskSpec(causal=True)
    cfg = AttentionConfig(impl="flash_pallas", block_q=64, block_kv=64)
    o_plain = attention(q, k, v, spec, cfg)
    with mesh, use_rules(mesh, rules):
        o_ring = jax.jit(lambda q, k, v: attention(q, k, v, spec, cfg))(q, k, v)
        with pytest.raises(ValueError, match="ring"):
            attention(q, k, v, spec, cfg, segment_ids=jnp.zeros(q.shape[:2], jnp.int32))
    assert_allclose(o_ring, o_plain, atol=2e-5)


@multidevice
def test_lm_forward_under_ring_rules(rng):
    """End to end: a GPT forward under ring rules matches the unsharded
    forward (ring is wired through apply_attention / gather_kv no-op)."""
    from repro.core.attention import AttentionConfig
    from repro.distributed.sharding import lm_rules, use_rules
    from repro.launch.train import PRESETS
    from repro.models import lm

    mesh = _mesh4()
    cfg = dataclasses.replace(PRESETS["gpt-20m"], attn_sharding="ring")
    params = lm.init_lm(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(rng, (2, 256), 0, cfg.vocab_size)
    acfg = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64)
    h0, _, _ = lm.forward(cfg, params, toks, acfg)
    with mesh, use_rules(mesh, lm_rules(cfg, model_axis=4)):
        h1 = jax.jit(lambda p, t: lm.forward(cfg, p, t, acfg)[0])(params, toks)
    assert_allclose(h1, h0, atol=2e-4, rtol=2e-4)
