"""XLA FlashAttention-2 (core/flash.py) vs the pure-jnp oracle: forward,
LSE, and the Algorithm-2 custom VJP, across shapes/dtypes/masks/modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import flash_attention, flash_attention_with_lse
from repro.core.flash_v1 import flash_v1_attention
from repro.core.masks import MaskSpec
from repro.kernels.ref import attention_reference, attention_reference_bwd

KEY = jax.random.PRNGKey(0)


def _mk(B, Sq, Sk, Hq, Hk, D, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, D), dtype)
    do = jax.random.normal(ks[3], (B, Sq, Hq, D), dtype)
    return q, k, v, do


SLOW = pytest.mark.slow
CASES = [
    # B, Sq, Sk, Hq, Hk, D, spec, mode  (slow tier: redundant-angle sweeps)
    ((2, 128, 128, 4, 4, 64, MaskSpec(causal=True), "auto"), SLOW),
    ((2, 128, 128, 4, 2, 64, MaskSpec(causal=True), "packed"), None),
    ((2, 128, 128, 4, 2, 64, MaskSpec(causal=True), "dense"), None),
    ((2, 96, 96, 4, 1, 32, MaskSpec(causal=True), "auto"), SLOW),  # padding + MQA
    ((1, 128, 256, 4, 4, 64, MaskSpec(), "auto"), None),  # cross attn
    ((2, 256, 256, 4, 2, 32, MaskSpec(causal=True, window=64), "auto"), SLOW),
    ((2, 192, 192, 4, 2, 32, MaskSpec(window=48), "auto"), None),
    ((2, 256, 256, 4, 2, 32, MaskSpec(window=48), "auto"), SLOW),
    ((2, 256, 256, 4, 2, 32, MaskSpec(causal=True, window=64, sink=16), "auto"), SLOW),
    ((1, 64, 192, 2, 2, 32, MaskSpec(causal=True, q_offset=128), "auto"), None),
    ((2, 128, 128, 8, 8, 128, MaskSpec(causal=True), "auto"), SLOW),  # d=128
]


@pytest.mark.parametrize(
    "case",
    [pytest.param(c, marks=m) if m else c for c, m in CASES],
    ids=[str(i) for i in range(len(CASES))],
)
def test_forward_and_grad_match_oracle(case):
    B, Sq, Sk, Hq, Hk, D, spec, mode = case
    q, k, v, do = _mk(B, Sq, Sk, Hq, Hk, D, jnp.float32)
    o_ref, lse_ref = attention_reference(q, k, v, spec)
    o, lse = flash_attention_with_lse(q, k, v, spec, block_q=64, block_kv=64, mode=mode)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=1e-4)
    lse_mask = ~np.isneginf(np.asarray(lse_ref))
    np.testing.assert_allclose(
        np.asarray(lse)[lse_mask], np.asarray(lse_ref)[lse_mask], atol=1e-4, rtol=1e-5
    )
    f = lambda q, k, v: (flash_attention(q, k, v, spec, block_q=64, block_kv=64, mode=mode) * do).sum()
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
    for a, b in zip(jax.grad(f, (0, 1, 2))(q, k, v), jax.grad(g, (0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=1e-3)


def test_bf16_forward_close():
    q, k, v, _ = _mk(2, 256, 256, 4, 2, 64, jnp.bfloat16)
    spec = MaskSpec(causal=True)
    o_ref, _ = attention_reference(q, k, v, spec)  # fp32 internally
    o = flash_attention(q, k, v, spec, block_q=64, block_kv=64)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_manual_bwd_matches_autodiff_reference():
    """attention_reference_bwd (explicit Alg.2 math) == jax.grad of ref."""
    q, k, v, do = _mk(2, 128, 128, 4, 2, 32, jnp.float32)
    spec = MaskSpec(causal=True)
    o, lse = attention_reference(q, k, v, spec)
    dq, dk, dv = attention_reference_bwd(q, k, v, o, do, lse, spec)
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
    dq_r, dk_r, dv_r = jax.grad(g, (0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, dq_r, atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(dk, dk_r, atol=5e-5, rtol=1e-4)
    np.testing.assert_allclose(dv, dv_r, atol=5e-5, rtol=1e-4)


def test_flash_v1_baseline_matches():
    q, k, v, _ = _mk(2, 256, 256, 4, 2, 64, jnp.float32)
    spec = MaskSpec(causal=True)
    o_ref, lse_ref = attention_reference(q, k, v, spec)
    o, m, l = flash_v1_attention(q, k, v, spec, block_kv=64)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=1e-4)
    # FA1 keeps (m, l); FA2 keeps only LSE = m + log l -- same information.
    np.testing.assert_allclose(m + jnp.log(l), lse_ref, atol=1e-4, rtol=1e-5)


def test_packed_visible_pairs_causal_halving():
    """C2 accounting: causal packing visits ~half the tiles."""
    from repro.core.flash import _visible_pairs

    ii, jj = _visible_pairs(MaskSpec(causal=True), 16, 16, 64, 64)
    assert len(ii) == 16 * 17 // 2  # triangular
    ii_w, _ = _visible_pairs(MaskSpec(causal=True, window=64), 16, 16, 64, 64)
    assert len(ii_w) == 16 + 15  # diagonal + one off-diagonal band
