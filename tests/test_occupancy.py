"""Occupancy-aware forward partitioning (ISSUE 5 / DESIGN.md Section 2.1b).

Four claims:
  (a) q-banding is *semantics-free to the bit*: each q row runs its
      unchanged kv visit sequence, just on a different parallel grid cell,
      so banded == unbanded compact bitwise on f32 (and still bitwise in
      bf16; vs the oracle with the usual tolerance) -- across MaskSpecs,
      GQA, packed varlen.
  (b) the band partition is balanced: under a causal mask the LPT deal
      (the zigzag pairing, band_assignment) keeps per-band visible-tile
      totals within one tile, and padding placeholder steps are
      compute-free flag-0 steps that revisit the last real tiles.
  (c) split-KV forward partials fold through merge_partials to the
      single-pass result (the decode/ring merge contract, applied to the
      forward), including the short-q/long-kv shapes the split exists for.
  (d) the partitioned grid really is a partitioned grid: a band axis is
      present and `parallel`, and the auto policy engages it exactly for
      the small-BH regime (degrading to 1 band when BH fills the target).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import MaskSpec
from repro.kernels.ops import (
    _TARGET_PARALLEL_CELLS,
    default_forward_partitions,
    flash_attention_pallas,
    flash_attention_pallas_varlen_with_lse,
    flash_attention_pallas_with_lse,
)
from repro.kernels.ref import attention_reference
from repro.kernels.schedule import (
    STEP_ACTIVE,
    STEP_FIRST,
    STEP_LAST,
    band_assignment,
    build_partitioned_schedule,
    build_tile_schedule,
    kv_split_edges,
)

KEY = jax.random.PRNGKey(11)

SPECS = {
    "causal": MaskSpec(causal=True),
    "window": MaskSpec(causal=True, window=64),
    "sink": MaskSpec(causal=True, window=64, sink=16),
    "full": MaskSpec(),
}


def _mk(B, Sq, Sk, Hq, Hk, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
        jax.random.normal(ks[1], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[2], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[3], (B, Sq, Hq, D), dtype),
    )


def _mk_segments(B, S, seed=0):
    rng = np.random.default_rng(seed)
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(8, S - 8), 2, replace=False))
        seg[b, : cuts[0]] = 1
        seg[b, cuts[0] : cuts[1]] = 2
        seg[b, cuts[1] :] = 3 if b % 2 == 0 else 0
    return jnp.asarray(seg)


# ---------------------------------------------------------------------------
# (a) banded == unbanded, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
@pytest.mark.parametrize(
    "nb", [2, pytest.param(3, marks=pytest.mark.slow)]
)
def test_banded_bitwise_matches_unbanded(spec_name, nb):
    spec = SPECS[spec_name]
    B, Sq, Sk, Hq, Hk, D = 2, 192, 192, 4, 2, 32  # GQA group 2
    q, k, v, _ = _mk(B, Sq, Sk, Hq, Hk, D)
    kw = dict(block_q=64, block_kv=64, kv_splits=1)
    o1, l1 = flash_attention_pallas_with_lse(q, k, v, spec, num_q_bands=1, **kw)
    o2, l2 = flash_attention_pallas_with_lse(q, k, v, spec, num_q_bands=nb, **kw)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("spec_name", ["causal", pytest.param("full", marks=pytest.mark.slow)])
def test_banded_varlen_bitwise(spec_name):
    spec = SPECS[spec_name]
    B, S, Hq, Hk, D = 2, 192, 4, 2, 32
    q, k, v, _ = _mk(B, S, S, Hq, Hk, D)
    seg = _mk_segments(B, S)
    kw = dict(block_q=64, block_kv=64, kv_splits=1)
    o1, l1 = flash_attention_pallas_varlen_with_lse(q, k, v, seg, spec, num_q_bands=1, **kw)
    o2, l2 = flash_attention_pallas_varlen_with_lse(q, k, v, seg, spec, num_q_bands=3, **kw)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_banded_bf16():
    spec = MaskSpec(causal=True)
    q, k, v, _ = _mk(2, 128, 128, 4, 2, 64, jnp.bfloat16)
    o_ref, _ = attention_reference(q, k, v, spec)
    o1 = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64, num_q_bands=1)
    o2 = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64, num_q_bands=2)
    assert o2.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(o1, np.float32), np.asarray(o2, np.float32))
    np.testing.assert_allclose(
        np.asarray(o2, np.float32), np.asarray(o_ref, np.float32), atol=2e-2, rtol=2e-2
    )


def test_banded_grads_bitwise():
    """Bands are a forward-only regrouping: residuals (o, lse) are bitwise
    identical, and the backward kernels never see the band axis."""
    spec = MaskSpec(causal=True)
    q, k, v, do = _mk(2, 192, 192, 4, 2, 32)

    def grads(nb):
        f = lambda q, k, v: (
            flash_attention_pallas(
                q, k, v, spec, block_q=64, block_kv=64, num_q_bands=nb, kv_splits=1
            ) * do
        ).sum()
        return jax.grad(f, (0, 1, 2))(q, k, v)

    for a, b in zip(grads(1), grads(3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_banded_nondivisible_padding():
    """Sq=Sk=200 with 64-blocks: KV padding tiles stay masked under bands."""
    spec = MaskSpec(causal=True)
    q, k, v, _ = _mk(1, 200, 200, 2, 1, 32)
    o_ref, _ = attention_reference(q, k, v, spec)
    o = flash_attention_pallas(
        q, k, v, spec, block_q=64, block_kv=64, num_q_bands=4, kv_splits=1
    )
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# (b) band balance + placeholder-step contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t_q,nb", [(16, 4), (16, 3), (12, 5), (7, 2), (9, 4)])
def test_causal_band_balance_bound(t_q, nb):
    """Causal zigzag/LPT balance: per-band visible totals within one tile."""
    sched = build_partitioned_schedule(
        MaskSpec(causal=True), t_q, t_q, 64, 64, t_q * 64, num_q_bands=nb
    )
    assert sched.part_active.max() - sched.part_active.min() <= 1, sched.part_active
    assert sched.part_active.sum() == t_q * (t_q + 1) // 2


def test_band_assignment_covers_all_rows():
    bands = band_assignment((1, 2, 3, 4, 5, 6, 7, 8), 3)
    rows = sorted(r for b in bands for r in b)
    assert rows == list(range(8))
    assert all(b for b in bands)  # no empty band
    # fully-masked rows still spread (placeholder-step load, not 0)
    bands0 = band_assignment((0, 0, 0, 0), 2)
    assert all(len(b) == 2 for b in bands0)


def test_partition_placeholder_contract():
    """Padding steps are flags==0 and revisit the partition's last real
    (outer, inner) pair -- no compute, no fresh DMA; every q row inits and
    emits exactly once per kv split."""
    spec = MaskSpec(causal=True, window=128)
    t = 8
    sched = build_partitioned_schedule(spec, t, t, 64, 64, t * 64, num_q_bands=3, kv_splits=2)
    for p in range(sched.num_parts):
        flags = sched.flags[p]
        real = np.nonzero((flags & (STEP_FIRST | STEP_LAST | STEP_ACTIVE)) != 0)[0]
        last_real = real.max()
        tail = np.arange(last_real + 1, sched.n_steps)
        assert (flags[tail] == 0).all()
        assert (sched.outer[p, tail] == sched.outer[p, last_real]).all()
        assert (sched.inner[p, tail] == sched.inner[p, last_real]).all()
    # per split: every q row is owned by exactly one band -> one FIRST and
    # one LAST per (row, split)
    for s in range(sched.kv_splits):
        parts = [p for p in range(sched.num_parts) if sched.part_kv[p] == s]
        firsts = sum((sched.flags[p] & STEP_FIRST != 0).sum() for p in parts)
        lasts = sum((sched.flags[p] & STEP_LAST != 0).sum() for p in parts)
        assert firsts == t and lasts == t


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
def test_partitions_tile_the_oracle(spec_name):
    """Active steps across all partitions == the unbanded compact schedule
    == the _visible_pairs oracle, with no duplicates."""
    spec = SPECS[spec_name]
    t = 8
    flat = build_tile_schedule(spec, t, t, 64, 64, t * 64)
    sched = build_partitioned_schedule(spec, t, t, 64, 64, t * 64, num_q_bands=3, kv_splits=3)
    assert sched.n_active == flat.n_active
    act = sched.flags & STEP_ACTIVE != 0
    got = list(zip(sched.outer[act].tolist(), sched.inner[act].tolist()))
    ref = set(zip(flat.outer[flat.flags & STEP_ACTIVE != 0].tolist(),
                  flat.inner[flat.flags & STEP_ACTIVE != 0].tolist()))
    assert len(got) == len(set(got))  # each visible tile in exactly one partition
    assert set(got) == ref


def test_kv_split_edges_ceil_div():
    assert kv_split_edges(7, 3) == [(0, 3), (3, 5), (5, 7)]
    assert kv_split_edges(4, 4) == [(0, 1), (1, 2), (2, 3), (3, 4)]


# ---------------------------------------------------------------------------
# (c) split-KV forward == single pass (merge_partials roundtrip)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "full"])
@pytest.mark.parametrize(
    "kvs", [2, pytest.param(3, marks=pytest.mark.slow)]
)
def test_splitkv_matches_single_pass(spec_name, kvs):
    spec = SPECS[spec_name]
    q, k, v, _ = _mk(2, 192, 192, 4, 2, 32)
    kw = dict(block_q=64, block_kv=64, num_q_bands=1)
    o1, l1 = flash_attention_pallas_with_lse(q, k, v, spec, kv_splits=1, **kw)
    o2, l2 = flash_attention_pallas_with_lse(q, k, v, spec, kv_splits=kvs, **kw)
    np.testing.assert_allclose(o2, o1, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(l2, l1, atol=1e-5, rtol=1e-5)


def test_splitkv_short_q_long_kv():
    """The shape the split exists for: one q tile vs many kv tiles
    (cross-attention and causal chunked prefill)."""
    B, Sq, Sk, Hq, Hk, D = 1, 64, 512, 2, 2, 32
    q, k, v, _ = _mk(B, Sq, Sk, Hq, Hk, D)
    for spec in (MaskSpec(), MaskSpec(causal=True, q_offset=Sk - Sq)):
        o_ref, lse_ref = attention_reference(q, k, v, spec)
        o1, l1 = flash_attention_pallas_with_lse(
            q, k, v, spec, block_q=64, block_kv=64, num_q_bands=1, kv_splits=1
        )
        o4, l4 = flash_attention_pallas_with_lse(
            q, k, v, spec, block_q=64, block_kv=64, num_q_bands=1, kv_splits=4
        )
        np.testing.assert_allclose(o4, o1, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(l4, l1, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(o4, o_ref, atol=2e-3, rtol=1e-4)
    # auto policy engages the split here: 1 q tile, 8 kv tiles, BH = 2
    nb, ks = default_forward_partitions(2, 1, 8)
    assert nb == 1 and ks > 1


def test_splitkv_grads_match():
    spec = MaskSpec(causal=True)
    q, k, v, do = _mk(2, 192, 192, 4, 2, 32)

    def grads(kvs):
        f = lambda q, k, v: (
            flash_attention_pallas(
                q, k, v, spec, block_q=64, block_kv=64, num_q_bands=1, kv_splits=kvs
            ) * do
        ).sum()
        return jax.grad(f, (0, 1, 2))(q, k, v)

    for a, b in zip(grads(1), grads(3)):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_splitkv_varlen_matches_single_pass():
    spec = MaskSpec(causal=True)
    B, S = 2, 192
    q, k, v, _ = _mk(B, S, S, 4, 2, 32)
    seg = _mk_segments(B, S)
    kw = dict(block_q=64, block_kv=64, num_q_bands=1)
    o1, l1 = flash_attention_pallas_varlen_with_lse(q, k, v, seg, spec, kv_splits=1, **kw)
    o2, l2 = flash_attention_pallas_varlen_with_lse(q, k, v, seg, spec, kv_splits=3, **kw)
    np.testing.assert_allclose(o2, o1, atol=1e-5, rtol=1e-5)
    m = ~np.isneginf(np.asarray(l1))
    np.testing.assert_allclose(np.asarray(l2)[m], np.asarray(l1)[m], atol=1e-5, rtol=1e-5)
    assert np.array_equal(np.isneginf(np.asarray(l2)), ~m)  # padded rows stay -inf


# ---------------------------------------------------------------------------
# (d) grid shape + auto policy
# ---------------------------------------------------------------------------


def _pallas_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            yield eqn
        for sub in jax.core.jaxprs_in_params(eqn.params):
            yield from _pallas_eqns(sub.jaxpr if hasattr(sub, "jaxpr") else sub)


def test_banded_grid_shape_and_parallel_axis():
    """Regression: the banded launch has grid (BH, bands, n_steps_band)
    with the band axis `parallel` -- the paper's Figure 2 forward split
    realized in the grid, in ONE launch (not bands separate kernels)."""
    B, S, Hq, Hk, D, nb = 1, 192, 2, 1, 32, 3
    q = jnp.ones((B, S, Hq, D))
    k = jnp.ones((B, S, Hk, D))
    v = jnp.ones((B, S, Hk, D))
    jaxpr = jax.make_jaxpr(
        lambda q, k, v: flash_attention_pallas_with_lse(
            q, k, v, MaskSpec(causal=True), block_q=64, block_kv=64,
            num_q_bands=nb, kv_splits=1,
        )
    )(q, k, v)
    eqns = list(_pallas_eqns(jaxpr.jaxpr))
    assert len(eqns) == 1
    grid = eqns[0].params["grid_mapping"].grid
    sched = build_partitioned_schedule(
        MaskSpec(causal=True), 3, 3, 64, 64, S, num_q_bands=nb
    )
    assert grid == (B * Hq, nb, sched.n_steps), grid
    sem = eqns[0].params["compiler_params"]["mosaic"]["dimension_semantics"]
    assert sem == ("parallel", "parallel", "arbitrary")


def test_default_forward_partitions_policy():
    T = _TARGET_PARALLEL_CELLS
    # large BH: no bands, no padding cost
    assert default_forward_partitions(T, 16, 16) == (1, 1)
    assert default_forward_partitions(4 * T, 16, 16) == (1, 1)
    # small BH, long S: bands up to the target (capped at t_q)
    nb, ks = default_forward_partitions(4, 64, 64)
    assert 4 * nb >= T and ks == 1
    assert default_forward_partitions(1, 8, 8) == (8, 1)
    # short q: bands degrade to 1 (nothing to band)
    assert default_forward_partitions(4, 1, 1) == (1, 1)
    # single-q-tile long-kv corner: kv splits take over
    nb, ks = default_forward_partitions(2, 1, 32)
    assert nb == 1 and ks == 32
    # dense schedule / explicit override handled in ops._resolve_partitions
    from repro.kernels.ops import PallasFlashConfig, _resolve_partitions

    cfg = PallasFlashConfig(spec=MaskSpec(causal=True), schedule="dense", num_q_bands=2)
    with pytest.raises(ValueError):
        _resolve_partitions(cfg, {}, "dense", 4, 8, 8)
    cfg = PallasFlashConfig(spec=MaskSpec(causal=True), num_q_bands=5, kv_splits=2)
    # explicit knobs clamp to t_q and win over a tuned entry
    assert _resolve_partitions(cfg, {}, "compact", 4, 3, 8) == (3, 2)
    assert _resolve_partitions(
        cfg, {"num_q_bands": 1, "kv_splits": 1}, "compact", 4, 3, 8
    ) == (3, 2)
