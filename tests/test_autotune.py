"""ISSUE 6: the empirical knob autotuner and the timing fix it stands on.

Covers: tuned-cache round-trip + schema validation, the resolution
precedence order (explicit arg > tuned cache > heuristic) pinned as a
regression test, the committed tuned.json actually being consulted by an
all-``None`` PallasFlashConfig, bitwise-identical outputs for tuned vs
heuristic knobs on a fixed shape, block-size legalization, decode-split
resolution, timer sanity (fwd <= fwd+bwd from the shared interleaved
min-of-N helper -- the exact inversion the old mean-of-3 produced), and
the benchmark trajectory's tolerant load / dedupe / prune.
"""

import json
import pathlib
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import AttentionConfig, decode_attention
from repro.core.masks import MaskSpec
from repro.kernels import autotune
from repro.kernels.ops import (
    PallasFlashConfig,
    default_block_sizes,
    flash_attention_pallas,
    resolve_pallas_knobs,
)
from repro.kernels.ref import attention_reference
from repro.utils.timing import interleaved_timeit

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))  # for `import benchmarks.run`

CAUSAL = MaskSpec(causal=True)


@pytest.fixture(autouse=True)
def _fresh_cache_state(monkeypatch):
    """Isolate every test from the process-level load cache and env."""
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    monkeypatch.delenv(autotune.ENV_PATH, raising=False)
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _write_cache(path, entries):
    doc = autotune.new_doc("test", entries)
    with open(path, "w") as f:
        json.dump(doc, f)
    autotune.clear_cache()
    return str(path)


# ---------------------------------------------------------------------------
# Cache file: key format, schema, round-trip, tolerant load
# ---------------------------------------------------------------------------


def test_cache_key_roundtrip():
    key = autotune.cache_key("flash_pallas", True, 512, 4, 64, jnp.float32)
    assert key == "flash_pallas/causal=1/seq=512/heads=4/hd=64/dtype=float32"
    meta = autotune.parse_key(key)
    assert meta == dict(impl="flash_pallas", causal=True, seq=512, heads=4,
                        head_dim=64, dtype="float32")


def test_validate_doc_rejects_bad_schema():
    good_key = autotune.cache_key("flash_pallas", True, 128, 2, 32, "float32")
    autotune.validate_doc(autotune.new_doc("x", {good_key: {"block_q": 64}}))
    for bad in (
        [],  # not an object
        {"version": 99, "backend": "x", "entries": {}},  # wrong version
        {"version": 1, "entries": {}},  # missing backend
        {"version": 1, "backend": "x"},  # missing entries
        {"version": 1, "backend": "x", "entries": {"nonsense": {}}},  # bad key
        {"version": 1, "backend": "x",
         "entries": {good_key: {"blocksize": 64}}},  # unknown knob
        {"version": 1, "backend": "x",
         "entries": {good_key: {"block_q": "big"}}},  # mis-typed knob
        {"version": 1, "backend": "x",
         "entries": {good_key: {"schedule": "zigzag"}}},  # bad enum
        {"version": 1, "backend": "x",
         "entries": {good_key: {"block_q": 0}}},  # < 1
    ):
        with pytest.raises(ValueError):
            autotune.validate_doc(bad)


def test_save_load_roundtrip(tmp_path):
    key = autotune.cache_key("flash_pallas", False, 256, 4, 64, "float32")
    doc = autotune.new_doc("test", {key: {"block_q": 64, "block_kv": 64,
                                          "us_fwd": 12.5}})
    path = str(tmp_path / "tuned.json")
    autotune.save_cache(doc, path)
    loaded = autotune.load_cache(path)
    assert loaded["entries"] == doc["entries"]
    # lookup strips provenance, returns only knobs
    knobs = autotune.lookup("flash_pallas", False, 256, 4, 64, jnp.float32,
                            path=path)
    assert knobs == {"block_q": 64, "block_kv": 64}


def test_load_tolerant_on_corrupt_file(tmp_path, monkeypatch):
    path = tmp_path / "tuned.json"
    path.write_text('{"version": 1, "backend": "x", "entr')  # truncated
    with pytest.warns(UserWarning, match="invalid tuned cache"):
        doc = autotune.load_cache(str(path))
    assert doc["entries"] == {}  # disabled, not crashed
    # and resolution against the corrupt file falls back to pure
    # heuristics without raising
    monkeypatch.setenv(autotune.ENV_PATH, str(path))
    autotune.clear_cache()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        r = resolve_pallas_knobs(
            PallasFlashConfig(spec=CAUSAL), (1, 64, 2, 32), (1, 64, 2, 32)
        )
    assert r["tuned"] == {}


def test_missing_file_is_empty(tmp_path):
    doc = autotune.load_cache(str(tmp_path / "nope.json"))
    assert doc["entries"] == {}


# ---------------------------------------------------------------------------
# Lookup: exact key, nearest-shape fallback, mask-family guards
# ---------------------------------------------------------------------------


def test_lookup_nearest_shape(tmp_path):
    key = autotune.cache_key("flash_pallas", True, 256, 4, 64, "float32")
    path = _write_cache(tmp_path / "t.json", {key: {"block_q": 64}})
    # exact
    assert autotune.lookup("flash_pallas", True, 256, 4, 64, jnp.float32,
                           path=path) == {"block_q": 64}
    # nearest within the 2x radius, heads relax too
    assert autotune.lookup("flash_pallas", True, 320, 8, 64, jnp.float32,
                           path=path) == {"block_q": 64}
    # beyond the radius: miss
    assert autotune.lookup("flash_pallas", True, 1024, 4, 64, jnp.float32,
                           path=path) == {}
    # causal / head-dim / dtype never relax
    assert autotune.lookup("flash_pallas", False, 256, 4, 64, jnp.float32,
                           path=path) == {}
    assert autotune.lookup("flash_pallas", True, 256, 4, 128, jnp.float32,
                           path=path) == {}
    assert autotune.lookup("flash_pallas", True, 256, 4, 64, jnp.bfloat16,
                           path=path) == {}


def test_lookup_prefers_heads_match_then_seq(tmp_path):
    k1 = autotune.cache_key("flash_pallas", True, 512, 4, 64, "float32")
    k2 = autotune.cache_key("flash_pallas", True, 384, 8, 64, "float32")
    path = _write_cache(tmp_path / "t.json",
                        {k1: {"block_q": 512}, k2: {"block_q": 128}})
    # same heads wins over closer seq
    assert autotune.lookup("flash_pallas", True, 400, 4, 64, jnp.float32,
                           path=path) == {"block_q": 512}


def test_window_and_sink_specs_skip_cache(tmp_path, monkeypatch):
    key = autotune.cache_key("flash_pallas", True, 256, 2, 32, "float32")
    path = _write_cache(tmp_path / "t.json", {key: {"block_q": 64}})
    monkeypatch.setenv(autotune.ENV_PATH, path)
    shape = (1, 256, 2, 32)
    r = resolve_pallas_knobs(
        PallasFlashConfig(spec=MaskSpec(causal=True, window=64)), shape, shape
    )
    assert r["tuned"] == {} and r["block_q"] == 256  # heuristic, not 64


# ---------------------------------------------------------------------------
# Precedence: explicit arg > tuned cache > heuristic (the regression pin)
# ---------------------------------------------------------------------------


def test_precedence_order(tmp_path, monkeypatch):
    shape = (2, 256, 2, 32)
    key = autotune.cache_key("flash_pallas", True, 256, 2, 32, "float32")
    tuned_knobs = {"block_q": 64, "block_kv": 64, "schedule": "dense",
                   "bwd": "split", "num_q_bands": 1, "kv_splits": 1}
    path = _write_cache(tmp_path / "t.json", dict([(key, tuned_knobs)]))
    monkeypatch.setenv(autotune.ENV_PATH, path)

    # all-None knobs -> the tuned entry, verbatim
    r = resolve_pallas_knobs(PallasFlashConfig(spec=CAUSAL), shape, shape)
    for k, v in tuned_knobs.items():
        assert r[k] == v, (k, r)
    assert r["tuned"] == tuned_knobs

    # explicit args win over the cache, knob by knob
    r = resolve_pallas_knobs(
        PallasFlashConfig(spec=CAUSAL, block_q=128, schedule="compact"),
        shape, shape,
    )
    assert r["block_q"] == 128 and r["schedule"] == "compact"
    assert r["block_kv"] == 64 and r["bwd"] == "split"  # rest still tuned

    # use_tuned=False -> pure heuristics
    r = resolve_pallas_knobs(
        PallasFlashConfig(spec=CAUSAL, use_tuned=False), shape, shape
    )
    bq_def, bk_def = default_block_sizes(256, 256, 32)
    assert (r["block_q"], r["block_kv"]) == (bq_def, bk_def)
    assert r["schedule"] == "compact" and r["bwd"] == "fused"
    assert r["tuned"] == {}

    # env escape hatch disables globally
    monkeypatch.setenv(autotune.ENV_DISABLE, "0")
    r = resolve_pallas_knobs(PallasFlashConfig(spec=CAUSAL), shape, shape)
    assert r["tuned"] == {} and r["schedule"] == "compact"


def test_committed_cache_consulted_by_all_none_config():
    """Acceptance: PallasFlashConfig with every knob None consults the
    COMMITTED tuned.json (no env overrides, no monkeypatching)."""
    doc = autotune.load_cache(autotune.DEFAULT_PATH)
    keys = [k for k in doc["entries"] if k.startswith("flash_pallas/")]
    assert keys, "committed tuned.json must ship flash_pallas entries"
    for key in keys:
        m = autotune.parse_key(key)
        shape = (2, m["seq"], m["heads"], m["head_dim"])
        r = resolve_pallas_knobs(
            PallasFlashConfig(spec=MaskSpec(causal=m["causal"])),
            shape, shape, dtype=m["dtype"],
        )
        entry = autotune.lookup(m["impl"], m["causal"], m["seq"], m["heads"],
                                m["head_dim"], m["dtype"],
                                path=autotune.DEFAULT_PATH)
        assert r["tuned"] == entry and entry, key
        for knob in ("block_q", "block_kv", "schedule"):
            if knob in entry:
                assert r[knob] == entry[knob], (key, knob, r)


# ---------------------------------------------------------------------------
# Tuned vs heuristic outputs
# ---------------------------------------------------------------------------


def test_tuned_vs_heuristic_bitwise(tmp_path, monkeypatch):
    """On a fixed shape, tuned knobs that only re-tile/band the q axis give
    BITWISE the heuristic's forward output (per-row kv visit order is
    unchanged); grads stay allclose."""
    B, S, H, D = 2, 256, 2, 32
    bq_def, bk_def = default_block_sizes(S, S, D)
    key = autotune.cache_key("flash_pallas", True, S, H, D, "float32")
    path = _write_cache(
        tmp_path / "t.json",
        {key: {"block_q": 64, "block_kv": bk_def, "num_q_bands": 2,
               "schedule": "compact", "bwd": "fused"}},
    )
    monkeypatch.setenv(autotune.ENV_PATH, path)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(k_, (B, S, H, D), jnp.float32) for k_ in ks)
    shape = (B, S, H, D)
    r_tuned = resolve_pallas_knobs(PallasFlashConfig(spec=CAUSAL), shape, shape)
    r_heur = resolve_pallas_knobs(
        PallasFlashConfig(spec=CAUSAL, use_tuned=False), shape, shape
    )
    assert r_tuned["block_q"] == 64 and r_heur["block_q"] == bq_def
    o_tuned = flash_attention_pallas(q, k, v, CAUSAL, use_tuned=True)
    o_heur = flash_attention_pallas(q, k, v, CAUSAL, use_tuned=False)
    assert np.array_equal(np.asarray(o_tuned), np.asarray(o_heur))

    def loss(fn_use_tuned):
        return jax.grad(lambda q: flash_attention_pallas(
            q, k, v, CAUSAL, use_tuned=fn_use_tuned).sum())(q)

    np.testing.assert_allclose(np.asarray(loss(True)), np.asarray(loss(False)),
                               atol=1e-5, rtol=1e-5)


def test_tuned_knobs_match_reference_oracle():
    """Whatever the committed cache resolves to must still be exact."""
    doc = autotune.load_cache(autotune.DEFAULT_PATH)
    keys = [k for k in doc["entries"]
            if k.startswith("flash_pallas/") and "/seq=256/" in k]
    assert keys
    m = autotune.parse_key(keys[0])
    spec = MaskSpec(causal=m["causal"])
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(k_, (2, m["seq"], m["heads"], m["head_dim"]),
                                 jnp.float32) for k_ in ks)
    o = flash_attention_pallas(q, k, v, spec)  # all knobs None -> tuned
    o_ref = attention_reference(q, k, v, spec)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Block-size legalization (satellite: no silent mis-padding)
# ---------------------------------------------------------------------------


def test_block_legalization_rounds_and_warns():
    shape = (1, 512, 2, 32)
    with pytest.warns(UserWarning, match="block_q=100 is not legal"):
        r = resolve_pallas_knobs(
            PallasFlashConfig(spec=CAUSAL, block_q=100, use_tuned=False),
            shape, shape,
        )
    assert r["block_q"] == 104  # rounded up to the 8-sublane contract
    with pytest.warns(UserWarning, match="block_kv=4096"):
        r = resolve_pallas_knobs(
            PallasFlashConfig(spec=CAUSAL, block_kv=4096, use_tuned=False),
            shape, shape,
        )
    assert r["block_kv"] == 512  # clamped to the padded sequence


@pytest.mark.parametrize("bad", [0, -8, 2.5, "128", True])
def test_block_legalization_rejects_garbage(bad):
    shape = (1, 128, 2, 32)
    with pytest.raises(ValueError):
        resolve_pallas_knobs(
            PallasFlashConfig(spec=CAUSAL, block_q=bad, use_tuned=False),
            shape, shape,
        )


def test_misaligned_explicit_block_still_exact():
    """A legalized (rounded) explicit block must produce oracle-exact
    output -- the pre-fix behavior let block=100 corrupt the padding."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(k_, (1, 200, 2, 32), jnp.float32)
               for k_ in ks)
    with pytest.warns(UserWarning):
        o = flash_attention_pallas(q, k, v, CAUSAL, block_q=100, block_kv=60,
                                   use_tuned=False)
    o_ref = attention_reference(q, k, v, CAUSAL)[0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Decode-split resolution
# ---------------------------------------------------------------------------


def test_decode_splits_resolution(tmp_path, monkeypatch):
    key = autotune.cache_key("flash_decode", True, 128, 2, 32, "float32")
    path = _write_cache(tmp_path / "t.json", {key: {"num_splits": 2}})
    monkeypatch.setenv(autotune.ENV_PATH, path)
    assert autotune.resolve_decode_splits(128, 2, 32, jnp.float32) == 2
    assert autotune.resolve_decode_splits(
        128, 2, 32, jnp.float32, use_tuned=False) == 8
    # and the attention-layer decode path consumes it (None -> tuned)
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(kq, (1, 1, 2, 32), jnp.float32)
    kc = jax.random.normal(kk, (1, 128, 2, 32), jnp.float32)
    vc = jax.random.normal(kv, (1, 128, 2, 32), jnp.float32)
    lens = jnp.array([100], jnp.int32)
    o_tuned = decode_attention(q, kc, vc, lens, AttentionConfig())
    o_explicit = decode_attention(
        q, kc, vc, lens, AttentionConfig(decode_splits=2))
    assert np.array_equal(np.asarray(o_tuned), np.asarray(o_explicit))


# ---------------------------------------------------------------------------
# Timer sanity (the satellite for the original inversion bug)
# ---------------------------------------------------------------------------


def test_timer_fwd_not_slower_than_fwdbwd():
    """The shared interleaved min-of-N helper must never report a strict
    subset of the work as slower: fwd <= fwd+bwd on a toy fn. This is the
    exact inversion BENCH_attn.json recorded under the old single-warmup
    mean-of-3 (`ref/causal=0/seq=512`: 438ms fwd vs 356ms fwd+bwd)."""
    x = jnp.ones((384, 384), jnp.float32) * 0.01
    fwd = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    fwdbwd = jax.jit(jax.grad(lambda x: jnp.tanh(x @ x).sum()))
    best = interleaved_timeit({"fwd": fwd, "fwdbwd": fwdbwd}, x, iters=10)
    assert best["fwd"] <= best["fwdbwd"], best


def test_rebaselined_trajectory_has_no_inversions():
    """Acceptance: the committed BENCH_attn.json has no fwd-slower-than-
    fwd+bwd inversion for any impl/shape (fig4/fig5 and sched_cmp pairs)."""
    rows = json.loads((ROOT / "BENCH_attn.json").read_text())
    by_key = {(r["bench"], r["config"]): r["us_per_call"] for r in rows}
    pairs = [
        (("fig5_fwd", c), ("fig4_fwdbwd", c))
        for (b, c) in by_key if b == "fig5_fwd"
    ] + [
        (("sched_cmp_fwd", c), ("sched_cmp_fwdbwd", c.replace("fwd", "fwdbwd")))
        for (b, c) in by_key if b == "sched_cmp_fwd"
    ]
    assert pairs, "trajectory must contain fwd/fwdbwd pairs"
    for fwd_key, bwd_key in pairs:
        if bwd_key not in by_key:
            continue
        assert by_key[fwd_key] <= by_key[bwd_key], (
            "fwd slower than fwd+bwd -- the timing bug is back", fwd_key,
            by_key[fwd_key], by_key[bwd_key],
        )


# ---------------------------------------------------------------------------
# Benchmark trajectory durability (run.py satellites)
# ---------------------------------------------------------------------------


def test_trajectory_load_tolerant_and_dedupes(tmp_path, capsys):
    from benchmarks.run import _load_existing

    path = tmp_path / "bench.json"
    # corrupt file: backed up, not fatal
    path.write_text('[{"bench": "a", "config": "x", "us')
    assert _load_existing(str(path)) == []
    assert not path.exists() and (tmp_path / "bench.json.bad").exists()
    # wrong shape: also backed up
    path.write_text('{"not": "a list"}')
    assert _load_existing(str(path)) == []
    # duplicate (bench, config): last write wins
    rows = [
        {"bench": "a", "config": "x", "us_per_call": 1.0},
        {"bench": "a", "config": "x", "us_per_call": 2.0},
        {"bench": "b", "config": "y", "us_per_call": 3.0},
    ]
    path.write_text(json.dumps(rows))
    out = _load_existing(str(path))
    assert sorted((r["bench"], r["us_per_call"]) for r in out) == [
        ("a", 2.0), ("b", 3.0),
    ]
