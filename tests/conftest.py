"""Shared test fixtures. NOTE: no XLA_FLAGS here by design -- smoke tests
and benches must see 1 device; multi-device tests spawn subprocesses.

Test tiers: the default run skips tests marked ``@pytest.mark.slow`` (the
exhaustive kernel sweeps), keeping tier-1 fast; run the slow tier with
``-m slow`` (or everything with ``-m "slow or not slow"``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: exhaustive sweeps excluded from the fast tier-1 run"
    )
    # Default to the fast tier: equivalent of addopts = -m "not slow", but
    # kept here so the repo needs no ini file and -m on the CLI still wins.
    # Explicit node ids (path::test) bypass the default so a slow test can
    # be run by naming it, without remembering -m slow.
    if not config.option.markexpr and not any("::" in a for a in config.args):
        config.option.markexpr = "not slow"


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_allclose(a, b, atol=1e-5, rtol=1e-5, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol, rtol=rtol,
        err_msg=msg,
    )


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)
