"""Shared test fixtures. NOTE: no XLA_FLAGS here by design -- smoke tests
and benches must see 1 device; multi-device tests spawn subprocesses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_allclose(a, b, atol=1e-5, rtol=1e-5, msg=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), atol=atol, rtol=rtol,
        err_msg=msg,
    )


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)
