"""Pallas kernels (interpret mode) vs the pure-jnp oracle: the brief's
per-kernel shape/dtype sweep. Covers fwd, both bwd kernels (via the
custom VJP), GQA grouping, padding, windows, sinks, chunked-prefill
offsets, and block-size sensitivity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import MaskSpec
from repro.kernels.ops import (
    flash_attention_pallas,
    flash_attention_pallas_with_lse,
)
from repro.kernels.ref import attention_reference

KEY = jax.random.PRNGKey(1)


def _mk(B, Sq, Sk, Hq, Hk, D, dtype):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
        jax.random.normal(ks[1], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[2], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[3], (B, Sq, Hq, D), dtype),
    )


@pytest.fixture(scope="module")
def mk_cache():
    """Share (q, k, v, do) across the sweep's spec axis (3x fewer RNG+device
    rounds) -- tests must not mutate the arrays."""
    cache = {}

    def get(*shape_dtype):
        if shape_dtype not in cache:
            cache[shape_dtype] = _mk(*shape_dtype)
        return cache[shape_dtype]

    return get


@pytest.fixture(scope="module")
def ref_cache(mk_cache):
    """Share the dense-oracle (o, lse) per (shape, spec) across tests."""
    cache = {}

    def get(shape, spec, dtype=jnp.float32):
        key = (shape, spec, dtype)
        if key not in cache:
            q, k, v, _ = mk_cache(*shape, dtype)
            cache[key] = attention_reference(q, k, v, spec)
        return cache[key]

    return get


SHAPES = [
    (2, 128, 128, 4, 4, 64),
    (2, 128, 128, 4, 2, 64),
    (2, 200, 200, 4, 1, 32),  # non-divisible seq -> kernel padding path
    (1, 128, 256, 4, 4, 64),  # cross shape
    (1, 256, 256, 2, 2, 128),  # d=128
]
SPECS = [MaskSpec(causal=True), MaskSpec(), MaskSpec(causal=True, window=64)]
# Fast tier: every shape under causal, the canonical shapes under the other
# specs; the full cross-product runs with -m slow.
_SWEEP = [
    pytest.param(s, i, marks=pytest.mark.slow) if (i > 0 and si >= 3) else (s, i)
    for i in range(len(SPECS))
    for si, s in enumerate(SHAPES)
]


@pytest.mark.parametrize("shape,spec_i", _SWEEP, ids=[f"{i}-{s}" for i in range(len(SPECS)) for s in SHAPES])
def test_fwd_sweep(shape, spec_i, mk_cache, ref_cache):
    B, Sq, Sk, Hq, Hk, D = shape
    spec = SPECS[spec_i]
    q, k, v, _ = mk_cache(B, Sq, Sk, Hq, Hk, D, jnp.float32)
    o_ref, lse_ref = ref_cache(shape, spec)
    o, lse = flash_attention_pallas_with_lse(q, k, v, spec, block_q=64, block_kv=64)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)
    mask = ~np.isneginf(np.asarray(lse_ref))
    np.testing.assert_allclose(
        np.asarray(lse)[mask], np.asarray(lse_ref)[mask], atol=1e-4, rtol=1e-5
    )


@pytest.mark.parametrize("spec", [
    MaskSpec(causal=True),
    MaskSpec(causal=True, window=64),
    pytest.param(MaskSpec(causal=True, window=64, sink=16), marks=pytest.mark.slow),
    pytest.param(MaskSpec(), marks=pytest.mark.slow),
], ids=["causal", "window", "sink", "full"])
def test_bwd_sweep(spec, mk_cache):
    B, Sq, Sk, Hq, Hk, D = 2, 192, 192, 4, 2, 32
    q, k, v, do = mk_cache(B, Sq, Sk, Hq, Hk, D, jnp.float32)
    f = lambda q, k, v: (flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64) * do).sum()
    g = lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum()
    for a, b in zip(jax.grad(f, (0, 1, 2))(q, k, v), jax.grad(g, (0, 1, 2))(q, k, v)):
        np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_block_size_invariance(bq, bk, mk_cache, ref_cache):
    """Output must be exactly invariant to the tile schedule."""
    shape = (1, 256, 256, 2, 2, 64)
    q, k, v, _ = mk_cache(*shape, jnp.float32)
    spec = MaskSpec(causal=True)
    o_ref, _ = ref_cache(shape, spec)
    o = flash_attention_pallas(q, k, v, spec, block_q=bq, block_kv=bk)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)


def test_bf16_kernel(mk_cache):
    q, k, v, _ = mk_cache(2, 128, 128, 4, 2, 64, jnp.bfloat16)
    spec = MaskSpec(causal=True)
    o_ref, _ = attention_reference(q, k, v, spec)
    o = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_chunked_prefill_offset():
    """Computing rows [128:192) with q_offset must equal the full result."""
    q, k, v, _ = _mk(1, 192, 192, 2, 2, 32, jnp.float32)
    spec = MaskSpec(causal=True)
    o_full, _ = attention_reference(q, k, v, spec)
    o_chunk = flash_attention_pallas(
        q[:, 128:], k, v, MaskSpec(causal=True, q_offset=128), block_q=32, block_kv=32
    )
    np.testing.assert_allclose(o_chunk, o_full[:, 128:], atol=3e-5, rtol=1e-4)


def test_pallas_matches_xla_flash_exactly_same_blocks(mk_cache):
    from repro.core.flash import flash_attention as flash_xla

    q, k, v, _ = mk_cache(2, 128, 128, 4, 2, 64, jnp.float32)
    spec = MaskSpec(causal=True)
    o_p = flash_attention_pallas(q, k, v, spec, block_q=64, block_kv=64)
    o_x = flash_xla(q, k, v, spec, block_q=64, block_kv=64)
    np.testing.assert_allclose(o_p, o_x, atol=2e-6, rtol=1e-6)
