"""Fused one-pass backward (kernels/flash_bwd.flash_bwd_fused).

Claims (ISSUE 4 / DESIGN.md Section 2):
  (a) ``bwd="fused"`` is semantics-free: gradients are BITWISE equal to the
      split baseline on f32 -- both run the same tile updates in the same
      (kv-ascending) accumulation order -- and atol-close on bf16, across
      specs x schedules x varlen x GQA;
  (b) launch accounting: ``jax.grad`` over ``flash_attention_pallas``
      contains exactly 2 pallas_calls in fused mode (fwd + fused bwd) and
      4 in split mode (fwd + delta + dkv + dq);
  (c) the kv-major schedule's STEP_QFIRST / STEP_QLAST bits mark each q
      tile's first/last visit exactly once -- including q tiles no visible
      step streams, which get tail placeholders so their dq block is still
      zeroed (no NaN from the uninitialized revisited output);
  (d) the ring shard-backward entry (`flash_attention_pallas_shard_bwd`)
      dispatches both modes and they agree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import MaskSpec
from repro.kernels.ops import (
    default_block_sizes,
    flash_attention_pallas,
    flash_attention_pallas_shard_bwd,
    flash_attention_pallas_varlen,
    flash_attention_pallas_with_lse,
)
from repro.kernels.ref import attention_reference
from repro.kernels.schedule import (
    STEP_ACTIVE,
    STEP_QFIRST,
    STEP_QLAST,
    build_tile_schedule,
)

KEY = jax.random.PRNGKey(11)

SPECS = {
    "causal": MaskSpec(causal=True),
    "window": MaskSpec(causal=True, window=64),
    "sink": MaskSpec(causal=True, window=64, sink=16),
    "full": MaskSpec(),
}


def _mk(B, Sq, Sk, Hq, Hk, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
        jax.random.normal(ks[1], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[2], (B, Sk, Hk, D), dtype),
        jax.random.normal(ks[3], (B, Sq, Hq, D), dtype),
    )


def _grads(q, k, v, do, spec, bwd, schedule="compact", segment_ids=None):
    def loss(q, k, v):
        if segment_ids is not None:
            o = flash_attention_pallas_varlen(
                q, k, v, segment_ids, spec, block_q=64, block_kv=64,
                schedule=schedule, bwd=bwd,
            )
        else:
            o = flash_attention_pallas(
                q, k, v, spec, block_q=64, block_kv=64,
                schedule=schedule, bwd=bwd,
            )
        return (o * do).sum()

    return jax.grad(loss, (0, 1, 2))(q, k, v)


# ---------------------------------------------------------------------------
# (a) fused == split: bitwise on f32, atol on bf16
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
def test_fused_matches_split_bitwise_f32(spec_name):
    spec = SPECS[spec_name]
    q, k, v, do = _mk(2, 192, 192, 4, 2, 32)  # GQA group 2
    g_f = _grads(q, k, v, do, spec, "fused")
    g_s = _grads(q, k, v, do, spec, "split")
    for a, b, name in zip(g_f, g_s, "qkv"):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"d{name}/{spec_name}"
        )
    g_ref = jax.grad(
        lambda q, k, v: (attention_reference(q, k, v, spec)[0] * do).sum(), (0, 1, 2)
    )(q, k, v)
    for a, r in zip(g_f, g_ref):
        np.testing.assert_allclose(a, r, atol=2e-3, rtol=1e-3)


def test_fused_matches_split_dense_schedule():
    spec = SPECS["causal"]
    q, k, v, do = _mk(2, 192, 192, 4, 2, 32)
    g_f = _grads(q, k, v, do, spec, "fused", schedule="dense")
    g_s = _grads(q, k, v, do, spec, "split", schedule="dense")
    for a, b, name in zip(g_f, g_s, "qkv"):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=f"d{name}")
    # and dense-fused == compact-fused (same tile updates, same order)
    g_c = _grads(q, k, v, do, spec, "fused")
    for a, c in zip(g_f, g_c):
        np.testing.assert_allclose(a, c, atol=1e-5, rtol=1e-5)


def test_fused_matches_split_bf16():
    spec = SPECS["causal"]
    q, k, v, do = _mk(2, 128, 128, 4, 2, 64, jnp.bfloat16)
    g_f = _grads(q, k, v, do, spec, "fused")
    g_s = _grads(q, k, v, do, spec, "split")
    for a, b, name in zip(g_f, g_s, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-2, rtol=1e-2, err_msg=f"d{name}",
        )


@pytest.mark.parametrize(
    "spec_name", ["causal", pytest.param("full", marks=pytest.mark.slow)]
)
def test_fused_varlen_matches_split(spec_name):
    spec = SPECS[spec_name]
    B, S = 2, 192
    q, k, v, do = _mk(B, S, S, 4, 2, 32)
    rng = np.random.default_rng(5)
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(8, S - 8), 2, replace=False))
        seg[b, : cuts[0]] = 1
        seg[b, cuts[0] : cuts[1]] = 2
        seg[b, cuts[1] :] = 3 if b % 2 == 0 else 0
    seg = jnp.asarray(seg)
    g_f = _grads(q, k, v, do, spec, "fused", segment_ids=seg)
    g_s = _grads(q, k, v, do, spec, "split", segment_ids=seg)
    for a, b, name in zip(g_f, g_s, "qkv"):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"d{name}/{spec_name}"
        )
    g_ref = jax.grad(
        lambda q, k, v: (
            attention_reference(q, k, v, spec, segment_ids=seg)[0] * do
        ).sum(),
        (0, 1, 2),
    )(q, k, v)
    for a, r in zip(g_f, g_ref):
        np.testing.assert_allclose(a, r, atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# (b) launch-count regression: 3 bwd launches -> 1
# ---------------------------------------------------------------------------


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_pallas_calls(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
    return n


@pytest.mark.parametrize("bwd,expected", [("fused", 2), ("split", 4)])
def test_fwdbwd_launch_count(bwd, expected):
    q, k, v, do = _mk(1, 128, 128, 2, 1, 32)
    spec = MaskSpec(causal=True)

    def loss(q, k, v):
        return (
            flash_attention_pallas(
                q, k, v, spec, block_q=64, block_kv=64, bwd=bwd
            ) * do
        ).sum()

    jaxpr = jax.make_jaxpr(jax.grad(loss, (0, 1, 2)))(q, k, v)
    n = _count_pallas_calls(jaxpr.jaxpr)
    assert n == expected, f"bwd={bwd}: expected {expected} pallas_calls, got {n}"


# ---------------------------------------------------------------------------
# (c) STEP_QFIRST / STEP_QLAST schedule bits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["causal", "window", "sink", "full"])
def test_qrow_flags_cover_every_q_tile_once(spec_name):
    spec = SPECS[spec_name]
    t = 16
    sched = build_tile_schedule(spec, t, t, 128, 128, t * 128, kv_major=True)
    qfirst = sched.flags & STEP_QFIRST != 0
    qlast = sched.flags & STEP_QLAST != 0
    # every q tile gets exactly one QFIRST and one QLAST step...
    assert sorted(sched.inner[qfirst].tolist()) == list(range(t))
    assert sorted(sched.inner[qlast].tolist()) == list(range(t))
    # ...and they bracket all of that tile's visits (kv runs ascend).
    for b in range(t):
        steps = np.nonzero(sched.inner == b)[0]
        assert sched.flags[steps[0]] & STEP_QFIRST
        assert sched.flags[steps[-1]] & STEP_QLAST
    # q-major schedules don't carry the bits (q rows own their runs there).
    qmaj = build_tile_schedule(spec, t, t, 128, 128, t * 128)
    assert not (qmaj.flags & (STEP_QFIRST | STEP_QLAST)).any()


def test_qrow_flags_unvisited_q_tiles_get_placeholders():
    """A q row that attends nothing (window far past the KV) still needs its
    dq block zeroed: tail placeholders carry QFIRST without ACTIVE."""
    spec = MaskSpec(causal=True, window=64, q_offset=4096)
    t = 4
    sched = build_tile_schedule(spec, t, t, 64, 64, t * 64, kv_major=True)
    assert sched.n_active == 0  # every tile is empty under this spec
    qfirst = sched.flags & STEP_QFIRST != 0
    assert sorted(sched.inner[qfirst].tolist()) == list(range(t))
    assert not (sched.flags[qfirst] & STEP_ACTIVE).any()


def test_fused_empty_spec_grads_are_zero_not_nan():
    """End-to-end over the placeholder path: all-masked attention has zero
    gradients, and the revisited dq output must not leak NaN."""
    spec = MaskSpec(causal=True, window=64, q_offset=4096)
    q, k, v, do = _mk(1, 128, 128, 2, 1, 32)
    for bwd in ("fused", "split"):
        g = _grads(q, k, v, do, spec, bwd)
        for a, name in zip(g, "qkv"):
            np.testing.assert_array_equal(
                np.asarray(a), 0.0, err_msg=f"d{name}/{bwd}"
            )


# ---------------------------------------------------------------------------
# (d) shard backward entry (the ring path) dispatches both modes
# ---------------------------------------------------------------------------


def test_shard_bwd_fused_matches_split():
    spec = MaskSpec(causal=True)
    q, k, v, do = _mk(2, 128, 128, 4, 2, 32)
    o, lse = flash_attention_pallas_with_lse(q, k, v, spec, block_q=64, block_kv=64)
    outs = {
        bwd: flash_attention_pallas_shard_bwd(
            q, k, v, o, lse, do, spec, block_q=64, block_kv=64, bwd=bwd
        )
        for bwd in ("fused", "split")
    }
    for a, b, name in zip(outs["fused"], outs["split"], ("dq", "dk", "dv")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6, err_msg=name
        )


# ---------------------------------------------------------------------------
# shape-aware default block sizes
# ---------------------------------------------------------------------------


def test_fused_falls_back_to_split_when_delta_scratch_too_big():
    """The fused delta scratch is O(G * Sqp) VMEM; past the budget the
    backward must silently degrade to split (delta in HBM) instead of
    blowing VMEM on real TPUs."""
    from repro.kernels.ops import _FUSED_DELTA_VMEM_BUDGET, _resolve_bwd

    assert _resolve_bwd("fused", 1, 128) == "fused"
    assert _resolve_bwd("fused", 1, _FUSED_DELTA_VMEM_BUDGET // 4) == "fused"
    assert _resolve_bwd("fused", 1, _FUSED_DELTA_VMEM_BUDGET // 4 + 8) == "split"
    assert _resolve_bwd("fused", 8, 128 * 1024) == "split"  # GQA multiplies
    assert _resolve_bwd("split", 1, 128) == "split"


def test_default_block_sizes_table():
    assert default_block_sizes(4096, 4096, 64) == (512, 512)
    assert default_block_sizes(4096, 4096, 256) == (512, 256)  # scratch diet
    assert default_block_sizes(4096, 4096, 512) == (256, 128)
    # clamped to the (8-aligned) padded sequence length
    assert default_block_sizes(200, 200, 64) == (200, 200)
    assert default_block_sizes(100, 4096, 64) == (104, 512)


def test_default_blocks_run_end_to_end():
    """block_q/block_kv omitted entirely: the heuristic path must be exact."""
    spec = MaskSpec(causal=True)
    q, k, v, do = _mk(1, 200, 200, 2, 1, 32)
    o = flash_attention_pallas(q, k, v, spec)
    o_ref, _ = attention_reference(q, k, v, spec)
    np.testing.assert_allclose(o, o_ref, atol=3e-5, rtol=1e-4)
    g = jax.grad(lambda q: (flash_attention_pallas(q, k, v, spec) * do).sum())(q)
    g_ref = jax.grad(
        lambda q: (attention_reference(q, k, v, spec)[0] * do).sum()
    )(q)
    np.testing.assert_allclose(g, g_ref, atol=2e-3, rtol=1e-3)
