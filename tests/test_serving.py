"""Serving integration: engine vs direct decode, continuous batching,
split-KV decode consistency across cache lengths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.launch.steps import build_prefill_step, build_serve_step
from repro.models import lm
from repro.serving.engine import Request, ServingEngine

ATTN = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64, decode_splits=2)


@pytest.fixture(scope="module")
def model():
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_prefill_matches_forward(model):
    """Prefill's last-position logits == full forward's last position."""
    cfg, params = model
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 100, (2, 24)), jnp.int32)
    h, _, _ = lm.forward(cfg, params, tokens, ATTN)
    logits_fwd = lm.logits_from_hidden(cfg, params, h[:, -1:])
    prefill = build_prefill_step(cfg, ATTN, cache_size=64)
    tok, _, _ = prefill(params, {"inputs": tokens})
    expect = jnp.argmax(logits_fwd[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(expect))


def test_decode_matches_incremental_forward(model):
    """Greedy decode via caches == greedy re-forward over the grown prompt."""
    cfg, params = model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 100, (1, 8)).astype(np.int32)
    prefill = jax.jit(build_prefill_step(cfg, ATTN, cache_size=64))
    step = jax.jit(build_serve_step(cfg, ATTN))

    tok, caches, lens = prefill(params, {"inputs": jnp.asarray(prompt)})
    seq = list(prompt[0]) + [int(tok[0, 0])]
    for _ in range(6):
        tok, caches = step(params, tok, caches, lens)
        lens = lens + 1
        seq.append(int(tok[0, 0]))

    # oracle: recompute each next token by full forward
    oracle = list(prompt[0])
    for i in range(7):
        t = jnp.asarray(np.asarray(oracle, np.int32)[None])
        h, _, _ = lm.forward(cfg, params, t, ATTN)
        logits = lm.logits_from_hidden(cfg, params, h[:, -1:])
        oracle.append(int(jnp.argmax(logits[..., : cfg.vocab_size], -1)[0, 0]))
    assert seq == oracle, (seq, oracle)


def test_engine_batching_consistency(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, ATTN, max_batch=2, cache_size=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[3 + i, 5, 7], max_new_tokens=4))
    done = eng.run(max_ticks=100)
    assert sorted(done) == [0, 1, 2]
    solo = ServingEngine(cfg, params, ATTN, max_batch=1, cache_size=64)
    solo.submit(Request(rid=9, prompt=[3, 5, 7], max_new_tokens=4))
    ref = solo.run(max_ticks=50)[9]
    assert ref.generated == done[0].generated


def test_engine_prompt_bucketing(model):
    """Admission pads prompts to prompt_pad buckets: one prefill compilation
    serves every length in the bucket, and the padded prefill generates
    exactly what unpadded (prompt_pad=1) admission generates."""
    cfg, params = model
    prompts = [[5, 7], [3, 5, 7], [2, 4, 6, 8, 10], [1] * 7]
    eng = ServingEngine(cfg, params, ATTN, max_batch=1, cache_size=64,
                        prompt_pad=16)
    exact = ServingEngine(cfg, params, ATTN, max_batch=1, cache_size=64,
                          prompt_pad=1)
    assert eng._bucket and not exact._bucket
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=4))
        exact.submit(Request(rid=i, prompt=list(p), max_new_tokens=4))
    done = eng.run(max_ticks=100)
    done_exact = exact.run(max_ticks=100)
    for i in range(len(prompts)):
        assert done[i].generated == done_exact[i].generated, i
    # 4 prompt lengths, one 16-wide bucket -> exactly one prefill compile;
    # the unbucketed engine compiled once per distinct length.
    assert eng._prefill._cache_size() == 1
    assert exact._prefill._cache_size() == len({len(p) for p in prompts})


def test_engine_slot_reuse(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, ATTN, max_batch=1, cache_size=64)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=3))
    eng.submit(Request(rid=1, prompt=[1, 2], max_new_tokens=3))
    done = eng.run(max_ticks=60)
    # identical prompts through the same (reused) slot must match
    assert done[0].generated == done[1].generated
