"""Unit tests for the roofline/perf tooling: the trip-aware HLO walker's
byte model, the analytic kernel-traffic formula, and the roofline algebra.
These guard the §Perf measurement chain itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.utils import flops as F
from repro.utils.hlo_analysis import Roofline
from repro.utils.hlo_walker import HloModule


def _walk(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return HloModule(hlo).entry_cost()


def test_walker_counts_scan_trips():
    """A scan of 10 matmuls must report ~10x the FLOPs of one matmul
    (XLA's own cost_analysis counts the body once -- the walker's reason
    for existing)."""
    a = jnp.ones((64, 64), jnp.float32)

    def one(x):
        return x @ a

    def scanned(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ a, None), x, None, length=10)
        return y

    c1 = _walk(one, a)
    c10 = _walk(scanned, a)
    assert c1.flops > 0
    ratio = c10.flops / c1.flops
    assert 9 <= ratio <= 11, ratio


def test_walker_flash_tag_attribution():
    """bytes inside a named_scope('fa2scan') scan land in flash_bytes."""
    a = jnp.ones((32, 32), jnp.float32)

    def f(x):
        with jax.named_scope("fa2scan"):
            y, _ = jax.lax.scan(lambda c, _: (c @ a, None), x, None, length=4)
        return y

    c = _walk(f, a)
    assert c.flash_bytes > 0
    assert c.flash_bytes <= c.bytes


def test_walker_dus_charges_slice_not_buffer():
    """In-place dynamic-update-slice must be charged ~slice bytes, not the
    full buffer (the iteration-1 measurement-model fix)."""
    big = jnp.zeros((1024, 256), jnp.float32)  # 1 MiB
    small = jnp.ones((8, 256), jnp.float32)  # 8 KiB

    def f(b, s):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, s, (i * 8, 0)), None

        out, _ = jax.lax.scan(body, b, jnp.arange(4))
        return out

    c = _walk(f, big, small)
    # naive model: >= 4 trips x 2 x 1 MiB = 8 MiB. slice model: ~4 x 16 KiB
    # plus one-off copies of the carry. Assert well under the naive bound.
    assert c.bytes < 4 * 2**20, f"DUS overcounted: {c.bytes:.3e}"


def test_kernel_bytes_ordering():
    """Analytic kernel traffic: train > prefill; causal arch at a given
    shape moves less KV than a hypothetical full-attention one."""
    cfg = registry.get("qwen3-8b")
    tr = F.flash_kernel_bytes(cfg, SHAPES["train_4k"])
    pf = F.flash_kernel_bytes(cfg, SHAPES["prefill_32k"])
    assert tr > 0 and pf > 0
    # windowed arch streams less KV per token than full-causal at 32k
    mix = registry.get("mixtral-8x22b")  # window 4096
    mix_pf = F.flash_kernel_bytes(mix, SHAPES["prefill_32k"])
    # normalize per (layer x head x token) to compare streaming intensity
    def per_unit(cfg_, b):
        attn_layers = sum(1 for k in cfg_.layer_kinds() if k != "mamba")
        return b / (attn_layers * cfg_.num_heads * cfg_.head_dim)
    assert per_unit(mix, mix_pf) < per_unit(cfg, pf)


def test_kernel_bytes_decode_not_substituted():
    cfg = registry.get("qwen3-8b")
    assert F.flash_kernel_bytes(cfg, SHAPES["decode_32k"]) == 0.0


def test_roofline_fraction_algebra():
    rl = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, chips=256,
                  model_flops=197e12)
    # t_compute == t_memory == 1s, useful == 1 -> fraction == 1
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 1.0) < 1e-9
    assert abs(rl.roofline_fraction - 1.0) < 1e-9
    # halving useful FLOPs at same step time halves the fraction
    rl2 = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=0.0, chips=256,
                   model_flops=197e12 / 2)
    assert abs(rl2.roofline_fraction - 0.5) < 1e-9


def test_visible_fraction_causal_half():
    f = F._visible_fraction("causal", None, 0, 32, 32, 128, 128)
    assert 0.5 <= f <= 0.55  # ~(t+1)/2t


def test_gqa_expansion_grads_sum_back():
    """The broadcast-expansion trick: d(expanded KV) sums over the group --
    equivalent to GQA's dK accumulation (paper's MQA/GQA note)."""
    B, S, Hk, G, D = 2, 8, 2, 3, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, Hk, D))

    def expand(k):
        e = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hk, G, D))
        return e.reshape(B, S, Hk * G, D)

    def loss(k):
        w = jnp.arange(Hk * G, dtype=jnp.float32)[None, None, :, None]
        return jnp.sum(expand(k) * w)

    dk = jax.grad(loss)(k)
    w = np.arange(Hk * G, dtype=np.float32).reshape(Hk, G)
    expect = np.broadcast_to(w.sum(1)[None, None, :, None], dk.shape)
    np.testing.assert_allclose(np.asarray(dk), expect, rtol=1e-6)
