"""Unified telemetry layer (ISSUE 8): metrics registry, lifecycle
tracing, MFU accounting -- and the load-bearing pin that attaching ANY of
it adds zero compiles and leaves jitted step shapes untouched."""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as cfg_registry
from repro.configs.base import ModelConfig
from repro.core.attention import AttentionConfig
from repro.core.masks import MaskSpec
from repro.models import lm
from repro.obs import (
    DecodeEfficiency,
    MetricsRegistry,
    TraceRecorder,
    TrainEfficiency,
    count_knob,
    default_registry,
    peak_flops,
    reset_default_registry,
    validate_trace,
)
from repro.serving.engine import PagedServingEngine, Request, ServingEngine

# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_snapshot():
    reg = MetricsRegistry()
    c = reg.counter("x/hits")
    c.inc()
    c.inc(2.5)
    reg.gauge("x/level").set(0.75)
    assert reg.snapshot() == {"x/hits": 3.5, "x/level": 0.75}
    # re-requesting a name returns the same instrument
    assert reg.counter("x/hits") is c
    with pytest.raises(ValueError):
        c.inc(-1)


def test_histogram_cumulative_le_schema():
    reg = MetricsRegistry()
    h = reg.histogram("lat", (1.0, 4.0, 16.0))
    for v in (0.5, 3.0, 3.0, 20.0):
        h.observe(v)
    snap = reg.snapshot()
    # Prometheus cumulative semantics: le_B counts everything <= B
    assert snap["lat/le_1"] == 1.0
    assert snap["lat/le_4"] == 3.0
    assert snap["lat/le_16"] == 3.0
    assert snap["lat/le_inf"] == 4.0
    assert snap["lat/count"] == 4.0
    assert snap["lat/sum"] == pytest.approx(26.5)
    with pytest.raises(ValueError):
        reg.histogram("lat", (1.0, 2.0))  # different buckets
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("bad", (4.0, 1.0))  # not ascending


def test_gauge_fn_lazy_and_fault_isolated():
    reg = MetricsRegistry()
    state = {"v": 1.0}
    reg.gauge_fn("pool/fill", lambda: state["v"])
    state["v"] = 0.5  # sampled at snapshot time, not registration time
    assert reg.snapshot()["pool/fill"] == 0.5

    def boom():
        raise RuntimeError("pool is gone")

    reg.gauge_fn("pool/fill", boom)  # re-register replaces the sampler
    assert math.isnan(reg.snapshot()["pool/fill"])  # never raises


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("n")
    with pytest.raises(ValueError):
        reg.gauge("n")
    with pytest.raises(ValueError):
        reg.histogram("n", (1.0,))


def test_count_knob_default_registry():
    reset_default_registry()
    count_knob("flash_pallas", "tuned", 3)
    count_knob("flash_pallas", "explicit")
    assert default_registry().snapshot() == {
        "knobs/flash_pallas/tuned": 3.0,
        "knobs/flash_pallas/explicit": 1.0,
    }
    with pytest.raises(ValueError):
        count_knob("flash_pallas", "vibes")
    reset_default_registry()
    assert default_registry().snapshot() == {}


def test_knob_resolution_sources_counted():
    """resolve_pallas_knobs classifies each knob's winning tier."""
    from repro.kernels.ops import PallasFlashConfig, resolve_pallas_knobs

    shapes = ((1, 128, 2, 32), (1, 128, 2, 32))
    reset_default_registry()
    # all four knobs explicit, dense schedule -> no partition knobs in play
    resolve_pallas_knobs(
        PallasFlashConfig(spec=MaskSpec(causal=True), block_q=64, block_kv=64,
                          schedule="dense", bwd="fused", use_tuned=False),
        *shapes,
    )
    assert default_registry().snapshot() == {"knobs/flash_pallas/explicit": 4.0}

    reset_default_registry()
    # nothing explicit, cache off -> heuristics fill every knob (compact
    # schedule puts num_q_bands/kv_splits in play: 6 total)
    resolve_pallas_knobs(
        PallasFlashConfig(spec=MaskSpec(causal=True), use_tuned=False), *shapes
    )
    snap = default_registry().snapshot()
    assert snap == {"knobs/flash_pallas/heuristic": 6.0}
    reset_default_registry()


def test_decode_splits_source_counted():
    from repro.kernels.autotune import resolve_decode_splits

    reset_default_registry()
    resolve_decode_splits(256, 4, 64, jnp.float32, use_tuned=False, default=4)
    resolve_decode_splits(256, 4, 64, jnp.float32, page_size=8,
                          use_tuned=False, default=4)
    snap = default_registry().snapshot()
    assert snap["knobs/flash_decode/heuristic"] == 1.0
    assert snap["knobs/flash_decode_paged8/heuristic"] == 1.0
    reset_default_registry()


# ---------------------------------------------------------------------------
# Trace recorder + validator
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_trace_spans_nest_and_validate(tmp_path):
    clk = _FakeClock()
    tr = TraceRecorder(process="unit", clock=clk)
    with tr.span("outer", tid=1):
        clk.t += 1e-3
        with tr.span("inner", tid=1):
            clk.t += 1e-3
        tr.instant("mark", tid=1, args={"rid": 7})
        clk.t += 1e-3
    tr.counter("occupancy", {"slots": 2})
    path = tmp_path / "t.json"
    tr.save(str(path))
    with open(path) as f:
        doc = json.load(f)
    events = validate_trace(doc)
    by_name = {e["name"]: e for e in events if e["ph"] in ("X", "i")}
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert (by_name["inner"]["ts"] + by_name["inner"]["dur"]
            <= by_name["outer"]["ts"] + by_name["outer"]["dur"])
    assert by_name["outer"]["dur"] == pytest.approx(3e3)
    # process metadata event is present and first
    assert doc["traceEvents"][0]["ph"] == "M"


def test_trace_validator_rejects_bad_events():
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"name": "x", "ph": "X", "ts": 0}]})
    with pytest.raises(ValueError):
        validate_trace(
            {"traceEvents": [{"ph": "X", "ts": 0, "pid": 1, "dur": -5}]}
        )
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "i", "pid": 1}]})  # no ts
    # straddling spans on one track: [0, 10) vs [5, 15) neither nests
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
            {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 1},
        ]})
    # different tracks may overlap freely
    validate_trace({"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 1},
        {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 2},
    ]})


# ---------------------------------------------------------------------------
# MFU accounting
# ---------------------------------------------------------------------------

TINY = ModelConfig(
    name="obs-tiny", family="dense", num_layers=2, d_model=64, num_heads=2,
    num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256, vocab_pad_to=64,
    dtype="float32",
)


def test_peak_flops_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PEAK_FLOPS", "2.5e12")
    assert peak_flops() == 2.5e12
    monkeypatch.delenv("REPRO_PEAK_FLOPS")
    assert peak_flops("tpu") == 197e12
    assert peak_flops("unknown-chip") == peak_flops("cpu")


def test_train_efficiency_gauges():
    reg = MetricsRegistry()
    eff = TrainEfficiency(TINY, batch_size=2, seq_len=128, registry=reg,
                          peak=1e12)
    eff.step(0.5)
    eff.step(0.5)
    snap = reg.snapshot()
    assert snap["train/steps"] == 2.0
    assert snap["train/tokens"] == 512.0
    assert snap["train/tokens_per_s"] == pytest.approx(512.0)
    assert snap["train/mfu"] > 0 and math.isfinite(snap["train/mfu"])
    # causal mask: the kernels launch less attention work than the
    # Megatron numerator charges, so HFU (achieved/launched) <= MFU basis
    assert eff.hardware_flops_per_step <= eff.model_flops_per_step
    assert 0 < snap["train/hfu"] <= snap["train/mfu"]
    # cumulative utilization equals the per-step value for equal steps
    assert snap["train/mfu"] == pytest.approx(
        eff.model_flops_per_step / 0.5 / 1e12
    )


def test_decode_efficiency_charges_live_rows_only():
    reg = MetricsRegistry()
    eff = DecodeEfficiency(TINY, reg, peak=1e12)
    dead = eff.tick_model_flops([0, 0])
    assert dead == 0.0
    one = eff.tick_model_flops([16])
    two = eff.tick_model_flops([16, 0, 16])
    assert two == pytest.approx(2 * one)
    # longer caches cost more (the 4*d_q*L attention read term)
    assert eff.tick_model_flops([32]) > one
    live = eff.tick([16, 0, 16], seconds=0.25)
    assert live == 2
    snap = reg.snapshot()
    assert snap["decode/tokens"] == 2.0
    assert snap["decode/tokens_per_s"] == pytest.approx(8.0)
    assert math.isfinite(snap["decode/mfu"]) and snap["decode/mfu"] > 0


# ---------------------------------------------------------------------------
# Engine integration: common snapshot interface + THE zero-overhead pin
# ---------------------------------------------------------------------------

ATTN = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64,
                       decode_splits=2)


@pytest.fixture(scope="module")
def model():
    cfg = cfg_registry.reduce_config(cfg_registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_fixed_engine_snapshot_and_compiles(model):
    """The fixed engine now speaks the same snapshot()/decode_compiles
    interface as the paged one (satellite a)."""
    cfg, params = model
    reg = MetricsRegistry()
    eng = ServingEngine(cfg, params, ATTN, max_batch=2, cache_size=64,
                        prompt_pad=16, registry=reg)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[3 + i] * (4 + i), max_new_tokens=4))
    done = eng.run(max_ticks=200)
    assert sorted(done) == [0, 1, 2]
    assert eng.decode_compiles == 1  # telemetry attached, still one trace
    snap = eng.snapshot()
    assert snap is not reg  # flat dict export
    assert snap["serving/admissions"] == 3.0
    assert snap["serving/retirements"] == 3.0
    assert snap["serving/admit_bucket/count"] == 3.0
    assert snap["serving/kv_cells_capacity"] == 2 * 64
    assert snap["serving/active_slots"] == 0.0  # all retired by now
    assert math.isfinite(snap["decode/mfu"]) and snap["decode/mfu"] > 0
    assert snap["decode/tokens_per_s"] > 0


def test_paged_engine_zero_compile_overhead_with_full_telemetry(model):
    """THE acceptance pin: registry + tracer attached, driven through the
    join/leave/preempt trace of test_paged -- decode still compiles ONCE,
    and the exported trace is schema-valid with paired preempt/resume."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(1, 100, 6))) for _ in range(4)]
    reg = MetricsRegistry()
    tracer = TraceRecorder(process="test-paged")
    eng = PagedServingEngine(cfg, params, ATTN, max_batch=4, num_pages=14,
                             page_size=4, pages_per_seq_max=8, prompt_pad=16,
                             registry=reg, tracer=tracer)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=24))
    done = eng.run(max_ticks=1000)
    assert sorted(done) == list(range(4))
    assert eng.preemptions > 0, "pool was sized to force preemption"
    assert eng.decode_compiles == 1  # telemetry adds ZERO compiles

    snap = eng.snapshot()
    assert snap["serving/preemptions"] == eng.preemptions
    assert snap["kv_pool/num_pages"] == eng.pool.usable_pages
    assert snap["kv_pool/used_pages"] == 0.0  # everything freed on retire
    assert snap["serving/admit_bucket/count"] == snap["serving/admissions"]
    assert snap["serving/admissions"] == 4 + eng.preemptions  # re-admits
    assert math.isfinite(snap["decode/mfu"]) and snap["decode/mfu"] > 0

    events = validate_trace(tracer.to_json())  # raises on schema violation
    # every request track carries the full lifecycle span chain
    for rid in range(4):
        names = {e["name"] for e in events if e.get("tid") == rid}
        assert {"submit", "queue_wait", "prefill", "decode", "retire"} <= names
    # forced preemption emits preempt + resume instants for the SAME rid
    preempted = {e["args"]["rid"] for e in events if e["name"] == "preempt"}
    resumed = {e["args"]["rid"] for e in events if e["name"] == "resume"}
    assert preempted and preempted == resumed
    # the engine track saw decode ticks and resident-counter samples
    assert any(e["name"] == "decode_tick" and e["ph"] == "X" for e in events)
    assert any(e["ph"] == "C" and e["name"] == "resident" for e in events)


def test_train_step_jaxpr_unchanged_by_telemetry():
    """The jitted train step's jaxpr is bit-identical whether or not a
    registry and MFU meter are attached -- telemetry is host-side only."""
    from repro.launch.steps import build_train_step
    from repro.training.optimizer import AdamWConfig, init_opt_state

    params = lm.init_lm(TINY, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {"inputs": jnp.zeros((2, 32), jnp.int32),
             "targets": jnp.ones((2, 32), jnp.int32)}
    attn = AttentionConfig(impl="ref")
    step = build_train_step(TINY, attn, AdamWConfig(), ce_chunk=64)
    plain = str(jax.make_jaxpr(step)(params, opt, batch))

    reg = MetricsRegistry()
    eff = TrainEfficiency(TINY, batch_size=2, seq_len=32, registry=reg)
    tracer = TraceRecorder(process="train-test")
    with tracer.span("step"):
        eff.step(0.01)
    instrumented = str(jax.make_jaxpr(step)(params, opt, batch))
    assert plain == instrumented


# ---------------------------------------------------------------------------
# Satellites: ledger schema check + timing provenance
# ---------------------------------------------------------------------------


def test_bench_schema_check_tags_nonconforming(capsys):
    import sys

    sys.path.insert(0, "benchmarks")
    try:
        from run import _check_schema
    finally:
        sys.path.pop(0)

    rows = [
        {"bench": "ok", "config": "a", "us_per_call": 1.0, "derived": ""},
        {"bench": "ok2", "config": "b", "us_per_call": None, "derived": "x=1"},
        {"bench": "", "config": "c", "us_per_call": 1.0, "derived": ""},
        {"bench": "no_units", "config": "d", "us_per_call": None, "derived": ""},
        {"bench": "missing"},
        {"bench": "fixed", "config": "e", "us_per_call": 2.0, "derived": "",
         "schema": "nonconforming: stale tag"},
    ]
    out = _check_schema(rows)
    assert out is rows  # warn-and-tag, never drop
    assert "schema" not in rows[0] and "schema" not in rows[1]
    assert rows[2]["schema"] == "nonconforming: empty bench name"
    assert rows[3]["schema"].startswith("nonconforming: no units field")
    assert rows[4]["schema"].startswith("nonconforming: missing keys")
    assert "schema" not in rows[5]  # conforming again -> stale tag cleared
    assert "3 ledger rows are nonconforming" in capsys.readouterr().err


def test_timing_result_provenance():
    from repro.utils.timing import interleaved_timeit

    res = interleaved_timeit({"a": lambda: jnp.zeros(()),
                              "b": lambda: jnp.ones(())}, iters=2, warmup=1)
    assert set(res) == {"a", "b"}  # still a plain mapping
    assert res.iters == 2 and res.warmup == 1
    assert res.provenance == "min_of_2w1"
    # clamping: zero iters/warmup are promoted to 1, and recorded as such
    res0 = interleaved_timeit({"a": lambda: jnp.zeros(())}, iters=0, warmup=0)
    assert res0.provenance == "min_of_1w1"
