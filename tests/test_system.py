"""End-to-end behaviour tests for the paper's system: learning happens,
restarts resume exactly, grad accumulation is equivalent, the registry
matches the assigned table, every dry-run cell has well-formed specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.core.attention import AttentionConfig
from repro.launch.steps import build_train_step
from repro.launch.train import PRESETS, TrainLoopConfig, train
from repro.models import lm
from repro.training.optimizer import AdamWConfig, init_opt_state

ATTN = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64)


@pytest.mark.slow
def test_training_learns(tmp_path):
    cfg = PRESETS["gpt-20m"]
    loop = TrainLoopConfig(steps=25, seq_len=64, batch_size=4,
                           ckpt_dir=None, log_every=100)
    _, _, hist = train(cfg, loop, AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=25))
    assert np.mean(hist["loss"][-3:]) < np.mean(hist["loss"][:3]) - 0.1


def test_packed_training_learns(tmp_path):
    """Varlen packed batches: loss drops AND the loss mask keeps padding /
    cross-segment boundaries out of the objective."""
    cfg = PRESETS["gpt-20m"]
    loop = TrainLoopConfig(steps=12, seq_len=64, batch_size=4,
                           ckpt_dir=None, log_every=100, packed=True)
    _, _, hist = train(cfg, loop, AdamWConfig(lr=2e-3, warmup_steps=4, total_steps=12))
    assert np.isfinite(hist["loss"]).all()
    assert np.mean(hist["loss"][-3:]) < np.mean(hist["loss"][:3])


@pytest.mark.slow
def test_restart_resumes_exactly(tmp_path):
    """Train 8 steps straight vs 4 + restore + 4: identical final loss."""
    cfg = PRESETS["gpt-20m"]
    kw = dict(seq_len=64, batch_size=4, log_every=100)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8)

    _, _, h_straight = train(cfg, TrainLoopConfig(steps=8, **kw), opt)

    ckpt = str(tmp_path / "ck")
    _, _, _ = train(cfg, TrainLoopConfig(steps=4, ckpt_dir=ckpt, ckpt_every=4, **kw), opt)
    _, _, h_resumed = train(cfg, TrainLoopConfig(steps=8, ckpt_dir=ckpt, ckpt_every=4, **kw), opt)

    assert h_resumed["restored_at"] == 4
    np.testing.assert_allclose(
        h_straight["loss"][4:], h_resumed["loss"], rtol=2e-4, atol=2e-4,
    )


def test_grad_accumulation_equivalent():
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {
        "inputs": jnp.asarray(np.random.default_rng(0).integers(0, 100, (4, 32)), jnp.int32),
        "targets": jnp.asarray(np.random.default_rng(1).integers(0, 100, (4, 32)), jnp.int32),
    }
    p1, _, m1 = jax.jit(build_train_step(cfg, ATTN, AdamWConfig()))(params, opt, batch)
    p2, _, m2 = jax.jit(
        build_train_step(cfg, ATTN, AdamWConfig(), microbatches=2)
    )(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------- registry

_ASSIGNED = {
    "whisper-base": dict(num_layers=6, d_model=512, num_heads=8, d_ff=2048),
    "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024, num_heads=16),
    "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48, d_ff=16384),
    "gemma3-1b": dict(num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1),
    "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8),
    "deepseek-coder-33b": dict(num_layers=62, d_model=7168, num_heads=56, d_ff=19200),
    "stablelm-12b": dict(num_layers=40, d_model=5120, num_heads=32, d_ff=13824),
    "falcon-mamba-7b": dict(num_layers=64, d_model=4096),
    "internvl2-76b": dict(num_layers=80, d_model=8192, num_heads=64, d_ff=28672),
    "hymba-1.5b": dict(num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5),
}


def test_all_assigned_archs_present():
    assert sorted(registry.names()) == sorted(_ASSIGNED)


@pytest.mark.parametrize("arch", sorted(_ASSIGNED))
def test_assigned_dims_exact(arch):
    cfg = registry.get(arch)
    for field, want in _ASSIGNED[arch].items():
        assert getattr(cfg, field) == want, f"{arch}.{field}"


def test_moe_configs():
    g = registry.get("granite-moe-1b-a400m").moe
    assert (g.num_experts, g.top_k) == (32, 8)
    m = registry.get("mixtral-8x22b").moe
    assert (m.num_experts, m.top_k) == (8, 2)


def test_every_cell_has_specs_or_skip():
    """All 40 (arch x shape) cells: either a skip reason or well-formed
    ShapeDtypeStruct specs with the cell's batch/seq."""
    n_ok = n_skip = 0
    for arch in registry.names():
        cfg = registry.get(arch)
        for shape in SHAPES.values():
            if registry.skip_reason(cfg, shape):
                n_skip += 1
                continue
            specs = registry.input_specs(cfg, shape)
            n_ok += 1
            if shape.kind in ("train", "prefill"):
                assert specs["inputs"].shape == (shape.global_batch, shape.seq_len)
            else:
                assert specs["token"].shape == (shape.global_batch, 1)
                leaves = jax.tree.leaves(specs["caches"])
                assert leaves, f"{arch}: empty cache specs"
    assert n_ok + n_skip == 40 and n_skip == 6
