"""Checkpoint/restart + fault tolerance: atomicity, per-shard manifests,
checksums + the corruption fallback ladder, async saves and write-cost
accounting, GC, elastic restore, data-pipeline determinism, Young/Daly
cadence semantics, supervisor restart loop, deterministic fault plans."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointCorruption, CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.obs import MetricsRegistry
from repro.training import fault_injection as FI
from repro.training.fault_injection import FaultPlan, InjectedFault
from repro.training.fault_tolerance import (
    CheckpointCadence,
    StepMonitor,
    run_with_restarts,
)
from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jnp.arange(8, dtype=jnp.float32)},
    }


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(3, tree, meta={"step": 3, "note": "x"})
    restored, meta = store.restore(jax.tree.map(jnp.zeros_like, tree))
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1}, async_=True)
    store.wait()
    assert store.latest_step() == 1


def test_keep_last_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        store.save(s, tree, meta={"step": s})
    assert store.steps() == [3, 4]


def test_crash_mid_save_leaves_last_durable(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1})
    # simulate a crash: a stale .tmp dir from a dead writer
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert store.latest_step() == 1  # tmp is never visible
    store.save(2, tree, meta={"step": 2})  # and does not block the next save
    assert store.latest_step() == 2


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint saved unsharded restores with a caller-provided
    sharding_fn -- the lose-a-pod rescale path."""
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1})
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    restored, _ = store.restore(tree, sharding_fn=lambda key, arr: sharding)
    assert all(x.sharding == sharding for x in jax.tree.leaves(restored))


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=97, seed=7)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        next(iter(a))
    b.restore(a.state())
    xa, ya = a.batch(a.state()["step"])
    xb, yb = b.batch(b.state()["step"])
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)
    # targets are inputs shifted by one
    np.testing.assert_array_equal(xa[:, 1:], ya[:, :-1])


def test_nan_step_skip():
    params = _tree()
    opt = init_opt_state(params)
    bad = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), params)
    new_p, new_opt, m = apply_updates(AdamWConfig(), opt, bad, param_dtype=jnp.float32)
    assert float(m["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(new_opt.step) == 1  # step counter still advances


def test_run_with_restarts_recovers():
    saves = {}
    fail_at = {5}

    def restore_fn():
        if not saves:
            return 0, 0.0
        s = max(saves)
        return s, saves[s]

    def step_fn(step, state):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("injected node failure")
        return state + 1.0

    def save_fn(step, state):
        saves[step] = state

    state, restarts, telem = run_with_restarts(
        step_fn, restore_fn, save_fn, total_steps=10, checkpoint_every=2
    )
    assert restarts == 1
    assert state == 10.0  # every step re-applied exactly once after restore


def test_step_monitor_flags_straggler():
    mon = StepMonitor(window=10, straggler_factor=1.5)
    import time as _t

    for i in range(6):
        mon.start()
        _t.sleep(0.001)
        mon.stop()
    mon.start()
    _t.sleep(0.05)
    ev = mon.stop()
    assert ev is not None and ev.duration > ev.median


def test_cadence_young_daly_interval():
    cad = CheckpointCadence(mtbf_seconds=3600, min_interval_steps=100)
    cad.observe_write(2.0)
    # first observation seeds the cost directly: sqrt(2 * 3600 * 2) ~ 120s
    assert 60 < cad.interval_seconds < 180
    cad.observe_write(1.0)  # EWMA from there
    assert cad.write_cost == pytest.approx(1.5)


def test_cadence_floor_is_a_minimum():
    """ckpt_every is a FLOOR on spacing: below it never checkpoint, above
    it the Young/Daly interval governs (the old code checkpointed *every*
    min_interval_steps -- a maximum acting under a minimum's name)."""
    cad = CheckpointCadence(mtbf_seconds=3600, min_interval_steps=10)
    cad.observe_write(1.0)
    assert not cad.should_checkpoint(5, 0.1)  # under the floor
    assert not cad.should_checkpoint(10, 0.1)  # floor met, interval not
    assert not cad.should_checkpoint(200, 0.1)  # still: ~85s not elapsed
    # tiny MTBF: interval collapses below one step => save at the floor
    fast = CheckpointCadence(mtbf_seconds=1e-4, min_interval_steps=10)
    fast.observe_write(0.01)
    assert not fast.should_checkpoint(9, 0.5)
    assert fast.should_checkpoint(10, 0.5)
    fast.mark(10)
    assert not fast.should_checkpoint(15, 0.5)  # floor counts from mark
    assert fast.should_checkpoint(20, 0.5)


def test_cadence_step_time_participates():
    """Nearest-boundary rule: with the optimum mid-way to the next step
    boundary, a long step tips the decision to 'checkpoint now'."""
    cad = CheckpointCadence(mtbf_seconds=3600, min_interval_steps=1)
    cad.write_cost = 1e-8  # force a tiny Young/Daly interval directly
    cad._last_ckpt_time = __import__("time").monotonic() - 0.001
    # elapsed ~0.001 < interval? interval = sqrt(2*3600*1e-8) ~ 0.0085
    assert not cad.should_checkpoint(5, step_time=0.0)
    assert cad.should_checkpoint(5, step_time=0.1)  # 0.001 + 0.05 > 0.0085


# ---------------------------------------------------------------------------
# Per-shard manifest schema, checksums, durability accounting
# ---------------------------------------------------------------------------


def _manifest(path, step):
    with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


def test_manifest_v2_per_shard_schema(tmp_path):
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry())
    tree = _tree()
    store.save(5, tree, meta={"step": 5})
    man = _manifest(str(tmp_path), 5)
    assert man["version"] == 2
    by_key = {l["key"]: l for l in man["leaves"]}
    w = by_key["w"]
    assert w["shape"] == [4, 8] and w["dtype"] == "float32"
    # single device: one shard covering the whole logical array, with CRC
    assert len(w["shards"]) == 1
    sh = w["shards"][0]
    assert sh["index"] == [[0, 4], [0, 8]]
    assert isinstance(sh["crc32"], int)
    assert os.path.exists(os.path.join(str(tmp_path), "step_00000005", sh["file"]))


def test_async_write_cost_recorded(tmp_path):
    """The worker's actual wall write duration reaches drain_write_stats
    -- the Young/Daly feed (the blocking save() only sees the snapshot)."""
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry())
    store.save(1, _tree(), meta={"step": 1}, async_=True)
    store.wait()
    stats = store.drain_write_stats()
    assert len(stats) == 1
    step, seconds = stats[0]
    assert step == 1 and seconds > 0
    assert store.drain_write_stats() == []  # drained


def test_restore_passes_shape_spec_to_sharding_fn(tmp_path):
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry())
    tree = _tree()
    store.save(1, tree, meta={"step": 1})
    seen = {}

    def fn(key, spec):
        seen[key] = (tuple(spec.shape), str(spec.dtype))
        return None

    store.restore(tree, sharding_fn=fn)
    assert seen["w"] == ((4, 8), "float32")


def test_v1_manifest_still_restores(tmp_path):
    """A pre-PR-10 whole-array manifest (no shards/CRC) restores."""
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry())
    tree = _tree()
    root = os.path.join(str(tmp_path), "step_00000003")
    os.makedirs(root)
    leaves = []
    for key, leaf in [("w", tree["w"]), ("nested/b", tree["nested"]["b"])]:
        fname = key.replace("/", "__") + ".npy"
        arr = np.asarray(leaf)
        np.save(os.path.join(root, fname), arr)
        leaves.append({"key": key, "file": fname, "shape": list(arr.shape),
                       "dtype": str(arr.dtype)})
    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump({"step": 3, "meta": {"step": 3}, "leaves": leaves}, f)
    restored, meta = store.restore(jax.tree.map(jnp.zeros_like, tree))
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# Fault-injection matrix: every corrupt/partial state is detected on
# restore and falls back to the previous durable step -- never a crash,
# never silently-wrong weights.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["torn", "trunc", "drop", "corrupt"])
def test_disk_fault_falls_back_one_step(tmp_path, kind):
    reg = MetricsRegistry()
    store = CheckpointStore(str(tmp_path), registry=reg)
    t1, t2 = _tree(1), _tree(2)
    store.save(1, t1, meta={"step": 1})
    store.save(2, t2, meta={"step": 2})
    FI.mutilate(os.path.join(str(tmp_path), "step_00000002"), kind,
                np.random.default_rng(0))
    with pytest.warns(UserWarning, match="corrupt"):
        restored, meta = store.restore(jax.tree.map(jnp.zeros_like, t1))
    assert meta["step"] == 1  # fell back to the previous durable step
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    snap = reg.snapshot()
    assert snap["ckpt/corruptions"] == 1 and snap["ckpt/fallbacks"] == 1


def test_all_corrupt_raises_not_silently_wrong(tmp_path):
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry())
    t1 = _tree(1)
    store.save(1, t1, meta={"step": 1})
    FI.mutilate(os.path.join(str(tmp_path), "step_00000001"), "corrupt",
                np.random.default_rng(0))
    with pytest.warns(UserWarning):
        with pytest.raises(FileNotFoundError, match="valid"):
            store.restore(jax.tree.map(jnp.zeros_like, t1))


def test_fault_plan_post_write_corruption(tmp_path):
    """A plan-driven disk fault corrupts the durable step the store just
    wrote; restore detects it and falls back."""
    plan = FaultPlan.parse("corrupt@2")
    store = CheckpointStore(str(tmp_path), registry=MetricsRegistry(),
                            fault_plan=plan)
    t1, t2 = _tree(1), _tree(2)
    store.save(1, t1, meta={"step": 1})
    store.save(2, t2, meta={"step": 2})
    with pytest.warns(UserWarning, match="corrupt"):
        _, meta = store.restore(jax.tree.map(jnp.zeros_like, t1))
    assert meta["step"] == 1


def test_abort_write_surfaces_immediately_and_on_wait(tmp_path):
    """A mid-file write kill leaves only a .tmp (the previous step stays
    durable), warns immediately, bumps ckpt/async_failures, and re-raises
    on wait()."""
    reg = MetricsRegistry()
    plan = FaultPlan.parse("abort@2")
    store = CheckpointStore(str(tmp_path), registry=reg, fault_plan=plan)
    t = _tree()
    store.save(1, t, meta={"step": 1})
    with pytest.warns(UserWarning, match="failed"):
        store.save(2, t, meta={"step": 2}, async_=True)
        store._worker.join()  # let the worker hit the fault
    assert reg.snapshot()["ckpt/async_failures"] == 1
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        store.wait()
    assert store.latest_step() == 1  # tmp never became visible
    assert os.path.exists(os.path.join(str(tmp_path), "step_00000002.tmp"))
    # the next save reuses the step and the run carries on
    store.save(2, t, meta={"step": 2})
    assert store.latest_step() == 2


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------


def test_fault_plan_parse_and_fire_once():
    plan = FaultPlan.parse("raise@3,corrupt@5")
    assert [(e.kind, e.step) for e in plan.events] == [("raise", 3), ("corrupt", 5)]
    with pytest.raises(InjectedFault):
        plan.fire_step(3)
    plan.fire_step(3)  # fired once: a replayed step does not re-fire
    assert plan.post_write_fault(5) == "corrupt"
    assert plan.post_write_fault(5) is None


def test_fault_plan_random_deterministic():
    a = FaultPlan.random(7, 100, rate=0.2)
    b = FaultPlan.random(7, 100, rate=0.2)
    assert a.events == b.events and len(a.events) > 0
    assert FaultPlan.random(8, 100, rate=0.2).events != a.events


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.parse("explode@3")
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultPlan.parse("raise-at-3")


# ---------------------------------------------------------------------------
# Supervisor: cadence-driven saves, preemption stop, restart counters
# ---------------------------------------------------------------------------


def test_run_with_restarts_cadence_and_registry():
    reg = MetricsRegistry()
    saves, fail_at = {}, {3}
    cad = CheckpointCadence(mtbf_seconds=1e-4, min_interval_steps=2)
    cad.observe_write(0.01)

    def restore_fn():
        return (max(saves), saves[max(saves)]) if saves else (0, 0.0)

    def step_fn(step, state):
        if step in fail_at:
            fail_at.clear()
            raise InjectedFault("boom")
        return state + 1.0

    state, restarts, telem = run_with_restarts(
        step_fn, restore_fn, lambda s, st: saves.__setitem__(s, st),
        total_steps=8, cadence=cad, registry=reg,
    )
    assert state == 8.0 and restarts == 1
    assert reg.snapshot()["train/restarts"] == 1
    assert 8 in saves  # the final step always saves
    assert telem["preempted"] is False


def test_run_with_restarts_should_stop_saves_and_exits():
    saves = {}
    calls = {"n": 0}

    def should_stop():
        calls["n"] += 1
        return calls["n"] > 3  # preemption notice arrives mid-run

    state, restarts, telem = run_with_restarts(
        lambda step, s: s + 1.0, lambda: (0, 0.0),
        lambda s, st: saves.__setitem__(s, st),
        total_steps=100, checkpoint_every=10, should_stop=should_stop,
    )
    assert telem["preempted"] is True
    assert telem["last_step"] == 3 and saves == {3: 3.0}


def test_run_with_restarts_needs_exactly_one_policy():
    with pytest.raises(ValueError, match="exactly one"):
        run_with_restarts(lambda s, st: st, lambda: (0, 0), lambda s, st: None,
                          total_steps=1)
