"""Checkpoint/restart + fault tolerance: atomicity, async saves, GC,
elastic restore, data-pipeline determinism, supervisor restart loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.training.fault_tolerance import (
    CheckpointCadence,
    StepMonitor,
    run_with_restarts,
)
from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jnp.arange(8, dtype=jnp.float32)},
    }


def test_save_restore_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(3, tree, meta={"step": 3, "note": "x"})
    restored, meta = store.restore(jax.tree.map(jnp.zeros_like, tree))
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1}, async_=True)
    store.wait()
    assert store.latest_step() == 1


def test_keep_last_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        store.save(s, tree, meta={"step": s})
    assert store.steps() == [3, 4]


def test_crash_mid_save_leaves_last_durable(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1})
    # simulate a crash: a stale .tmp dir from a dead writer
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert store.latest_step() == 1  # tmp is never visible
    store.save(2, tree, meta={"step": 2})  # and does not block the next save
    assert store.latest_step() == 2


def test_elastic_restore_resharding(tmp_path):
    """A checkpoint saved unsharded restores with a caller-provided
    sharding_fn -- the lose-a-pod rescale path."""
    store = CheckpointStore(str(tmp_path))
    tree = _tree()
    store.save(1, tree, meta={"step": 1})
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    restored, _ = store.restore(tree, sharding_fn=lambda key, arr: sharding)
    assert all(x.sharding == sharding for x in jax.tree.leaves(restored))


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(batch_size=2, seq_len=16, vocab_size=97, seed=7)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        next(iter(a))
    b.restore(a.state())
    xa, ya = a.batch(a.state()["step"])
    xb, yb = b.batch(b.state()["step"])
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)
    # targets are inputs shifted by one
    np.testing.assert_array_equal(xa[:, 1:], ya[:, :-1])


def test_nan_step_skip():
    params = _tree()
    opt = init_opt_state(params)
    bad = jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), params)
    new_p, new_opt, m = apply_updates(AdamWConfig(), opt, bad, param_dtype=jnp.float32)
    assert float(m["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(new_opt.step) == 1  # step counter still advances


def test_run_with_restarts_recovers():
    saves = {}
    fail_at = {5}

    def restore_fn():
        if not saves:
            return 0, 0.0
        s = max(saves)
        return s, saves[s]

    def step_fn(step, state):
        if step in fail_at:
            fail_at.clear()
            raise RuntimeError("injected node failure")
        return state + 1.0

    def save_fn(step, state):
        saves[step] = state

    state, restarts, telem = run_with_restarts(
        step_fn, restore_fn, save_fn, total_steps=10, checkpoint_every=2
    )
    assert restarts == 1
    assert state == 10.0  # every step re-applied exactly once after restore


def test_step_monitor_flags_straggler():
    mon = StepMonitor(window=10, straggler_factor=1.5)
    import time as _t

    for i in range(6):
        mon.start()
        _t.sleep(0.001)
        mon.stop()
    mon.start()
    _t.sleep(0.05)
    ev = mon.stop()
    assert ev is not None and ev.duration > ev.median


def test_cadence_young_daly():
    cad = CheckpointCadence(mtbf_seconds=3600, min_interval_steps=100)
    cad.observe_write(2.0)
    # sqrt(2 * 3600 * ~1.5) ~ 104s; exact value tracks the EWMA
    assert 60 < cad.interval_seconds < 180
    assert cad.should_checkpoint(200, 0.1)  # step multiple triggers
