"""Per-architecture smoke tests: every assigned arch, reduced config,
one forward + one train step on CPU; output shapes and finiteness.

The FULL configs are exercised shape-only by launch/dryrun.py (deliverable
e); these reduced configs keep the same family/features (GQA ratios, MoE
routing, SSM scan, hybrid heads, enc-dec cross-attn, meta tokens).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.launch.steps import build_prefill_step, build_serve_step, build_train_step
from repro.models import lm, whisper
from repro.training.optimizer import AdamWConfig, init_opt_state

ARCHS = registry.names()
ATTN = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64, decode_splits=2)
B, S = 2, 64

# Fast tier keeps one dense-GQA and one MoE representative; the heavy /
# exotic families (hybrid, SSM, enc-dec, VLM, big-window) run in `-m slow`.
_SLOW_TRAIN = {
    "whisper-base", "mixtral-8x22b", "gemma3-1b", "deepseek-coder-33b",
    "stablelm-12b", "falcon-mamba-7b", "internvl2-76b", "hymba-1.5b",
}
_SLOW_SERVE = {"gemma3-1b", "hymba-1.5b", "falcon-mamba-7b", "internvl2-76b"}


def _tiered(names, slow_set):
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in slow_set else a
        for a in names
    ]


def _params_and_batch(cfg):
    if cfg.family == "encdec":
        params = whisper.init_whisper(cfg, jax.random.PRNGKey(0))
    else:
        params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    batch = {
        "inputs": jnp.ones((B, S), jnp.int32),
        "targets": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, 32, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_validates(arch):
    cfg = registry.get(arch)
    cfg.validate()
    assert cfg.num_layers == len(cfg.layer_kinds())


@pytest.mark.parametrize("arch", _tiered(ARCHS, _SLOW_TRAIN))
def test_train_step_smoke(arch):
    cfg = registry.reduce_config(registry.get(arch))
    params, batch = _params_and_batch(cfg)
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, ATTN, AdamWConfig()))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: non-finite loss"
    assert int(new_opt.step) == 1
    # params changed and stayed finite
    leaves = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves), f"{arch}: non-finite params"


@pytest.mark.parametrize(
    "arch",
    _tiered([a for a in ARCHS if registry.get(a).family != "encdec"], _SLOW_SERVE),
)
def test_prefill_decode_smoke(arch):
    cfg = registry.reduce_config(registry.get(arch))
    params, batch = _params_and_batch(cfg)
    cache = 128
    prefill = jax.jit(build_prefill_step(cfg, ATTN, cache_size=cache))
    pre_batch = {k: v for k, v in batch.items() if k in ("inputs", "patches")}
    tok, caches, lens = prefill(params, pre_batch)
    assert tok.shape == (B, 1)
    step = jax.jit(build_serve_step(cfg, ATTN))
    tok2, caches2 = step(params, tok, caches, lens)
    assert tok2.shape == (B, 1)
    assert bool((tok2 >= 0).all()) and bool((tok2 < cfg.vocab_size).all())


def test_whisper_decode_smoke():
    cfg = registry.reduce_config(registry.get("whisper-base"))
    params, batch = _params_and_batch(cfg)
    prefill = jax.jit(build_prefill_step(cfg, ATTN, cache_size=128))
    tok, caches, lens = prefill(
        params, {"inputs": batch["inputs"], "frames": batch["frames"]}
    )
    step = jax.jit(build_serve_step(cfg, ATTN))
    tok2, _ = step(params, tok, caches, lens)
    assert tok2.shape == (B, 1)
