"""Elastic checkpoint restore across mesh shapes + kill-and-resume.

Two groups:

  * in-process tests on an 8-virtual-device (data=2, model=4) mesh --
    save writes per-shard files (never a host gather of the global
    array) and the same checkpoint restores onto a smaller (1, 4)
    submesh and onto a single device, bitwise equal;
  * slow-tier subprocess tests: SIGKILL the trainer mid-run via a
    deterministic fault plan, relaunch against the same --ckpt-dir, and
    assert the resumed loss curve is bitwise identical to an
    uninterrupted run's suffix -- on one device and on the composed
    (data=2 x model=4) ring mesh.

The in-process group needs ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
*before* jax starts (the CI multidevice job sets it); the subprocess
group sets the flag itself and runs anywhere.
"""

import json
import os
import signal
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore

P = jax.sharding.PartitionSpec
NS = jax.sharding.NamedSharding

multidevice8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


def _host_tree():
    return {
        "w": np.arange(8 * 16, dtype=np.float32).reshape(8, 16),
        "nested": {"b": np.arange(16, dtype=np.float32) * 0.5},
    }


def _sharded_tree(mesh):
    host = _host_tree()
    return {
        # fully sharded over both axes: 8 shards of (4, 4)
        "w": jax.device_put(host["w"], NS(mesh, P("data", "model"))),
        # sharded over model, replicated over data: 4 distinct shards
        "nested": {"b": jax.device_put(host["nested"]["b"], NS(mesh, P("model")))},
    }


def _manifest(root, step):
    with open(os.path.join(root, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# save on a mesh: per-shard files, no global gather
# ---------------------------------------------------------------------------


@multidevice8
def test_mesh_save_writes_local_shards_only(tmp_path, monkeypatch):
    mesh = _mesh24()
    tree = _sharded_tree(mesh)

    def no_gather(*a, **k):  # the save path must never gather to host
        raise AssertionError("save() called jax.device_get on a global array")

    monkeypatch.setattr(jax, "device_get", no_gather)
    store = CheckpointStore(str(tmp_path))
    store.save(1, tree, meta={"step": 1})

    man = _manifest(str(tmp_path), 1)
    by_key = {l["key"]: l for l in man["leaves"]}
    w = by_key["w"]
    assert w["shape"] == [8, 16]
    assert len(w["shards"]) == 8  # one file per device shard
    step_dir = os.path.join(str(tmp_path), "step_00000001")
    covered = np.zeros((8, 16), dtype=bool)
    for sh in w["shards"]:
        arr = np.load(os.path.join(step_dir, sh["file"]))
        (r0, r1), (c0, c1) = sh["index"]
        assert arr.shape == (r1 - r0, c1 - c0) == (4, 4)  # LOCAL shape
        covered[r0:r1, c0:c1] = True
    assert covered.all()  # shards tile the logical array exactly
    # replicated-over-data leaf: replica_id dedupe keeps 4 of 8 copies
    b = by_key["nested/b"]
    assert len(b["shards"]) == 4
    assert sorted(sh["index"] for sh in b["shards"]) == [
        [[0, 4]], [[4, 8]], [[8, 12]], [[12, 16]]
    ]


# ---------------------------------------------------------------------------
# elastic restore: (2,4) -> (1,4), (2,4) -> single device, same mesh
# ---------------------------------------------------------------------------


def _restore_onto(store, sharding_for):
    host = _host_tree()
    template = jax.tree.map(lambda x: jnp.zeros_like(x), host)
    restored, meta = store.restore(
        template, sharding_fn=lambda key, spec: sharding_for(key, spec)
    )
    assert meta["step"] == 1
    for want, got in zip(jax.tree.leaves(host), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(want, np.asarray(got))  # bitwise
    return restored


@multidevice8
def test_elastic_restore_smaller_mesh(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _sharded_tree(_mesh24()), meta={"step": 1})
    sub = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model")
    )
    restored = _restore_onto(
        store,
        lambda key, spec: NS(sub, P("data", "model") if len(spec.shape) == 2
                             else P("model")),
    )
    assert restored["w"].sharding.mesh.shape == {"data": 1, "model": 4}
    # each (1,4)-mesh shard is (8, 4): reassembled from two saved (4, 4)s
    assert {s.data.shape for s in restored["w"].addressable_shards} == {(8, 4)}


@multidevice8
def test_elastic_restore_single_device(tmp_path):
    store = CheckpointStore(str(tmp_path))
    store.save(1, _sharded_tree(_mesh24()), meta={"step": 1})
    one = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored = _restore_onto(store, lambda key, spec: one)
    assert all(x.sharding == one for x in jax.tree.leaves(restored))


@multidevice8
def test_elastic_restore_same_mesh_stays_sharded(tmp_path):
    store = CheckpointStore(str(tmp_path))
    mesh = _mesh24()
    store.save(1, _sharded_tree(mesh), meta={"step": 1})
    target = NS(mesh, P("data", "model"))
    restored = _restore_onto(
        store,
        lambda key, spec: target if len(spec.shape) == 2 else NS(mesh, P("model")),
    )
    assert restored["w"].sharding == target
    assert {s.data.shape for s in restored["w"].addressable_shards} == {(4, 4)}


@multidevice8
def test_elastic_restore_params_and_opt_state(tmp_path):
    """The satellite case verbatim: params + a resumable AdamW state saved
    on the (2,4) mesh come back bitwise on a (1,4) submesh."""
    from repro.training.optimizer import init_opt_state

    mesh = _mesh24()
    params = {
        "wq": jax.device_put(
            np.arange(8 * 16, dtype=np.float32).reshape(8, 16),
            NS(mesh, P("data", "model"))),
        "bias": jax.device_put(np.arange(16, dtype=np.float32),
                               NS(mesh, P("model"))),
    }
    opt = init_opt_state(params)
    store = CheckpointStore(str(tmp_path))
    store.save(7, {"params": params, "opt": opt}, meta={"step": 7})

    sub = jax.sharding.Mesh(
        np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model")
    )

    def fn(key, spec):
        if len(spec.shape) == 2:
            return NS(sub, P("data", "model"))
        if len(spec.shape) == 1:
            return NS(sub, P("model"))
        return NS(sub, P())  # opt step counter and other scalars

    template = jax.tree.map(
        lambda x: jnp.zeros(x.shape, x.dtype), {"params": params, "opt": opt}
    )
    restored, meta = store.restore(template, sharding_fn=fn)
    assert meta["step"] == 7
    want = jax.tree.leaves({"params": params, "opt": opt})
    got = jax.tree.leaves(restored)
    assert len(want) == len(got)
    for a, b in zip(want, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(restored["opt"].step) == int(opt.step)  # resumable counter


# ---------------------------------------------------------------------------
# subprocess kill-and-resume: bitwise loss-curve continuation
# ---------------------------------------------------------------------------

_TRAIN = [sys.executable, "-m", "repro.launch.train", "--preset", "gpt-20m",
          "--steps", "8", "--seq", "64", "--batch", "2",
          "--ckpt-every", "2", "--mtbf", "0.01"]


def _run(tmp_path, name, ckpt, extra, devices=None, expect_kill=False):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    if devices:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    hist = str(tmp_path / f"{name}.json")
    cmd = _TRAIN + ["--ckpt-dir", str(tmp_path / ckpt),
                    "--history-out", hist] + extra
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    if expect_kill:
        assert proc.returncode == -signal.SIGKILL, proc.stderr[-2000:]
        return None
    assert proc.returncode == 0, proc.stderr[-2000:]
    with open(hist) as f:
        return json.load(f)


def _kill_and_resume(tmp_path, devices, mesh_extra):
    full = _run(tmp_path, "full", "ck_a", mesh_extra, devices)
    _run(tmp_path, "killed", "ck_b",
         mesh_extra + ["--fault-plan", "sigkill@4"], devices, expect_kill=True)
    resumed = _run(tmp_path, "resumed", "ck_b", mesh_extra, devices)
    assert resumed["restored_at"] > 0
    assert resumed["preempted"] is False
    # bitwise continuation: the resumed run's losses equal the
    # uninterrupted run's suffix from the restored step
    assert resumed["loss"] == full["loss"][resumed["restored_at"]:]


@pytest.mark.slow
def test_kill_and_resume_single_device(tmp_path):
    _kill_and_resume(tmp_path, devices=None, mesh_extra=[])


@pytest.mark.slow
def test_kill_and_resume_2d_mesh(tmp_path):
    _kill_and_resume(
        tmp_path, devices=8,
        mesh_extra=["--data-axis", "2", "--model-axis", "4",
                    "--attn-sharding", "ring"],
    )
