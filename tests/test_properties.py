"""Hypothesis property tests on the system's core invariants.

P1  online-softmax combine is associative + commutative (the correctness
    basis of the KV-loop, split-KV decode, AND context parallelism).
P2  combine_lse_outputs merges locally-normalized parts exactly.
P3  causal attention output is independent of future K/V rows.
P4  GQA flash == explicitly-expanded MHA.
P5  flash(q,k,v) rows are convex combinations of V rows (weights sum to 1).
P6  softmax shift invariance: adding a constant to all scores of a row
    leaves attention unchanged (flash must inherit this).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import online_softmax as osm
from repro.core.flash import flash_attention, flash_attention_with_lse
from repro.core.masks import MaskSpec
from repro.kernels.ref import attention_reference

SETTINGS = dict(max_examples=15, deadline=None)


def _rand(seed, *shape):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@given(seed=st.integers(0, 2**16), rows=st.integers(1, 8), cols=st.integers(1, 16), d=st.integers(1, 8))
@settings(**SETTINGS)
def test_p1_combine_associative_commutative(seed, rows, cols, d):
    s = _rand(seed, 3, rows, cols) * 4
    v = _rand(seed + 1, 3, cols, d)
    states = [osm.block_state(jnp.asarray(s[i]), jnp.asarray(v[i])) for i in range(3)]
    ab_c = osm.combine(osm.combine(states[0], states[1]), states[2])
    a_bc = osm.combine(states[0], osm.combine(states[1], states[2]))
    ba_c = osm.combine(osm.combine(states[1], states[0]), states[2])
    for x, y in ((ab_c, a_bc), (ab_c, ba_c)):
        np.testing.assert_allclose(x.m, y.m, atol=1e-6)
        np.testing.assert_allclose(x.l, y.l, rtol=1e-5)
        np.testing.assert_allclose(x.o, y.o, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**16), parts=st.integers(1, 6))
@settings(**SETTINGS)
def test_p2_split_merge_exact(seed, parts):
    rows, cols, d = 4, 8, 5
    s = _rand(seed, parts, rows, cols) * 3
    v = _rand(seed + 1, parts, cols, d)
    o_parts, lse_parts = [], []
    for i in range(parts):
        o_i, lse_i = osm.finalize(osm.block_state(jnp.asarray(s[i]), jnp.asarray(v[i])))
        o_parts.append(o_i)
        lse_parts.append(lse_i)
    o, lse = osm.combine_lse_outputs(jnp.stack(o_parts), jnp.stack(lse_parts))
    s_cat = jnp.concatenate([jnp.asarray(x) for x in s], axis=-1)
    v_cat = jnp.concatenate([jnp.asarray(x) for x in v], axis=0)
    o_ref, lse_ref = osm.finalize(osm.block_state(s_cat, v_cat))
    np.testing.assert_allclose(o, o_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lse, lse_ref, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_p3_causal_future_independence(seed):
    B, S, H, D = 1, 64, 2, 16
    rng = np.random.default_rng(seed)
    q, k, v = (rng.standard_normal((B, S, H, D)).astype(np.float32) for _ in range(3))
    cut = int(rng.integers(1, S))
    o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         MaskSpec(causal=True), block_q=16, block_kv=16)
    k2, v2 = k.copy(), v.copy()
    k2[:, cut:] = rng.standard_normal(k2[:, cut:].shape)  # perturb the future
    v2[:, cut:] = rng.standard_normal(v2[:, cut:].shape)
    o2 = flash_attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2),
                         MaskSpec(causal=True), block_q=16, block_kv=16)
    np.testing.assert_allclose(o1[:, :cut], o2[:, :cut], atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 2**16), g=st.sampled_from([1, 2, 4]))
@settings(**SETTINGS)
def test_p4_gqa_equals_expanded_mha(seed, g):
    B, S, Hk, D = 1, 32, 2, 8
    Hq = Hk * g
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hk, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hk, D)).astype(np.float32))
    spec = MaskSpec(causal=True)
    o_gqa = flash_attention(q, k, v, spec, block_q=16, block_kv=16)
    k_exp = jnp.repeat(k, g, axis=2)
    v_exp = jnp.repeat(v, g, axis=2)
    o_mha = flash_attention(q, k_exp, v_exp, spec, block_q=16, block_kv=16)
    np.testing.assert_allclose(o_gqa, o_mha, atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_p5_convex_combination(seed):
    B, S, H, D = 1, 32, 2, 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
    v_const = jnp.ones((B, S, H, D), jnp.float32) * 3.7  # constant V rows
    o = flash_attention(q, k, v_const, MaskSpec(causal=True), block_q=16, block_kv=16)
    np.testing.assert_allclose(o, 3.7, atol=1e-5)  # weights sum to exactly 1


@given(seed=st.integers(0, 2**16))
@settings(**SETTINGS)
def test_p6_kv_permutation_invariance(seed):
    """Non-causal attention is permutation-invariant in the KV rows: the
    online-softmax accumulation order cannot matter (this is what makes the
    packed tile schedule and context-parallel KV rotation exact)."""
    B, Sq, Sk, H, D = 1, 16, 48, 2, 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)).astype(np.float32))
    k = rng.standard_normal((B, Sk, H, D)).astype(np.float32)
    v = rng.standard_normal((B, Sk, H, D)).astype(np.float32)
    perm = rng.permutation(Sk)
    o1 = flash_attention(q, jnp.asarray(k), jnp.asarray(v), MaskSpec(), block_q=16, block_kv=16)
    o2 = flash_attention(q, jnp.asarray(k[:, perm]), jnp.asarray(v[:, perm]), MaskSpec(), block_q=16, block_kv=16)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=1e-4)
