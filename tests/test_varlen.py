"""Segment-packed (varlen) attention: Pallas + XLA vs the masked dense
reference, gradient checks, block-skip accounting, packing transform, and
packed decode. Acceptance: <=2e-5 (fp32) / <=2e-2 (bf16) parity on packed
batches with segment boundaries NOT aligned to block_kv."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flash import _visible_pairs, flash_attention
from repro.core.masks import MaskSpec, SegmentInfo, segment_positions
from repro.kernels.ops import (
    flash_attention_pallas_varlen,
    flash_attention_pallas_varlen_with_lse,
)
from repro.kernels.ref import attention_reference

KEY = jax.random.PRNGKey(7)
B, S, HQ, HK, D = 2, 128, 4, 2, 32
BLK = 32


def _mk(dtype=jnp.float32, hq=HQ, hk=HK):
    ks = jax.random.split(KEY, 4)
    return (
        jax.random.normal(ks[0], (B, S, hq, D), dtype),
        jax.random.normal(ks[1], (B, S, hk, D), dtype),
        jax.random.normal(ks[2], (B, S, hk, D), dtype),
        jax.random.normal(ks[3], (B, S, hq, D), dtype),
    )


def _segments(n_seg: int, seed: int = 0) -> jnp.ndarray:
    """n_seg ragged segments per row, deliberately NOT block-aligned, with a
    short trailing padding region (id 0)."""
    rng = np.random.default_rng(seed)
    seg = np.zeros((B, S), np.int32)
    for b in range(B):
        pad = int(rng.integers(0, 9))  # trailing padding, may be 0
        cuts = np.sort(rng.choice(np.arange(1, S - pad), n_seg - 1, replace=False))
        bounds = np.concatenate([[0], cuts, [S - pad]])
        for s in range(n_seg):
            seg[b, bounds[s] : bounds[s + 1]] = s + 1
    return jnp.asarray(seg)


SPECS = {
    "causal": MaskSpec(causal=True),
    "full": MaskSpec(),
    "causal_window": MaskSpec(causal=True, window=48),
}


@pytest.mark.parametrize("n_seg", [1, 2, 3, 6])
@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_varlen_fwd_parity(n_seg, spec_name):
    spec = SPECS[spec_name]
    q, k, v, _ = _mk()
    seg = _segments(n_seg, seed=n_seg)
    o_ref, lse_ref = attention_reference(q, k, v, spec, segment_ids=seg)
    o, lse = flash_attention_pallas_varlen_with_lse(
        q, k, v, seg, spec, block_q=BLK, block_kv=BLK
    )
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=1e-4)
    mask = ~np.isneginf(np.asarray(lse_ref))
    np.testing.assert_allclose(
        np.asarray(lse)[mask], np.asarray(lse_ref)[mask], atol=1e-4, rtol=1e-5
    )
    # XLA flash mirrors the same semantics
    o_x = flash_attention(q, k, v, spec, block_q=BLK, block_kv=BLK, segment_ids=seg)
    np.testing.assert_allclose(o_x, o_ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("spec_name", sorted(SPECS))
def test_varlen_grads(spec_name):
    """dq/dk/dv parity on unaligned 3-segment packing (Pallas and XLA)."""
    spec = SPECS[spec_name]
    q, k, v, do = _mk()
    seg = _segments(3, seed=11)

    def f_pallas(q, k, v):
        o = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=BLK, block_kv=BLK)
        return (o * do).sum()

    def f_xla(q, k, v):
        o = flash_attention(q, k, v, spec, block_q=BLK, block_kv=BLK, segment_ids=seg)
        return (o * do).sum()

    def f_ref(q, k, v):
        return (attention_reference(q, k, v, spec, segment_ids=seg)[0] * do).sum()

    g_ref = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for impl in (f_pallas, f_xla):
        for name, a, b in zip("dq dk dv".split(), jax.grad(impl, (0, 1, 2))(q, k, v), g_ref):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-3, err_msg=name)


def test_varlen_gqa_mqa():
    """GQA grouping (and the G=Hq MQA extreme) under packing."""
    seg = _segments(3, seed=3)
    spec = MaskSpec(causal=True)
    for hq, hk in [(4, 2), (4, 1)]:
        q, k, v, _ = _mk(hq=hq, hk=hk)
        o_ref, _ = attention_reference(q, k, v, spec, segment_ids=seg)
        o = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=BLK, block_kv=BLK)
        np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=1e-4)


def test_varlen_bf16():
    q, k, v, _ = _mk(jnp.bfloat16)
    seg = _segments(4, seed=5)
    spec = MaskSpec(causal=True)
    o_ref, _ = attention_reference(q, k, v, spec, segment_ids=seg)
    o = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=BLK, block_kv=BLK)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32), atol=2e-2, rtol=2e-2
    )


def test_varlen_block_size_invariance():
    """Packed output must not depend on the tile schedule."""
    q, k, v, _ = _mk()
    seg = _segments(3, seed=13)
    spec = MaskSpec(causal=True)
    o64 = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=64, block_kv=64)
    o32 = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=32, block_kv=32)
    o_asym = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=32, block_kv=64)
    np.testing.assert_allclose(o64, o32, atol=3e-6, rtol=1e-5)
    np.testing.assert_allclose(o64, o_asym, atol=3e-6, rtol=1e-5)


# ---------------------------------------------------------------- accounting


def test_block_skip_accounting_aligned():
    """Visible tiles of a packed batch == sum of per-segment visible tiles
    (no B x S^2 fallback) when boundaries are block-aligned."""
    spec = MaskSpec(causal=True)
    bq = bk = 32
    lengths = [96, 64, 96]  # multiples of the block -> exact accounting
    Sq = sum(lengths)
    segs = np.repeat(np.arange(1, len(lengths) + 1), lengths)
    t = Sq // bq
    got = len(_visible_pairs(spec, t, t, bq, bk, segments=segs)[0])
    want = 0
    for L in lengths:
        tl = L // bq
        want += len(_visible_pairs(spec, tl, tl, bq, bk)[0])  # per-segment causal
    assert got == want, (got, want)
    # and far below the no-skip causal count for the whole row
    assert got < len(_visible_pairs(spec, t, t, bq, bk)[0])


def test_block_skip_accounting_unaligned():
    """Unaligned boundaries: every kept tile must contain a same-segment
    pair, every dropped (but spec-visible) tile must not."""
    spec = MaskSpec(causal=True)
    bq = bk = 32
    Sq = 256
    segs = np.repeat([1, 2, 3], [100, 90, 66])  # not multiples of 32
    t = Sq // bq
    kept = set(zip(*(arr.tolist() for arr in _visible_pairs(spec, t, t, bq, bk, segments=segs))))
    spec_vis = set(zip(*(arr.tolist() for arr in _visible_pairs(spec, t, t, bq, bk))))
    assert kept < spec_vis  # strictly fewer tiles than causal-only
    for (i, j) in spec_vis:
        qs = segs[i * bq : (i + 1) * bq]
        ks = segs[j * bk : (j + 1) * bk]
        same = (qs[:, None] == ks[None, :]).any()
        assert ((i, j) in kept) == bool(same), (i, j)


# ------------------------------------------------------------------- helpers


def test_segment_positions():
    seg = jnp.asarray([[1, 1, 1, 2, 2, 3, 0, 0]])
    got = segment_positions(seg)
    np.testing.assert_array_equal(got[0], [0, 1, 2, 0, 1, 0, 0, 1])


def test_segment_info_accepted_by_public_api():
    """SegmentInfo is interchangeable with the raw id array on both
    varlen entry points."""
    q, k, v, _ = _mk()
    seg = _segments(2, seed=21)
    spec = MaskSpec(causal=True)
    info = SegmentInfo.packed(seg)
    assert info.q is info.kv
    o_ids = flash_attention_pallas_varlen(q, k, v, seg, spec, block_q=BLK, block_kv=BLK)
    o_info = flash_attention_pallas_varlen(q, k, v, info, spec, block_q=BLK, block_kv=BLK)
    np.testing.assert_array_equal(o_ids, o_info)
    x_ids = flash_attention(q, k, v, spec, block_q=BLK, block_kv=BLK, segment_ids=seg)
    x_info = flash_attention(q, k, v, spec, block_q=BLK, block_kv=BLK, segment_ids=info)
    np.testing.assert_array_equal(x_ids, x_info)


def test_pack_documents():
    from repro.data.pipeline import pack_documents

    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 26)]  # 4+2+5 pairs
    inputs, targets, seg, mask = pack_documents(docs, seq_len=8)
    assert inputs.shape == targets.shape == seg.shape == mask.shape
    # first-fit: row0 = doc0 (4) + doc1 (2); row1 = doc2 (5)
    assert seg.shape[0] == 2
    np.testing.assert_array_equal(seg[0], [1, 1, 1, 1, 2, 2, 0, 0])
    np.testing.assert_array_equal(inputs[0, :4], [1, 2, 3, 4])
    np.testing.assert_array_equal(targets[0, :4], [2, 3, 4, 5])
    np.testing.assert_array_equal(inputs[0, 4:6], [10, 11])
    np.testing.assert_array_equal(targets[0, 4:6], [11, 12])
    assert mask[0].sum() == 6 and mask[1].sum() == 5
    # targets never leak across segments: boundary target comes from its doc
    assert targets[0, 3] == 5 and targets[0, 5] == 12


# -------------------------------------------------------------------- decode


def test_packed_decode_segment_isolated():
    """Split-KV decode must not read across segment boundaries in a packed
    cache -- XLA and Pallas paths against the masked dense reference."""
    from repro.core.decode import flash_decode
    from repro.kernels.ops import flash_decode_pallas

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    Sc, hq, hk = 128, 4, 2
    q = jax.random.normal(ks[0], (B, 1, hq, D))
    kc = jax.random.normal(ks[1], (B, Sc, hk, D))
    vc = jax.random.normal(ks[2], (B, Sc, hk, D))
    cache_len = jnp.array([100, 120], jnp.int32)
    kseg = np.zeros((B, Sc), np.int32)
    kseg[0, :60] = 1
    kseg[0, 60:100] = 2
    kseg[1, :50] = 1
    kseg[1, 50:120] = 2
    kseg = jnp.asarray(kseg)
    qseg = jnp.array([2, 2], jnp.int32)

    # dense oracle: same-segment AND within cache_len
    kv_ids = jnp.where(jnp.arange(Sc)[None] < cache_len[:, None], kseg, -1)
    o_ref, _ = attention_reference(
        q, kc, vc, MaskSpec(), segment_ids=qseg[:, None], kv_segment_ids=kv_ids
    )
    o_x, _ = flash_decode(q, kc, vc, cache_len, kv_segment_ids=kseg, q_segment=qseg)
    o_p, _ = flash_decode_pallas(
        q, kc, vc, cache_len, kv_segment_ids=kseg, q_segment=qseg
    )
    np.testing.assert_allclose(o_x, o_ref, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(o_p, o_ref, atol=2e-5, rtol=1e-4)
