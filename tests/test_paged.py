"""Paged-KV serving: page pool, block-table indirect decode kernel
(bitwise vs contiguous), and the paged continuous-batching engine
(token parity under join/leave/preemption, zero decode recompiles,
batched single-compile admission)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core.attention import AttentionConfig
from repro.core.decode import flash_decode_paged
from repro.kernels.ops import flash_decode_pallas, flash_decode_paged_pallas
from repro.models import lm
from repro.serving.engine import PagedServingEngine, Request, ServingEngine
from repro.serving.kv_pool import NULL_PAGE, KVPagePool

# ---------------------------------------------------------------------------
# Pool
# ---------------------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    pool = KVPagePool(num_pages=8, page_size=16)
    assert pool.usable_pages == 7 and pool.free_pages == 7
    a = pool.alloc(1, 3)
    assert len(a) == 3 and NULL_PAGE not in a and len(set(a)) == 3
    assert pool.used_pages == 3 and pool.pages_of(1) == a
    b = pool.alloc(2, 4)
    assert set(a).isdisjoint(b) and pool.free_pages == 0
    assert pool.free(1) == 3 and pool.free_pages == 3
    assert pool.pages_of(1) == []
    assert pool.free(2) == 4 and pool.free_pages == 7


def test_pool_alloc_all_or_nothing():
    pool = KVPagePool(num_pages=4, page_size=8)
    assert pool.alloc(1, 5) is None  # over capacity: no partial grant
    assert pool.free_pages == 3 and pool.pages_of(1) == []
    assert pool.alloc(1, 3) is not None
    assert pool.alloc(2, 1) is None  # empty pool


def test_pool_extend_and_oom():
    pool = KVPagePool(num_pages=4, page_size=8)
    first = pool.alloc(7, 2)
    p = pool.extend(7)
    assert p is not None and pool.pages_of(7) == first + [p]
    assert pool.extend(7) is None  # OOM signals the engine to preempt
    assert pool.page_utilization() == 1.0


def test_pool_pages_for_tokens():
    pool = KVPagePool(num_pages=4, page_size=16)
    assert pool.pages_for_tokens(1) == 1
    assert pool.pages_for_tokens(16) == 1
    assert pool.pages_for_tokens(17) == 2


# ---------------------------------------------------------------------------
# Kernel: page-indirect decode vs contiguous
# ---------------------------------------------------------------------------

B, S, PS, Hq, Hk, D = 3, 128, 16, 8, 2, 64
NPAGES = S // PS


def _paginate(kc, vc, seed=0):
    """Contiguous (B,S,Hk,D) caches -> shuffled physical page planes
    (Hk,P,ps,D) + block table, page 0 reserved null."""
    kc, vc = np.asarray(kc), np.asarray(vc)
    P = B * NPAGES + 1
    perm = np.random.default_rng(seed).permutation(P - 1) + 1
    table = perm.reshape(B, NPAGES).astype(np.int32)
    k_pages = np.zeros((Hk, P, PS, D), kc.dtype)
    v_pages = np.zeros((Hk, P, PS, D), vc.dtype)
    for b in range(B):
        for i in range(NPAGES):
            phys = table[b, i]
            k_pages[:, phys] = kc[b, i * PS : (i + 1) * PS].transpose(1, 0, 2)
            v_pages[:, phys] = vc[b, i * PS : (i + 1) * PS].transpose(1, 0, 2)
    return jnp.asarray(k_pages), jnp.asarray(v_pages), jnp.asarray(table)


@pytest.fixture(scope="module")
def kv():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    kc = jax.random.normal(ks[0], (B, S, Hk, D))
    vc = jax.random.normal(ks[1], (B, S, Hk, D))
    q = jax.random.normal(ks[2], (B, 1, Hq, D))
    lens = jnp.array([128, 97, 37], jnp.int32)  # full / prime / odd-page
    return q, kc, vc, lens


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_paged_bitwise_parity_one_page_per_split(kv, dtype):
    """One split == one page makes the paged kernel's per-split math and
    merge tree identical to the contiguous kernel's -> (o, lse) must be
    BITWISE equal, independent of physical page placement. GQA (Hq=8 over
    Hk=2) and ragged prime/odd lengths included."""
    q, kc, vc = (t.astype(dtype) for t in kv[:3])
    lens = kv[3]
    k_pages, v_pages, table = _paginate(kc, vc)
    o_c, lse_c = flash_decode_pallas(q, kc, vc, lens, num_splits=NPAGES)
    o_p, lse_p = flash_decode_paged_pallas(
        q, k_pages, v_pages, lens, table, num_splits=NPAGES
    )
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_c))
    np.testing.assert_array_equal(np.asarray(lse_p), np.asarray(lse_c))


def test_paged_multi_page_splits_match(kv):
    """pp > 1 (several pages walked sequentially per split) changes the
    reduction order, so parity is allclose, not bitwise."""
    q, kc, vc, lens = kv
    k_pages, v_pages, table = _paginate(kc, vc)
    o_c, lse_c = flash_decode_pallas(q, kc, vc, lens, num_splits=NPAGES)
    for splits in (1, 2, 4):
        o_p, lse_p = flash_decode_paged_pallas(
            q, k_pages, v_pages, lens, table, num_splits=splits
        )
        np.testing.assert_allclose(o_p, o_c, atol=5e-6, rtol=1e-5)
        np.testing.assert_allclose(lse_p, lse_c, atol=1e-5, rtol=1e-5)


def test_paged_shuffle_invariance(kv):
    """The physical placement of pages is pure bookkeeping: two different
    shuffles must produce BITWISE identical results."""
    q, kc, vc, lens = kv
    outs = []
    for seed in (0, 1):
        k_pages, v_pages, table = _paginate(kc, vc, seed=seed)
        outs.append(
            flash_decode_paged_pallas(
                q, k_pages, v_pages, lens, table, num_splits=4
            )
        )
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(outs[1][0]))
    np.testing.assert_array_equal(np.asarray(outs[0][1]), np.asarray(outs[1][1]))


def test_paged_window_sink_bitwise(kv):
    q, kc, vc, lens = kv
    k_pages, v_pages, table = _paginate(kc, vc)
    o_c, lse_c = flash_decode_pallas(
        q, kc, vc, lens, window=32, sink=8, num_splits=NPAGES
    )
    o_p, lse_p = flash_decode_paged_pallas(
        q, k_pages, v_pages, lens, table, window=32, sink=8, num_splits=NPAGES
    )
    np.testing.assert_array_equal(np.asarray(o_p), np.asarray(o_c))
    np.testing.assert_array_equal(np.asarray(lse_p), np.asarray(lse_c))


def test_paged_xla_fallback_matches(kv):
    q, kc, vc, lens = kv
    k_pages, v_pages, table = _paginate(kc, vc)
    o_p, lse_p = flash_decode_paged_pallas(
        q, k_pages, v_pages, lens, table, num_splits=4
    )
    o_x, lse_x = flash_decode_paged(
        q, k_pages, v_pages, lens, table, num_splits=4
    )
    np.testing.assert_allclose(o_p, o_x, atol=5e-6, rtol=1e-5)
    np.testing.assert_allclose(lse_p, lse_x, atol=1e-5, rtol=1e-5)


def test_paged_empty_slot_masked(kv):
    """ISSUE 7 satellite: a free/finished slot (length 0, all-null table
    row) must read no KV: its pages are never active, so o == 0 and
    lse == -inf, regardless of what garbage sits in the null page."""
    q, kc, vc, _ = kv
    k_pages, v_pages, table = _paginate(kc, vc)
    # poison the null page: masked-out reads would show up immediately
    k_pages = k_pages.at[:, 0].set(1e9)
    v_pages = v_pages.at[:, 0].set(1e9)
    lens = jnp.array([128, 0, 37], jnp.int32)
    table = table.at[1].set(0)
    o, lse = flash_decode_paged_pallas(
        q, k_pages, v_pages, lens, table, num_splits=4
    )
    assert np.all(np.asarray(o[1]) == 0.0)
    assert np.all(np.isneginf(np.asarray(lse[1])))
    # live rows unaffected by the poisoned null page
    o_ref, _ = flash_decode_paged(
        q, k_pages, v_pages, lens, table, num_splits=4
    )
    np.testing.assert_allclose(o[0], o_ref[0], atol=5e-6, rtol=1e-5)
    np.testing.assert_allclose(o[2], o_ref[2], atol=5e-6, rtol=1e-5)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

ATTN = AttentionConfig(impl="flash_xla", block_q=64, block_kv=64, decode_splits=2)


@pytest.fixture(scope="module")
def model():
    cfg = registry.reduce_config(registry.get("qwen3-8b"))
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _sequential_refs(cfg, params, prompts, max_new):
    """Oracle: each request alone through the fixed-slot engine."""
    refs = {}
    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, ATTN, max_batch=1, cache_size=64,
                             prompt_pad=16)
        solo.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new))
        refs[i] = solo.run(max_ticks=200)[i].generated
    return refs


def test_paged_engine_token_parity_and_compiles(model):
    """Requests joining and leaving mid-flight through the paged engine
    generate exactly the sequential-oracle tokens; the decode step compiles
    ONCE for the whole run and admission compiles once per (bucket, width)."""
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, 100, rng.integers(2, 20))))
               for _ in range(5)]
    refs = _sequential_refs(cfg, params, prompts, max_new=6)
    eng = PagedServingEngine(cfg, params, ATTN, max_batch=2, num_pages=17,
                             page_size=8, pages_per_seq_max=8, prompt_pad=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=6))
    done = eng.run(max_ticks=400)
    assert sorted(done) == list(range(5))
    for i in range(5):
        assert done[i].generated == refs[i], i
    assert eng.decode_compiles == 1  # zero recompiles across join/leave
    # 5 prompts, 2 buckets (pad 16 / 32), widths bounded by max_batch=2:
    # a handful of admit traces, never one per request
    assert eng.admit_compiles <= 4
    # free-on-retire returned every page
    assert eng.pool.used_pages == 0
    assert eng.pool.free_pages == eng.pool.usable_pages


def test_paged_engine_batched_admission_one_compile(model):
    """All same-bucket queued prompts are admitted in ONE batched prefill:
    3 different same-bucket lengths into an empty 4-slot engine -> exactly
    one admit trace, and slot reuse later sticks to it."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ATTN, max_batch=4, num_pages=33,
                             page_size=8, pages_per_seq_max=8, prompt_pad=16)
    for i, L in enumerate((3, 7, 11)):
        eng.submit(Request(rid=i, prompt=[2 + i] * L, max_new_tokens=4))
    eng.tick()  # admits all three in one call (width padded to 4)
    assert eng.admit_compiles == 1
    for i, L in enumerate((5, 9, 13)):
        eng.submit(Request(rid=10 + i, prompt=[1 + i] * L, max_new_tokens=4))
    done = eng.run(max_ticks=200)
    assert sorted(done) == [0, 1, 2, 10, 11, 12]
    # one bucket, pow2 widths only: at most 1 + log2(max_batch) traces ever,
    # however requests trickle in (here widths 4, then 1/2 as slots free)
    assert eng.admit_compiles <= 3
    assert eng.decode_compiles == 1


def test_paged_engine_preemption_resume(model):
    """A pool too small for concurrent growth forces preempt-youngest;
    requeued requests resume (prompt+generated re-prefill) and still
    produce exactly the oracle tokens."""
    cfg, params = model
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(1, 100, 6))) for _ in range(4)]
    refs = _sequential_refs(cfg, params, prompts, max_new=24)
    eng = PagedServingEngine(cfg, params, ATTN, max_batch=4, num_pages=14,
                             page_size=4, pages_per_seq_max=8, prompt_pad=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=24))
    done = eng.run(max_ticks=1000)
    assert sorted(done) == list(range(4))
    for i in range(4):
        assert done[i].generated == refs[i], i
    assert eng.preemptions > 0, "pool was sized to force preemption"
    assert eng.decode_compiles == 1  # preemption churn never recompiles


def test_paged_engine_rejects_oversized(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, ATTN, max_batch=2, num_pages=9,
                             page_size=8, pages_per_seq_max=4)
    with pytest.raises(AssertionError):
        eng.submit(Request(rid=0, prompt=[1] * 20, max_new_tokens=20))
